"""Throughput + footprint benchmark: bandit-step rate vs fleet size.

The paper's §V-F complexity claim (O(|Q_k|) per decision step) only
matters if the loop actually scales past the testbed's 30 LBs x 10
instances, so this sweeps K (players) x M (arms) far beyond it and
emits steps/sec, µs/step, per-cell compile seconds and per-cell peak
device memory (XLA ``memory_analysis``: temp + output buffers) per
variant:

  * ``stream``     — the fleet-scale hot path: the FUSED round
                     (``SimConfig.fused_round``, kernels/ops.round_step
                     — all C rounds of a step in one dispatch), metric
                     accumulators carried on device, O(K·M) memory
                     independent of the horizon (trace=False). This is
                     the cell the smoke floor gates, so the fused path
                     cannot regress and stay green.
  * ``round_scan`` — the streaming cell with only ``fused_round``
                     disabled (per-step lax.scan over the C rounds):
                     ``round_fusion_speedup`` isolates what the round
                     megakernel buys on the anchor cells.
  * ``trace``      — same step structure but materializing the full
                     (T, K, C)/(T, K, M) trajectories (trace=True);
                     the memory baseline the streaming engine deprecates.
  * ``sequential`` — the pre-PR-1 step structure (per-round ring
                     scatters + full-width (K, M, R) sort+KDE
                     maintenance every step), kept as the historical
                     speedup reference on a few anchor cells.
  * ``resilient``  — the streaming cell with the request-lifecycle
                     resilience layer on (attempt timeout + 2
                     deadline-bounded retries + circuit breakers):
                     the ``resilience_overhead`` ratio per M=10 cell
                     prices the unrolled attempt loop, and the smoke
                     gate holds it to the same steps/s floor.
  * ``controlled`` — the resilient cell plus the closed-loop control
                     plane (reactive autoscaler + AIMD admission +
                     capacity migration in the scan carry): the
                     ``control_overhead`` ratio prices the policy
                     state machine, gated on the same smoke floor.
  * ``recorder``   — the streaming cell with the flight recorder on
                     (``SimConfig.recorder``, repro.obs: a 1024-event
                     ring in the scan carry recording breaker/retry/
                     control/QoS-spike events): ``recorder_overhead``
                     prices always-on observability, and the obs CI
                     lane asserts the K=1000 x M=50 anchor stays under
                     1.10x. Because this ratio is a *gated artifact*,
                     it is measured from interleaved best-of-N runs of
                     the stream and recorder executables
                     (``_paired_overhead``) so container load drift
                     between the independently timed cells cannot
                     masquerade as recorder cost.

Two extra cells tell the memory story end to end:

  * ``mem_*`` — K=1000 x M=50 at a 120 s horizon: the streaming cell is
    compiled AND run; the trace reference is only compiled (its
    ``memory_analysis`` peak is the point — running it would allocate
    the very trajectories the engine exists to avoid).
  * ``chunked_*`` — the `build_sim_chunks` driver with a donated carry,
    timed over the full chunk loop, proving the bounded-memory path
    costs no meaningful throughput.

The ``grid_dev*`` cells measure the **sharded evaluation grid**
(`build_sim_grid_fn`): the same S-scenario streaming grid run at 1, 2,
4 and 8 devices. Device count is a process-level XLA decision, so each
cell runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=D`` (fake host
devices share the container's cores, so wall-clock speedups here are
bounded by real parallelism — per-device *memory* and program shape
are the faithfully measured quantities; see EXPERIMENTS.md).

The ``players_dev*`` cells measure the **player-sharded simulator**
(`build_sim_players_fn`): ONE K=1000 × M=50 simulation whose player
axis splits over 1/2/4/8 devices — per-device peak memory is the
headline column (the ~37 MB bandit state divides D ways), and the
``players_K10000`` cell runs a K=10⁴ fleet end to end at 8 shards to
pin the per-device peak of a fleet one device would struggle to hold.
Same subprocess mechanics as the grid cells.

In ``--smoke`` mode the grid shrinks to seconds and the measured
streaming/chunked cells — including one multi-fake-device ``grid_dev``
cell and one 2-D (data=2 × players=2) ``grid2x2`` cell, so neither
shard axis can silently rot on single-GPU runners —
are gated on ``SMOKE_FLOOR_STEPS_PER_S``, a deliberately conservative
floor (~5x below typical container numbers) so CI fails on an
order-of-magnitude regression, not on scheduler noise. The grid cell
is gated on *per-device* throughput (aggregate ÷ device count, so
D-way lane parallelism cannot mask a per-lane regression) against its
own lower ``SMOKE_GRID_FLOOR_STEPS_PER_S`` — fake devices share
however few physical cores the runner has, so per-device rates sink
with oversubscription even when nothing regressed. ``_grid_cell`` also
hard-fails if the child did not actually see the requested device
count, so the shard path cannot silently degrade to the 1-device
fallback and stay green.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, executable_memory, timed
from repro.continuum import (Scenario, SimConfig, build_sim_chunks,
                             build_sim_fn, compile_scenario, slice_drivers)
from repro.obs import RecorderConfig

GRID_K = (30, 100, 300, 1000)
GRID_M = (10, 50)
SMOKE_GRID_K = (30, 100)
SMOKE_GRID_M = (10,)
# Cells that also run the references: small, mid and large K*M anchor
# the speedup / memory trends without paying the sequential reference's
# full-width maintenance (minutes of wall clock) on every cell.
# The K=1000 x M=50 anchor joined the sequential references once the
# bitonic maintenance sort made its full-width (50k, 64) pass ~60 ms
# instead of ~350 ms/step: the headline fused-vs-pre-PR-1 speedup is
# now measured on the ROADMAP cell itself, not extrapolated.
SEQ_REF_CELLS = ((30, 10), (100, 50), (300, 50), (1000, 50))
# round_scan (only the round scan differs from stream) runs on the
# same cells — it is cheap everywhere.
ROUND_REF_CELLS = SEQ_REF_CELLS
# recorder-overhead anchors: the smallest cell (where fixed per-step
# cost shows worst) and the ROADMAP K=1000 x M=50 cell the obs CI lane
# gates at < 1.10x.
RECORDER_CELLS = ((30, 10), (1000, 50))
TRACE_REF_CELLS = ((30, 10), (100, 50), (300, 50), (1000, 50))
MEM_CELL = (1000, 50, 120.0)        # K, M, horizon [s] for the memory story
# CI floor for the smoke gate (stream + chunked cells, K<=100 x M=10 at
# a 2 s horizon). The slowest gated cell (chunked K100, 4 dispatches of
# 5 steps) measured ~185 steps/s on this container and the others are
# 280-1400; the floor sits ~3x under the worst so it catches structural
# regressions (e.g. the round loop re-unrolling), not scheduler noise.
SMOKE_FLOOR_STEPS_PER_S = 60.0
# Per-device floor for the smoke grid cell. Worst case is a 1-core
# runner where 4 fake devices timeshare one core: per-device rate ~=
# single-stream/4 ~= 150-300 steps/s for the smoke cell's K30xM10 on
# this container, so 25 keeps ~6x margin there while a structural
# shard-path regression (10x+) still trips it.
SMOKE_GRID_FLOOR_STEPS_PER_S = 25.0


def _rand_rtt(K, M, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0.002, 0.04, (K, M)), jnp.float32)


def _cell_inputs(K, M, cfg):
    # throughput cells run the compiled `baseline` scenario — same
    # constant schedules as before, produced by the scenario compiler
    drv = compile_scenario(Scenario("baseline", n_nodes=K, n_instances=M),
                           cfg, jax.random.PRNGKey(0))
    return (_rand_rtt(K, M), drv, jax.random.PRNGKey(7))


# the resilience-overhead row: the streaming cell re-measured with the
# full request-lifecycle layer on (timeout + 2 deadline-bounded retries
# + breakers), so the unrolled attempt loop and the breaker carry pay
# their cost in the open
RESILIENT_KNOBS = dict(attempt_timeout=0.055, max_retries=2,
                       retry_backoff=0.002, breaker_threshold=4,
                       breaker_cooldown=1.0)


def _controlled_knobs():
    # the control-overhead row: resilient cell + the full closed-loop
    # control plane (reactive autoscaler over a 2-instance standby
    # slice, AIMD admission, 2-region capacity migration), so the
    # controller's in-carry state machine pays its per-step cost in
    # the open
    from repro.continuum import ControlConfig
    return dict(RESILIENT_KNOBS,
                control=ControlConfig(managed=2, warmup=1.0,
                                      up_queue=2.0, down_queue=0.5,
                                      hold=0.4, action_cooldown=2.0,
                                      admit=True, target_queue=3.0,
                                      regions=2))


def _lower_cell(K, M, horizon, variant):
    knobs = {}
    if variant == "resilient":
        knobs = RESILIENT_KNOBS
    elif variant == "controlled":
        knobs = _controlled_knobs()
    elif variant == "round_scan":
        # the streaming cell with ONLY the round megakernel disabled
        # (the per-step scan over C rounds stays): isolates what round
        # fusion itself buys, where ``sequential`` prices the whole
        # pre-PR-1 step structure
        knobs = dict(fused_round=False)
    elif variant == "recorder":
        # the streaming cell with ONLY the flight recorder on; the
        # fused round stays (the recorder update sits outside the
        # round loop), so the ratio prices the ring append alone
        knobs = dict(recorder=RecorderConfig(capacity=1024))
    cfg = SimConfig(horizon=horizon, **knobs)
    args = _cell_inputs(K, M, cfg)
    run = jax.jit(build_sim_fn(
        "qedgeproxy", cfg, K, M, fused=variant != "sequential",
        trace=variant not in ("stream", "resilient", "controlled",
                              "round_scan", "recorder")))
    return run.lower(*args), args, cfg.num_steps


def _compile_cell(lowered):
    """Compile one AOT-lowered program; returns (exe, seconds, memory).

    Per-device peak memory comes from XLA's static ``memory_analysis``
    (temp + output buffers of the executable; see
    ``common.executable_memory``) — deterministic, no need to execute,
    and it is exactly the quantity that differs between streaming and
    trace mode (trajectory outputs vs accumulators) and between grid
    device counts (each device holds only its scenario shard).
    """
    t0 = time.perf_counter()
    exe = lowered.compile()
    compile_s = time.perf_counter() - t0
    return exe, compile_s, executable_memory(exe)


def _measure(K, M, horizon, variant, run=True, with_exe=False):
    lowered, args, T = _lower_cell(K, M, horizon, variant)
    exe, compile_s, mem = _compile_cell(lowered)
    cell = {"steps": T, "compile_s": compile_s, **mem}
    if run:
        _, us = timed(exe, *args)
        run_s = us / 1e6
        cell.update(run_s=run_s, steps_per_s=T / run_s,
                    us_per_step=us / T)
    if with_exe:
        return cell, exe, args
    return cell


def _paired_overhead(exe_a, args_a, exe_b, args_b, reps=5):
    """Overhead ratio b/a from interleaved best-of-N timings.

    The per-variant cells are timed minutes apart (compiles in
    between), so a ratio of their single-shot numbers folds container
    load drift into what it claims is per-step cost — that is how a
    ~1.5% recorder cost once landed in the artifact as 1.79x.
    Alternating a/b back-to-back inside one window cancels the drift;
    best-of-N rejects scheduler spikes. Returns
    ``(ratio, best_a_us, best_b_us)`` (per-call microseconds)."""
    for exe, args in ((exe_a, args_a), (exe_b, args_b)):    # warm both
        jax.block_until_ready(exe(*args))
    best_a = best_b = float("inf")
    for _ in range(reps):
        _, us = timed(exe_a, *args_a)
        best_a = min(best_a, us)
        _, us = timed(exe_b, *args_b)
        best_b = min(best_b, us)
    return best_b / best_a, best_a, best_b


def _chunked_cell(K, M, horizon, chunk_steps):
    """Full chunk loop through `build_sim_chunks` with a donated carry:
    per-chunk compile measured once (AOT), steps/s over the whole loop
    including the host-side chunk dispatch."""
    cfg = SimConfig(horizon=horizon)
    T = cfg.num_steps
    rtt, drv, key = _cell_inputs(K, M, cfg)
    init_fn, chunk_fn = build_sim_chunks("qedgeproxy", cfg, K, M)
    carry, keys = jax.jit(init_fn)(rtt, drv.active[0], key)
    jax.block_until_ready(jax.tree.leaves(carry))
    n = chunk_steps
    lowered = jax.jit(chunk_fn, donate_argnums=(1,)).lower(
        rtt, carry, jnp.arange(n), slice_drivers(drv, 0, n), keys[:n])
    exe, compile_s, mem = _compile_cell(lowered)

    t0 = time.perf_counter()
    steps = 0
    for lo in range(0, T - n + 1, n):       # drop any remainder chunk
        carry, ys = exe(rtt, carry, jnp.arange(lo, lo + n),
                        slice_drivers(drv, lo, lo + n), keys[lo:lo + n])
        steps += n
    jax.block_until_ready(jax.tree.leaves(carry))
    run_s = time.perf_counter() - t0
    return {"steps": steps, "chunk_steps": n, "chunks": steps // n,
            "compile_s": compile_s, "run_s": run_s,
            "steps_per_s": steps / run_s,
            "us_per_step": run_s / steps * 1e6, **mem}


# Sharded-grid device scaling: forced host device counts for the full
# sweep and the (smaller) smoke gate cells. Fake devices beyond the
# container's cores only stress correctness, not speed.
GRID_DEVICES = (1, 2, 4, 8)
GRID_CELL = dict(K=100, M=10, S=8, horizon=10.0)
SMOKE_GRID_CELL = dict(devices=4, K=30, M=10, S=4, horizon=2.0)
# 2-D mesh smoke cell: lanes over data=2 AND each lane's players over
# players=2 — the composed axes stay load-bearing in CI. The longer
# horizon amortizes per-dispatch overhead so the per-data-shard rate
# sits ~5x over the smoke floor on this container.
SMOKE_GRID2D_CELL = dict(devices=4, players=2, K=32, M=10, S=4,
                         horizon=6.0)
# player-sharded single-simulation cells (full mode): the ROADMAP's
# K=1000 x M=50 memory cell split 1/2/4/8 ways, plus one K=10^4 fleet
PLAYERS_DEVICES = (1, 2, 4, 8)
PLAYERS_CELL = dict(K=1000, M=50, horizon=5.0)
PLAYERS_XL_CELL = dict(devices=8, K=10_000, M=50, horizon=2.0)

_GRID_SUB_SRC = """
import json, time
import jax, jax.numpy as jnp, numpy as np
from benchmarks.common import executable_memory
from repro.continuum import (SimConfig, build_sim_grid_fn, compile_scenario,
                             get_library, stack_drivers)
from repro.launch.mesh import make_continuum_mesh

K, M, S, horizon, players = {K}, {M}, {S}, {horizon}, {players}
cfg = SimConfig(horizon=horizon)
T = cfg.num_steps
rng = np.random.default_rng(0)
rtts = jnp.asarray(rng.uniform(0.002, 0.04, (S, K, M)), jnp.float32)
keys = jax.random.split(jax.random.PRNGKey(7), S)
# grid lanes cycle the scenario library: the sharded axis carries real
# scenario DIVERSITY (surges, failures, drift), not constant fills
lib = list(get_library(horizon, K, M).values())
drivers = stack_drivers(
    [compile_scenario(lib[i % len(lib)], cfg,
                      jax.random.PRNGKey(1000 + i)) for i in range(S)])

mesh = make_continuum_mesh(players=players) if players > 1 else None
run_grid, mesh = build_sim_grid_fn("qedgeproxy", cfg, K, M, mesh=mesh)
t0 = time.perf_counter()
exe = jax.jit(run_grid).lower(rtts, drivers, keys).compile()
compile_s = time.perf_counter() - t0
t0 = time.perf_counter()
out = exe(rtts, drivers, keys)
jax.block_until_ready(out)
run_s = time.perf_counter() - t0
cell = dict(devices=int(mesh.devices.size), player_shards=players,
            scenarios=S, steps=T,
            sharded=int(mesh.devices.size) > 1, compile_s=compile_s,
            run_s=run_s, grid_steps_per_s=S * T / run_s,
            **executable_memory(exe))
print("GRID_CELL " + json.dumps(cell))
"""

_PLAYERS_SUB_SRC = """
import json, time
import jax, jax.numpy as jnp, numpy as np
from benchmarks.common import executable_memory
from repro.continuum import SimConfig, Scenario, build_sim_players_fn, \\
    compile_scenario

K, M, horizon = {K}, {M}, {horizon}
cfg = SimConfig(horizon=horizon)
T = cfg.num_steps
rng = np.random.default_rng(0)
rtt = jnp.asarray(rng.uniform(0.002, 0.04, (K, M)), jnp.float32)
drv = compile_scenario(Scenario("baseline", n_nodes=K, n_instances=M),
                       cfg, jax.random.PRNGKey(0))
key = jax.random.PRNGKey(7)
run, mesh = build_sim_players_fn("qedgeproxy", cfg, K, M)
t0 = time.perf_counter()
exe = jax.jit(run).lower(rtt, drv, key).compile()
compile_s = time.perf_counter() - t0
t0 = time.perf_counter()
out = exe(rtt, drv, key)
jax.block_until_ready(out)
run_s = time.perf_counter() - t0
sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
cell = dict(devices=int(mesh.devices.size),
            player_shards=int(sizes.get("players", 1)), K=K, M=M,
            steps=T, sharded=int(sizes.get("players", 1)) > 1,
            compile_s=compile_s, run_s=run_s, steps_per_s=T / run_s,
            us_per_step=run_s / T * 1e6, **executable_memory(exe))
print("PLAYERS_CELL " + json.dumps(cell))
"""


_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _forced_device_cell(devices, src, marker):
    """Run one benchmark cell at a forced host device count.

    XLA locks the device count at first init, so each point of a
    device-scaling sweep needs its own process; the child pins
    JAX_PLATFORMS=cpu (fake host devices are a CPU-platform feature)
    and reports its cell dict as JSON on stdout. The parent env is
    inherited; only the device-count flag is replaced, and the import
    path is pinned to this checkout so the parent's cwd/PYTHONPATH
    don't matter.
    """
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    flags.append(f"--xla_force_host_platform_device_count={devices}")
    env["XLA_FLAGS"] = " ".join(flags)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_REPO_ROOT, "src"), _REPO_ROOT]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run([sys.executable, "-c", src], capture_output=True,
                         text=True, env=env, cwd=_REPO_ROOT, timeout=560)
    if out.returncode != 0:
        raise RuntimeError(
            f"{marker} cell (devices={devices}) failed:\n"
            + out.stdout + out.stderr)
    line = next((l for l in out.stdout.splitlines()
                 if l.startswith(marker + " ")), None)
    if line is None:
        raise RuntimeError(
            f"{marker} cell (devices={devices}) exited 0 without a "
            f"{marker} line:\n" + out.stdout + out.stderr)
    cell = json.loads(line[len(marker) + 1:])
    if cell["devices"] != devices:
        # e.g. the forced-host-device flag stopped being honored: the
        # child fell back to fewer devices and the shard path would go
        # untested (or the scaling table mislabeled) while staying green
        raise RuntimeError(
            f"{marker} cell requested {devices} devices but the child "
            f"saw {cell['devices']}")
    return cell


def _grid_cell(devices, K, M, S, horizon, players=1):
    return _forced_device_cell(
        devices,
        _GRID_SUB_SRC.format(K=K, M=M, S=S, horizon=horizon,
                             players=players),
        "GRID_CELL")


def _players_cell(devices, K, M, horizon):
    """One player-sharded single-simulation cell: all forced devices go
    on the ``players`` axis (``make_continuum_mesh()`` default)."""
    return _forced_device_cell(
        devices, _PLAYERS_SUB_SRC.format(K=K, M=M, horizon=horizon),
        "PLAYERS_CELL")


def bandit_scale():
    grid_k = SMOKE_GRID_K if common.SMOKE else GRID_K
    grid_m = SMOKE_GRID_M if common.SMOKE else GRID_M
    horizon = 2.0 if common.SMOKE else 10.0     # steady steps/s by ~100 steps

    payload = {}
    compile_wall = 0.0
    for M in grid_m:
        for K in grid_k:
            stream_c, stream_exe, stream_args = _measure(
                K, M, horizon, "stream", with_exe=True)
            cell = {"stream": stream_c}
            if M == grid_m[0]:      # resilience-overhead row (one M)
                cell["resilient"] = _measure(K, M, horizon, "resilient")
                cell["resilience_overhead"] = (
                    cell["resilient"]["us_per_step"]
                    / cell["stream"]["us_per_step"])
                cell["controlled"] = _measure(K, M, horizon, "controlled")
                cell["control_overhead"] = (
                    cell["controlled"]["us_per_step"]
                    / cell["resilient"]["us_per_step"])
            if (K, M) in TRACE_REF_CELLS or common.SMOKE:
                cell["trace"] = _measure(K, M, horizon, "trace")
            if (K, M) in SEQ_REF_CELLS or common.SMOKE:
                cell["sequential"] = _measure(K, M, horizon, "sequential")
            if (K, M) in ROUND_REF_CELLS or common.SMOKE:
                cell["round_scan"] = _measure(K, M, horizon, "round_scan")
            if (K, M) in RECORDER_CELLS or common.SMOKE:
                rec_c, rec_exe, rec_args = _measure(
                    K, M, horizon, "recorder", with_exe=True)
                cell["recorder"] = rec_c
                # the gated ratio comes from interleaved paired runs of
                # the two executables, not from the single-shot cells
                # above — see _paired_overhead
                ratio, off_us, on_us = _paired_overhead(
                    stream_exe, stream_args, rec_exe, rec_args)
                cell["stream"]["paired_us_per_step"] = (
                    off_us / cell["stream"]["steps"])
                cell["recorder"]["paired_us_per_step"] = (
                    on_us / rec_c["steps"])
                cell["recorder_overhead"] = ratio
            if "sequential" in cell:
                cell["step_speedup"] = (cell["sequential"]["us_per_step"]
                                        / cell["stream"]["us_per_step"])
            if "round_scan" in cell:
                cell["round_fusion_speedup"] = (
                    cell["round_scan"]["us_per_step"]
                    / cell["stream"]["us_per_step"])
            if "trace" in cell and "per_device_peak_mb" in cell["trace"]:
                cell["hbm_ratio"] = (
                    cell["trace"]["per_device_peak_mb"]
                    / max(cell["stream"]["per_device_peak_mb"], 1e-9))
            compile_wall += sum(v["compile_s"] for v in cell.values()
                                if isinstance(v, dict))
            payload[f"K{K}_M{M}"] = cell

    # chunked-horizon driver: smoke gates it, full mode sizes it up
    ck, cm, chz, cchunk = ((100, 10, 2.0, 5) if common.SMOKE
                           else (300, 50, 30.0, 75))
    chunked = _chunked_cell(ck, cm, chz, cchunk)
    compile_wall += chunked["compile_s"]
    payload[f"chunked_K{ck}_M{cm}"] = chunked

    # sharded evaluation grid: a device-scaling sweep in full mode; in
    # smoke, one multi-fake-device 1-D cell plus one 2-D
    # (data x players) cell (subprocesses either way — the parent's
    # device count is already locked)
    if common.SMOKE:
        c = dict(SMOKE_GRID_CELL)
        grid_cells = {f"grid_dev{c['devices']}": _grid_cell(**c)}
        c2 = dict(SMOKE_GRID2D_CELL)
        grid_cells[f"grid2x2_dev{c2['devices']}"] = _grid_cell(**c2)
    else:
        grid_cells = {f"grid_dev{d}": _grid_cell(devices=d, **GRID_CELL)
                      for d in GRID_DEVICES}
    for name, cell in grid_cells.items():
        compile_wall += cell["compile_s"]
        payload[name] = cell

    if not common.SMOKE:
        # player-axis sharding: the ROADMAP memory cell split D ways,
        # plus one K=10^4 fleet at 8 shards — per-device peak is the
        # headline (state divides D ways; wall clock is bound by the
        # container's cores, like every forced-host-device sweep)
        for d in PLAYERS_DEVICES:
            cell = _players_cell(devices=d, **PLAYERS_CELL)
            compile_wall += cell["compile_s"]
            payload[f"players_dev{d}"] = cell
        xl = dict(PLAYERS_XL_CELL)
        cell = _players_cell(**xl)
        compile_wall += cell["compile_s"]
        payload[f"players_K{xl['K']}_dev{xl['devices']}"] = cell

    if not common.SMOKE:
        # the memory story: stream runs, trace is only compiled — its
        # memory_analysis peak IS the baseline the engine removes
        K, M, hz = MEM_CELL
        mem_stream = _measure(K, M, hz, "stream")
        mem_trace = _measure(K, M, hz, "trace", run=False)
        compile_wall += mem_stream["compile_s"] + mem_trace["compile_s"]
        payload[f"mem_K{K}_M{M}"] = {
            "stream": mem_stream, "trace_compiled_only": mem_trace,
            "hbm_ratio": (mem_trace.get("per_device_peak_mb", 0.0)
                          / max(mem_stream.get("per_device_peak_mb", 1e-9),
                                1e-9))}

    payload["compile_wall_s"] = compile_wall

    if common.SMOKE:
        slow = {k: v["stream"]["steps_per_s"] for k, v in payload.items()
                if isinstance(v, dict) and "stream" in v
                and v["stream"]["steps_per_s"] < SMOKE_FLOOR_STEPS_PER_S}
        # the retry/breaker path holds the same floor: the resilient
        # cell regressing below it means the attempt unroll went
        # quadratic or the breaker carry stopped fusing
        slow.update({f"{k}_resilient": v["resilient"]["steps_per_s"]
                     for k, v in payload.items()
                     if isinstance(v, dict) and "resilient" in v
                     and v["resilient"]["steps_per_s"]
                     < SMOKE_FLOOR_STEPS_PER_S})
        # the closed-loop control carry holds the same floor: a
        # regression here means the controller stopped fusing into the
        # scan (or sneaked in an extra collective)
        slow.update({f"{k}_controlled": v["controlled"]["steps_per_s"]
                     for k, v in payload.items()
                     if isinstance(v, dict) and "controlled" in v
                     and v["controlled"]["steps_per_s"]
                     < SMOKE_FLOOR_STEPS_PER_S})
        # the flight-recorder ring holds the same floor: regressing
        # below it means the ring append stopped fusing into the scan
        slow.update({f"{k}_recorder": v["recorder"]["steps_per_s"]
                     for k, v in payload.items()
                     if isinstance(v, dict) and "recorder" in v
                     and v["recorder"]["steps_per_s"]
                     < SMOKE_FLOOR_STEPS_PER_S})
        if chunked["steps_per_s"] < SMOKE_FLOOR_STEPS_PER_S:
            slow["chunked"] = chunked["steps_per_s"]
        for name, cell in grid_cells.items():
            # gate per data-shard so D-way lane parallelism can't mask
            # a per-lane regression, against the grid cell's own floor
            # (fake devices timeshare the runner's physical cores).
            # Player shards of ONE lane work on the same lane-steps,
            # so they don't divide the lane-step rate.
            data_shards = cell["devices"] / cell.get("player_shards", 1)
            per_device = cell["grid_steps_per_s"] / data_shards
            if per_device < SMOKE_GRID_FLOOR_STEPS_PER_S:
                slow[name] = per_device
        if slow:
            raise RuntimeError(
                f"streaming throughput below the "
                f"{SMOKE_FLOOR_STEPS_PER_S:.0f} steps/s smoke floor: "
                + " ".join(f"{k}={v:.0f}" for k, v in slow.items()))

    biggest = f"K{grid_k[-1]}_M{grid_m[-1]}"
    derived = " ".join(
        f"{k}={v['stream']['steps_per_s']:.0f}steps/s"
        + (f"(x{v['step_speedup']:.1f})" if "step_speedup" in v else "")
        for k, v in payload.items()
        if isinstance(v, dict) and "stream" in v and "steps_per_s" in v["stream"])
    derived += " " + " ".join(
        f"{k}={v['grid_steps_per_s']:.0f}steps/s"
        for k, v in grid_cells.items())
    derived += " " + " ".join(
        f"{k}={v.get('per_device_peak_mb', 0.0):.1f}MB/dev"
        for k, v in payload.items() if k.startswith("players_"))
    derived += " " + " ".join(
        f"{k}:round_x{v['round_fusion_speedup']:.2f}"
        for k, v in payload.items()
        if isinstance(v, dict) and "round_fusion_speedup" in v)
    derived += " " + " ".join(
        f"{k}:res_x{v['resilience_overhead']:.2f}"
        for k, v in payload.items()
        if isinstance(v, dict) and "resilience_overhead" in v)
    derived += " " + " ".join(
        f"{k}:ctl_x{v['control_overhead']:.2f}"
        for k, v in payload.items()
        if isinstance(v, dict) and "control_overhead" in v)
    derived += " " + " ".join(
        f"{k}:rec_x{v['recorder_overhead']:.2f}"
        for k, v in payload.items()
        if isinstance(v, dict) and "recorder_overhead" in v)
    derived += f" compile_wall={compile_wall:.1f}s"
    mem_key = f"mem_K{MEM_CELL[0]}_M{MEM_CELL[1]}"
    if mem_key in payload:
        derived += f" mem_ratio=x{payload[mem_key]['hbm_ratio']:.0f}"
    emit("bandit_scale", payload[biggest]["stream"]["us_per_step"], derived,
         payload)
    return payload
