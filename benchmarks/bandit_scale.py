"""Throughput + footprint benchmark: bandit-step rate vs fleet size.

The paper's §V-F complexity claim (O(|Q_k|) per decision step) only
matters if the loop actually scales past the testbed's 30 LBs x 10
instances, so this sweeps K (players) x M (arms) far beyond it and
emits steps/sec, µs/step, per-cell compile seconds and per-cell peak
device memory (XLA ``memory_analysis``: temp + output buffers) per
variant:

  * ``stream``     — the fleet-scale hot path: scanned round loop,
                     metric accumulators carried on device, O(K·M)
                     memory independent of the horizon (trace=False).
  * ``trace``      — same step structure but materializing the full
                     (T, K, C)/(T, K, M) trajectories (trace=True);
                     the memory baseline the streaming engine deprecates.
  * ``sequential`` — the pre-PR-1 step structure (per-round ring
                     scatters + full-width (K, M, R) sort+KDE
                     maintenance every step), kept as the historical
                     speedup reference on a few anchor cells.

Two extra cells tell the memory story end to end:

  * ``mem_*`` — K=1000 x M=50 at a 120 s horizon: the streaming cell is
    compiled AND run; the trace reference is only compiled (its
    ``memory_analysis`` peak is the point — running it would allocate
    the very trajectories the engine exists to avoid).
  * ``chunked_*`` — the `build_sim_chunks` driver with a donated carry,
    timed over the full chunk loop, proving the bounded-memory path
    costs no meaningful throughput.

In ``--smoke`` mode the grid shrinks to seconds and the measured
streaming/chunked cells are gated on ``SMOKE_FLOOR_STEPS_PER_S`` — a
deliberately conservative floor (~5x below typical container numbers)
so CI fails on an order-of-magnitude regression, not on scheduler noise.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, timed
from repro.continuum import SimConfig, build_sim_chunks, build_sim_fn

GRID_K = (30, 100, 300, 1000)
GRID_M = (10, 50)
SMOKE_GRID_K = (30, 100)
SMOKE_GRID_M = (10,)
# Cells that also run the references: small, mid and large K*M anchor
# the speedup / memory trends without paying the sequential reference's
# full-width maintenance (minutes of wall clock) on every cell.
SEQ_REF_CELLS = ((30, 10), (100, 50), (300, 50))
TRACE_REF_CELLS = ((30, 10), (100, 50), (300, 50), (1000, 50))
MEM_CELL = (1000, 50, 120.0)        # K, M, horizon [s] for the memory story
# CI floor for the smoke gate (stream + chunked cells, K<=100 x M=10 at
# a 2 s horizon). The slowest gated cell (chunked K100, 4 dispatches of
# 5 steps) measured ~185 steps/s on this container and the others are
# 280-1400; the floor sits ~3x under the worst so it catches structural
# regressions (e.g. the round loop re-unrolling), not scheduler noise.
SMOKE_FLOOR_STEPS_PER_S = 60.0


def _rand_rtt(K, M, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0.002, 0.04, (K, M)), jnp.float32)


def _cell_inputs(K, M, cfg):
    T = cfg.num_steps
    return (_rand_rtt(K, M), jnp.full((T, K), 4, jnp.int32),
            jnp.ones((T, M), bool), jax.random.PRNGKey(7))


def _lower_cell(K, M, horizon, variant):
    cfg = SimConfig(horizon=horizon)
    args = _cell_inputs(K, M, cfg)
    run = jax.jit(build_sim_fn(
        "qedgeproxy", cfg, K, M,
        fused=variant != "sequential", trace=variant != "stream"))
    return run.lower(*args), args, cfg.num_steps


def _compile_cell(lowered):
    """Compile one AOT-lowered program; returns (exe, seconds, memory).

    Peak device memory comes from XLA's static ``memory_analysis``
    (temp + output buffers of the executable) — deterministic, no need
    to execute, and it is exactly the quantity that differs between
    streaming and trace mode (trajectory outputs vs accumulators).
    """
    t0 = time.perf_counter()
    exe = lowered.compile()
    compile_s = time.perf_counter() - t0
    mem = {}
    try:
        ma = exe.memory_analysis()
        mem = {"peak_mb": (ma.temp_size_in_bytes
                           + ma.output_size_in_bytes) / 1e6,
               "temp_mb": ma.temp_size_in_bytes / 1e6,
               "output_mb": ma.output_size_in_bytes / 1e6}
    except Exception:       # pragma: no cover - backend without the API
        pass
    return exe, compile_s, mem


def _measure(K, M, horizon, variant, run=True):
    lowered, args, T = _lower_cell(K, M, horizon, variant)
    exe, compile_s, mem = _compile_cell(lowered)
    cell = {"steps": T, "compile_s": compile_s, **mem}
    if run:
        _, us = timed(exe, *args)
        run_s = us / 1e6
        cell.update(run_s=run_s, steps_per_s=T / run_s,
                    us_per_step=us / T)
    return cell


def _chunked_cell(K, M, horizon, chunk_steps):
    """Full chunk loop through `build_sim_chunks` with a donated carry:
    per-chunk compile measured once (AOT), steps/s over the whole loop
    including the host-side chunk dispatch."""
    cfg = SimConfig(horizon=horizon)
    T = cfg.num_steps
    rtt, n_clients, active, key = _cell_inputs(K, M, cfg)
    init_fn, chunk_fn = build_sim_chunks("qedgeproxy", cfg, K, M)
    carry, keys = jax.jit(init_fn)(rtt, active[0], key)
    jax.block_until_ready(jax.tree.leaves(carry))
    n = chunk_steps
    lowered = jax.jit(chunk_fn, donate_argnums=(1,)).lower(
        rtt, carry, jnp.arange(n), n_clients[:n], active[:n], keys[:n])
    exe, compile_s, mem = _compile_cell(lowered)

    t0 = time.perf_counter()
    steps = 0
    for lo in range(0, T - n + 1, n):       # drop any remainder chunk
        carry, ys = exe(rtt, carry, jnp.arange(lo, lo + n),
                        n_clients[lo:lo + n], active[lo:lo + n],
                        keys[lo:lo + n])
        steps += n
    jax.block_until_ready(jax.tree.leaves(carry))
    run_s = time.perf_counter() - t0
    return {"steps": steps, "chunk_steps": n, "chunks": steps // n,
            "compile_s": compile_s, "run_s": run_s,
            "steps_per_s": steps / run_s,
            "us_per_step": run_s / steps * 1e6, **mem}


def bandit_scale():
    grid_k = SMOKE_GRID_K if common.SMOKE else GRID_K
    grid_m = SMOKE_GRID_M if common.SMOKE else GRID_M
    horizon = 2.0 if common.SMOKE else 10.0     # steady steps/s by ~100 steps

    payload = {}
    compile_wall = 0.0
    for M in grid_m:
        for K in grid_k:
            cell = {"stream": _measure(K, M, horizon, "stream")}
            if (K, M) in TRACE_REF_CELLS or common.SMOKE:
                cell["trace"] = _measure(K, M, horizon, "trace")
            if (K, M) in SEQ_REF_CELLS or common.SMOKE:
                cell["sequential"] = _measure(K, M, horizon, "sequential")
            if "sequential" in cell:
                cell["step_speedup"] = (cell["sequential"]["us_per_step"]
                                        / cell["stream"]["us_per_step"])
            if "trace" in cell and "peak_mb" in cell["trace"]:
                cell["hbm_ratio"] = (cell["trace"]["peak_mb"]
                                     / max(cell["stream"]["peak_mb"], 1e-9))
            compile_wall += sum(v["compile_s"] for v in cell.values()
                                if isinstance(v, dict))
            payload[f"K{K}_M{M}"] = cell

    # chunked-horizon driver: smoke gates it, full mode sizes it up
    ck, cm, chz, cchunk = ((100, 10, 2.0, 5) if common.SMOKE
                           else (300, 50, 30.0, 75))
    chunked = _chunked_cell(ck, cm, chz, cchunk)
    compile_wall += chunked["compile_s"]
    payload[f"chunked_K{ck}_M{cm}"] = chunked

    if not common.SMOKE:
        # the memory story: stream runs, trace is only compiled — its
        # memory_analysis peak IS the baseline the engine removes
        K, M, hz = MEM_CELL
        mem_stream = _measure(K, M, hz, "stream")
        mem_trace = _measure(K, M, hz, "trace", run=False)
        compile_wall += mem_stream["compile_s"] + mem_trace["compile_s"]
        payload[f"mem_K{K}_M{M}"] = {
            "stream": mem_stream, "trace_compiled_only": mem_trace,
            "hbm_ratio": (mem_trace.get("peak_mb", 0.0)
                          / max(mem_stream.get("peak_mb", 1e-9), 1e-9))}

    payload["compile_wall_s"] = compile_wall

    if common.SMOKE:
        slow = {k: v["stream"]["steps_per_s"] for k, v in payload.items()
                if isinstance(v, dict) and "stream" in v
                and v["stream"]["steps_per_s"] < SMOKE_FLOOR_STEPS_PER_S}
        if chunked["steps_per_s"] < SMOKE_FLOOR_STEPS_PER_S:
            slow["chunked"] = chunked["steps_per_s"]
        if slow:
            raise RuntimeError(
                f"streaming throughput below the "
                f"{SMOKE_FLOOR_STEPS_PER_S:.0f} steps/s smoke floor: "
                + " ".join(f"{k}={v:.0f}" for k, v in slow.items()))

    biggest = f"K{grid_k[-1]}_M{grid_m[-1]}"
    derived = " ".join(
        f"{k}={v['stream']['steps_per_s']:.0f}steps/s"
        + (f"(x{v['step_speedup']:.1f})" if "step_speedup" in v else "")
        for k, v in payload.items()
        if isinstance(v, dict) and "stream" in v and "steps_per_s" in v["stream"])
    derived += f" compile_wall={compile_wall:.1f}s"
    mem_key = f"mem_K{MEM_CELL[0]}_M{MEM_CELL[1]}"
    if mem_key in payload:
        derived += f" mem_ratio=x{payload[mem_key]['hbm_ratio']:.0f}"
    emit("bandit_scale", payload[biggest]["stream"]["us_per_step"], derived,
         payload)
    return payload
