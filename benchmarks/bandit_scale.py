"""Throughput benchmark: bandit-step rate vs fleet size (the repo's
first perf trajectory).

The paper's §V-F complexity claim (O(|Q_k|) per decision step) only
matters if the loop actually scales past the testbed's 30 LBs x 10
instances, so this sweeps K (players) x M (arms) far beyond it and
emits steps/sec + µs/step JSON artifacts per cell:

  * ``fused``      — the current simulator hot path: per-round (K, M)
                     feedback control interleaved with selection, ring
                     writes deferred to one ``record_rings_batch``
                     scatter at step end, maintenance gathered to the
                     ~K/H_d players whose staggered clock fired.
                     Compile time reported separately (AOT lowering).
  * ``sequential`` — the pre-refactor step structure (C sequential
                     record rounds + full-width (K, M, R) sort+KDE
                     maintenance every step), same trajectories, kept
                     as the reference point for the speedup column.

The sequential reference is skipped for the largest cells (it is the
thing being deprecated; its full-width maintenance makes it minutes of
wall clock at K=1000) unless it fits the time budget.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import compile_all, emit, timed
from repro.continuum import SimConfig, build_sim_fn

GRID_K = (30, 100, 300, 1000)
GRID_M = (10, 50)
SMOKE_GRID_K = (30, 100)
SMOKE_GRID_M = (10,)
# Cells that also run the deprecated sequential reference: small, mid
# and large K*M anchor the speedup trend without paying the reference's
# full-width maintenance (minutes of wall clock) on every cell.
SEQ_REF_CELLS = ((30, 10), (100, 50), (300, 50))


def _rand_rtt(K, M, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0.002, 0.04, (K, M)), jnp.float32)


def _lower_cell(K, M, horizon, fused):
    cfg = SimConfig(horizon=horizon)
    T = cfg.num_steps
    rtt = _rand_rtt(K, M)
    n_clients = jnp.full((T, K), 4, jnp.int32)
    active = jnp.ones((T, M), bool)
    key = jax.random.PRNGKey(7)
    run = jax.jit(build_sim_fn("qedgeproxy", cfg, K, M, fused=fused))
    lowered = run.lower(rtt, n_clients, active, key)
    return lowered, (rtt, n_clients, active, key), T


def bandit_scale():
    grid_k = SMOKE_GRID_K if common.SMOKE else GRID_K
    grid_m = SMOKE_GRID_M if common.SMOKE else GRID_M
    horizon = 2.0 if common.SMOKE else 10.0     # steady steps/s by ~100 steps

    cells = []          # (name, variant, lowered, args, T)
    for M in grid_m:
        for K in grid_k:
            cells.append((f"K{K}_M{M}", "fused",
                          *_lower_cell(K, M, horizon, fused=True)))
            if (K, M) in SEQ_REF_CELLS or common.SMOKE:
                cells.append((f"K{K}_M{M}", "sequential",
                              *_lower_cell(K, M, horizon, fused=False)))
    t0 = time.perf_counter()
    compiled = compile_all([c[2] for c in cells])
    compile_wall = time.perf_counter() - t0

    payload = {"compile_wall_s": compile_wall}
    for (name, variant, _, args, T), exe in zip(cells, compiled):
        _, us = timed(exe, *args)
        run_s = us / 1e6
        payload.setdefault(name, {})[variant] = {
            "steps": T, "run_s": run_s,
            "steps_per_s": T / run_s, "us_per_step": us / T}
    for name, cell in payload.items():
        if isinstance(cell, dict) and "sequential" in cell:
            cell["step_speedup"] = (cell["sequential"]["us_per_step"]
                                    / cell["fused"]["us_per_step"])
    biggest = f"K{grid_k[-1]}_M{grid_m[-1]}"
    derived = " ".join(
        f"{k}={v['fused']['steps_per_s']:.0f}steps/s"
        + (f"(x{v['step_speedup']:.1f})" if "step_speedup" in v else "")
        for k, v in payload.items() if isinstance(v, dict))
    emit("bandit_scale", payload[biggest]["fused"]["us_per_step"], derived,
         payload)
    return payload
