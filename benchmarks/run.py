"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (harness contract) and writes a
JSON artifact per benchmark into results/benchmarks/.

  PYTHONPATH=src python -m benchmarks.run [--only fig3_qos_success ...]
                                          [--smoke]

``--smoke`` shrinks every benchmark to a tiny horizon/fleet so the full
harness completes in seconds — a correctness gate to run alongside the
tier-1 tests, not a source of publishable numbers.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks import (bandit_scale, beyond, common, figures, footprint,
                        roofline_round, scenario_suite)

ALL = {
    # paper §VII figures
    "fig3_qos_success": figures.fig3_qos_success,
    "fig4_fairness": figures.fig4_fairness,
    "fig5_per_client": figures.fig5_per_client,
    "fig6_rolling_qos": figures.fig6_rolling_qos,
    "fig7_request_distribution": figures.fig7_request_distribution,
    "fig8_p90_latency": figures.fig8_p90_latency,
    "fig9_single_lb": figures.fig9_single_lb,
    "fig10_client_surge": figures.fig10_client_surge,
    "fig11_instance_removal": figures.fig11_instance_removal,
    # theory + footprint (paper §V-E, §VII-E)
    "regret_curve": figures.regret_curve,
    "footprint": footprint.footprint,
    "kde_hotspot": footprint.kde_hotspot,
    # scenario engine: the named non-stationarity library
    "scenario_suite": scenario_suite.scenario_suite,
    # multi-tenant continuum: S services sharing one fleet
    "multi_tenant": scenario_suite.multi_tenant,
    # harness + scale-out throughput (perf trajectory)
    "suite_build": common.suite_build,
    "bandit_scale": bandit_scale.bandit_scale,
    "roofline_round": roofline_round.roofline_round,
    # beyond-paper
    "beyond_paper_variants": beyond.beyond_paper_variants,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", choices=sorted(ALL), default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny horizon/fleet: seconds-level CI gate")
    args = ap.parse_args()
    if args.smoke:
        common.configure(smoke=True)
    names = args.only or list(ALL)
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        try:
            ALL[name]()
        except Exception as e:  # keep the harness running; report at end
            failures.append((name, repr(e)))
            print(f"{name},nan,ERROR {e!r}")
    if failures:
        sys.exit(f"{len(failures)} benchmark(s) failed: {failures}")


if __name__ == '__main__':
    main()
