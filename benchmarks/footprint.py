"""Resource footprint (paper §VII-E) + scale-out cost of the bandit.

The paper reports 0.13 cores / 60 MB per proxy at 40 req/s. Our
equivalents: µs per routed request (select+record) and µs per
maintenance step, at the paper's scale (K=30, M=10) and at datacenter
scale (K=1024 front-ends x M=64 replicas) — the O(K·M·R) vectorized
state is the 1000+-node story.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, timed
from repro.core import BanditParams, init_state, maintenance, record, select


def _bench_scale(K, M, ring=64):
    p = BanditParams()
    st = init_state(K, M, p, ring=ring, key=jax.random.PRNGKey(0))
    rtt = jnp.asarray(np.random.default_rng(0).uniform(0.002, 0.04, (K, M)),
                      jnp.float32)
    sel = jax.jit(select)
    rec = jax.jit(record, static_argnums=1)
    mnt = jax.jit(maintenance, static_argnums=1)

    # warm up + state with data
    choice, st, _ = sel(st)
    lat = rtt[jnp.arange(K), choice] + 0.01
    st = rec(st, p, choice, lat, jnp.float32(0.0), jnp.ones((K,), bool))
    st = mnt(st, p, rtt, jnp.float32(1.0))
    jax.block_until_ready(st.weights)

    def route_once(st, t):
        choice, st, _ = sel(st)
        lat = rtt[jnp.arange(K), choice] + 0.01
        return rec(st, p, choice, lat, t, jnp.ones((K,), bool))

    _, us_route = timed(
        lambda: jax.block_until_ready(route_once(st, jnp.float32(2.0))),
        repeat=20)
    _, us_maint = timed(
        lambda: jax.block_until_ready(mnt(st, p, rtt, jnp.float32(3.0))),
        repeat=20)
    state_mb = sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(st)) / 1e6
    return {"route_us": us_route, "maintenance_us": us_maint,
            "state_mb": state_mb,
            "route_us_per_player": us_route / K,
            "maintenance_us_per_player": us_maint / K}


def _live_bytes() -> int:
    return sum(x.nbytes for x in jax.live_arrays())


def _sim_memory():
    """Streaming vs full-trajectory device residency of one simulator
    run, measured with ``jax.live_arrays()`` — the memory claim of the
    streaming engine as a tracked artifact (like kde_hotspot.json).

    ``*_out_mb`` is what the run leaves resident (its outputs);
    ``*_live_peak_mb`` additionally includes everything else alive at
    measurement time. Trace-mode outputs grow O(T·K·M); streaming
    outputs are O(K·M) + O(T) scalars.
    """
    from repro.continuum import SimConfig, run_sim, run_sim_stream

    K, M = (30, 10) if common.SMOKE else (300, 50)
    cfg = SimConfig(horizon=12.0 if common.SMOKE else 60.0)
    rtt = jnp.asarray(
        np.random.default_rng(0).uniform(0.002, 0.04, (K, M)), jnp.float32)

    out = {"cell": f"K{K}_M{M}_T{cfg.num_steps}"}
    for mode, runner in (("trace", run_sim), ("stream", run_sim_stream)):
        base = _live_bytes()
        res = runner("qedgeproxy", rtt, cfg, jax.random.PRNGKey(0))
        jax.block_until_ready(jax.tree.leaves(res))
        out_bytes = sum(x.nbytes for x in jax.tree.leaves(res)
                        if hasattr(x, "nbytes"))
        out[mode] = {"out_mb": out_bytes / 1e6,
                     "live_delta_mb": (_live_bytes() - base) / 1e6}
        del res
    out["out_ratio"] = out["trace"]["out_mb"] / max(
        out["stream"]["out_mb"], 1e-9)
    return out


def footprint():
    payload = {"paper_scale_K30_M10": _bench_scale(30, 10),
               "sim_memory": _sim_memory()}
    if not common.SMOKE:
        payload["datacenter_scale_K1024_M64"] = _bench_scale(1024, 64)
    derived = (
        f"K30xM10:route={payload['paper_scale_K30_M10']['route_us']:.0f}us,"
        f"maint={payload['paper_scale_K30_M10']['maintenance_us']:.0f}us")
    if "datacenter_scale_K1024_M64" in payload:
        derived += (
            f";K1024xM64:maint="
            f"{payload['datacenter_scale_K1024_M64']['maintenance_us']:.0f}us,"
            f"state={payload['datacenter_scale_K1024_M64']['state_mb']:.0f}MB")
    mem = payload["sim_memory"]
    derived += (f";sim_out:trace={mem['trace']['out_mb']:.0f}MB,"
                f"stream={mem['stream']['out_mb']:.2f}MB"
                f"(x{mem['out_ratio']:.0f})")
    emit("footprint", payload["paper_scale_K30_M10"]["route_us"], derived,
         payload)
    return payload


def kde_hotspot():
    """µs per fused KDE evaluation (the Alg-1 line-12 hot spot)."""
    from repro.kernels import ref
    from repro.kernels.kde import kde_success_prob
    rng = np.random.default_rng(0)
    out = {}
    shapes = ((300, 64),) if common.SMOKE else ((300, 64), (65536, 64))
    for rows, R in shapes:
        lat = jnp.asarray(rng.exponential(0.03, (rows, R)), jnp.float32)
        mask = jnp.asarray(rng.random((rows, R)) < 0.7)
        bw = jnp.asarray(rng.uniform(1e-3, 1e-2, rows), jnp.float32)
        f_ref = jax.jit(lambda l, m, b: ref.kde_success_prob(l, m, 0.08, b))
        jax.block_until_ready(f_ref(lat, mask, bw))
        _, us = timed(lambda: jax.block_until_ready(f_ref(lat, mask, bw)),
                      repeat=10)
        out[f"rows{rows}"] = {"xla_us": us, "us_per_row": us / rows}
    derived = " ".join(f"{k[4:]}rows={v['xla_us']:.0f}us"
                       for k, v in out.items())
    emit("kde_hotspot", out["rows300"]["xla_us"], derived, out)
    return out
