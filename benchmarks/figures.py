"""One benchmark per paper figure (Figs 3-11). Each returns a payload
dict and emits a CSV line; see EXPERIMENTS.md §Paper-validation for the
side-by-side against the paper's reported numbers."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (CFG, SCENARIOS, STRATEGIES, WARM, emit,
                               get_suite, timed)
from repro.continuum import (client_qos_satisfaction, cumulative_regret,
                             jain_fairness, p90_proc_latency,
                             per_client_success, per_lb_request_distribution,
                             request_rate_per_instance, rolling_qos)


def fig3_qos_success():
    suite = get_suite()

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            vals = [client_qos_satisfaction(suite[(s, label)], CFG.rho, WARM)
                    for s in SCENARIOS]
            out[label] = {"per_scenario": vals,
                          "mean": float(np.mean(vals)),
                          "std": float(np.std(vals))}
        return out

    payload, us = timed(compute)
    derived = " ".join(f"{k}={v['mean']:.1f}%" for k, v in payload.items())
    emit("fig3_qos_success", us, derived, payload)
    return payload


def fig4_fairness():
    suite = get_suite()

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            vals = [jain_fairness(suite[(s, label)], warmup_steps=WARM)
                    for s in SCENARIOS]
            out[label] = {"per_scenario": vals,
                          "mean": float(np.mean(vals))}
        return out

    payload, us = timed(compute)
    derived = " ".join(f"{k}={v['mean']:.3f}" for k, v in payload.items())
    emit("fig4_fairness", us, derived, payload)
    return payload


def fig5_per_client():
    suite = get_suite()

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            ratio, present = per_client_success(suite[(1, label)], WARM)
            r = np.sort(ratio[present])
            out[label] = {
                "min": float(r[0]), "p25": float(np.percentile(r, 25)),
                "median": float(np.median(r)),
                "clients_below_target": int((r < CFG.rho).sum()),
                "n_clients": int(r.size),
            }
        return out

    payload, us = timed(compute)
    derived = " ".join(f"{k}:below={v['clients_below_target']}/{v['n_clients']}"
                       for k, v in payload.items())
    emit("fig5_per_client", us, derived, payload)
    return payload


def fig6_rolling_qos():
    suite = get_suite()
    win = int(CFG.window / CFG.dt)

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            roll = rolling_qos(suite[(1, label)], win)
            steady = roll[WARM:].mean()
            # convergence: first time rolling QoS reaches 95% of steady
            thresh = 0.95 * steady
            idx = np.argmax(roll >= thresh)
            out[label] = {"steady": float(steady),
                          "convergence_s": float(idx * CFG.dt),
                          "curve_30s_samples": roll[::50][:40].tolist()}
        return out

    payload, us = timed(compute)
    derived = " ".join(
        f"{k}:steady={v['steady']:.3f}@{v['convergence_s']:.0f}s"
        for k, v in payload.items())
    emit("fig6_rolling_qos", us, derived, payload)
    return payload


def fig7_request_distribution():
    suite = get_suite()

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            rate = request_rate_per_instance(suite[(1, label)], CFG.dt, WARM)
            out[label] = {"per_instance_req_s": rate.tolist(),
                          "max": float(rate.max()), "min": float(rate.min())}
        return out

    payload, us = timed(compute)
    derived = " ".join(f"{k}:max={v['max']:.0f}r/s" for k, v in payload.items())
    emit("fig7_request_distribution", us, derived, payload)
    return payload


def fig8_p90_latency():
    suite = get_suite()

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            p90 = p90_proc_latency(suite[(1, label)], WARM)
            out[label] = {"per_instance_ms": (p90 * 1e3).tolist(),
                          "max_ms": float(p90.max() * 1e3)}
        return out

    payload, us = timed(compute)
    derived = " ".join(f"{k}:maxp90={v['max_ms']:.0f}ms"
                       for k, v in payload.items())
    emit("fig8_p90_latency", us, derived, payload)
    return payload


def fig9_single_lb():
    suite = get_suite()
    topo = suite[("topo", 1)]
    inst_nodes = set(np.asarray(topo.instance_nodes).tolist())
    lb_local = next(i for i in range(30) if i in inst_nodes)
    lb_remote = next(i for i in range(30) if i not in inst_nodes)

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            o = suite[(1, label)]
            out[label] = {
                "lb_with_local": per_lb_request_distribution(
                    o, lb_local, WARM).tolist(),
                "lb_without_local": per_lb_request_distribution(
                    o, lb_remote, WARM).tolist(),
            }
            for key in ("lb_with_local", "lb_without_local"):
                p = np.asarray(out[label][key])
                nz = p[p > 0]
                out[label][key + "_entropy"] = float(
                    -(nz * np.log(nz)).sum())
        return out

    payload, us = timed(compute)
    derived = " ".join(
        f"{k}:H_local={v['lb_with_local_entropy']:.2f}"
        f"/H_remote={v['lb_without_local_entropy']:.2f}"
        for k, v in payload.items())
    emit("fig9_single_lb", us, derived, payload)
    return payload


def _event_run(event: str):
    import jax
    import jax.numpy as jnp
    from repro.continuum import make_topology, run_sim
    topo = get_suite()[("topo", 1)]
    rtt = topo.lb_instance_rtt()
    T = CFG.num_steps
    win = int(CFG.window / CFG.dt)
    out = {}
    for label, kw in STRATEGIES:
        from benchmarks.common import strategy_name
        if event == "surge":
            n_clients = np.full((T, 30), 2, np.int32)
            rng = np.random.default_rng(0)
            n_clients[T // 2:, rng.choice(30, 15, replace=False)] += 2
            o = run_sim(strategy_name(label), rtt, CFG,
                        jax.random.PRNGKey(11),
                        n_clients=jnp.asarray(n_clients), **kw)
        else:
            active = np.ones((T, 10), bool)
            active[T // 2:, 9] = False
            o = run_sim(strategy_name(label), rtt, CFG,
                        jax.random.PRNGKey(11),
                        active=jnp.asarray(active), **kw)
        roll = rolling_qos(o, win)
        pre = roll[T // 2 - win:T // 2].mean()
        dip = roll[T // 2:T // 2 + 3 * win].min()
        tail = roll[-int(20 / CFG.dt):].mean()
        # recovery: first time after the event at >= 0.95*tail
        post = roll[T // 2:]
        rec_idx = int(np.argmax(post >= 0.95 * tail))
        out[label] = {"pre": float(pre), "dip": float(dip),
                      "post_steady": float(tail),
                      "recovery_s": rec_idx * CFG.dt}
    return out


def fig10_client_surge():
    payload, us = timed(_event_run, "surge")
    derived = " ".join(
        f"{k}:post={v['post_steady']:.2f}@{v['recovery_s']:.0f}s"
        for k, v in payload.items())
    emit("fig10_client_surge", us, derived, payload)
    return payload


def fig11_instance_removal():
    payload, us = timed(_event_run, "removal")
    derived = " ".join(
        f"{k}:post={v['post_steady']:.2f}@{v['recovery_s']:.0f}s"
        for k, v in payload.items())
    emit("fig11_instance_removal", us, derived, payload)
    return payload


def regret_curve():
    """§V-E empirics: cumulative regret growth exponent (<1 = sublinear)."""
    suite = get_suite()

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            reg = cumulative_regret(suite[(1, label)])
            t = np.arange(1, len(reg) + 1)
            sl = slice(len(reg) // 4, None)
            slope = np.polyfit(np.log(t[sl]), np.log(reg[sl] + 1e-9), 1)[0]
            out[label] = {"total_regret": float(reg[-1]),
                          "late_growth_exponent": float(slope)}
        return out

    payload, us = timed(compute)
    derived = " ".join(
        f"{k}:R(T)={v['total_regret']:.0f},exp={v['late_growth_exponent']:.2f}"
        for k, v in payload.items())
    emit("regret_curve", us, derived, payload)
    return payload
