"""One benchmark per paper figure (Figs 3-11). Each returns a payload
dict and emits a CSV line; see EXPERIMENTS.md §Paper-validation for the
side-by-side against the paper's reported numbers.

All figures consume the suite's streaming outputs (metric accumulators
+ per-step scalar series) — no figure needs the full per-step
trajectories, so the suite never materializes them. `trace=True` runs
remain available through `run_sim` for ad-hoc inspection.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import STRATEGIES, emit, get_suite, timed
from repro.continuum import (client_qos_satisfaction_stream,
                             cumulative_regret_series, event_recovery,
                             jain_fairness_stream, per_client_success_stream,
                             per_lb_request_distribution_stream,
                             proc_latency_quantile_stream,
                             request_rate_per_instance_stream,
                             rolling_qos_series)


def fig3_qos_success():
    suite = get_suite()

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            vals = [client_qos_satisfaction_stream(
                        suite[(s, label)].acc, common.CFG.rho)
                    for s in common.SCENARIOS]
            out[label] = {"per_scenario": vals,
                          "mean": float(np.mean(vals)),
                          "std": float(np.std(vals))}
        return out

    payload, us = timed(compute)
    derived = " ".join(f"{k}={v['mean']:.1f}%" for k, v in payload.items())
    emit("fig3_qos_success", us, derived, payload)
    return payload


def fig4_fairness():
    suite = get_suite()

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            vals = [jain_fairness_stream(suite[(s, label)].acc)
                    for s in common.SCENARIOS]
            out[label] = {"per_scenario": vals,
                          "mean": float(np.mean(vals))}
        return out

    payload, us = timed(compute)
    derived = " ".join(f"{k}={v['mean']:.3f}" for k, v in payload.items())
    emit("fig4_fairness", us, derived, payload)
    return payload


def fig5_per_client():
    suite = get_suite()

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            ratio, present = per_client_success_stream(suite[(1, label)].acc)
            r = np.sort(ratio[present])
            out[label] = {
                "min": float(r[0]), "p25": float(np.percentile(r, 25)),
                "median": float(np.median(r)),
                "clients_below_target": int((r < common.CFG.rho).sum()),
                "n_clients": int(r.size),
            }
        return out

    payload, us = timed(compute)
    derived = " ".join(f"{k}:below={v['clients_below_target']}/{v['n_clients']}"
                       for k, v in payload.items())
    emit("fig5_per_client", us, derived, payload)
    return payload


def fig6_rolling_qos():
    suite = get_suite()
    win = int(common.CFG.window / common.CFG.dt)

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            roll = rolling_qos_series(suite[(1, label)].series, win)
            steady = roll[common.WARM:].mean()
            # convergence: first time rolling QoS reaches 95% of steady
            thresh = 0.95 * steady
            idx = np.argmax(roll >= thresh)
            out[label] = {"steady": float(steady),
                          "convergence_s": float(idx * common.CFG.dt),
                          "curve_30s_samples": roll[::50][:40].tolist()}
        return out

    payload, us = timed(compute)
    derived = " ".join(
        f"{k}:steady={v['steady']:.3f}@{v['convergence_s']:.0f}s"
        for k, v in payload.items())
    emit("fig6_rolling_qos", us, derived, payload)
    return payload


def fig7_request_distribution():
    suite = get_suite()

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            rate = request_rate_per_instance_stream(
                suite[(1, label)].acc, common.CFG.dt)
            out[label] = {"per_instance_req_s": rate.tolist(),
                          "max": float(rate.max()), "min": float(rate.min())}
        return out

    payload, us = timed(compute)
    derived = " ".join(f"{k}:max={v['max']:.0f}r/s" for k, v in payload.items())
    emit("fig7_request_distribution", us, derived, payload)
    return payload


def fig8_p90_latency():
    suite = get_suite()

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            p90 = proc_latency_quantile_stream(suite[(1, label)].acc, 0.9)
            out[label] = {"per_instance_ms": (p90 * 1e3).tolist(),
                          "max_ms": float(p90.max() * 1e3)}
        return out

    payload, us = timed(compute)
    derived = " ".join(f"{k}:maxp90={v['max_ms']:.0f}ms"
                       for k, v in payload.items())
    emit("fig8_p90_latency", us, derived, payload)
    return payload


def fig9_single_lb():
    suite = get_suite()
    topo = suite[("topo", 1)]
    inst_nodes = set(np.asarray(topo.instance_nodes).tolist())
    lb_local = next(i for i in range(30) if i in inst_nodes)
    lb_remote = next(i for i in range(30) if i not in inst_nodes)

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            acc = suite[(1, label)].acc
            out[label] = {
                "lb_with_local": per_lb_request_distribution_stream(
                    acc, lb_local).tolist(),
                "lb_without_local": per_lb_request_distribution_stream(
                    acc, lb_remote).tolist(),
            }
            for key in ("lb_with_local", "lb_without_local"):
                p = np.asarray(out[label][key])
                nz = p[p > 0]
                out[label][key + "_entropy"] = float(
                    -(nz * np.log(nz)).sum())
        return out

    payload, us = timed(compute)
    derived = " ".join(
        f"{k}:H_local={v['lb_with_local_entropy']:.2f}"
        f"/H_remote={v['lb_without_local_entropy']:.2f}"
        for k, v in payload.items())
    emit("fig9_single_lb", us, derived, payload)
    return payload


_event_cache = common.register_cache({})

# The §VII-C surge subset: the pre-DSL harness drew it as
# default_rng(0).choice(30, 15, replace=False); frozen as data so the
# scenario spec (not a numpy stream) is the source of truth.
# tests/test_scenarios.py locks the compiled drivers — and the sim
# results — bit-identical to the hand-rolled legacy arrays.
SURGE_LBS = (0, 1, 4, 5, 6, 9, 10, 13, 14, 16, 17, 20, 22, 24, 29)


def legacy_event_scenarios(cfg, K: int = 30, M: int = 10):
    """The two legacy events (Figs 10/11) as scenario specs: a +2-client
    step surge on half the LBs, and the last instance going dark —
    both at mid-horizon."""
    from repro.continuum import InstanceKill, LoadSurge, Scenario
    half = (cfg.num_steps // 2) * cfg.dt
    surge = Scenario(
        "legacy_surge",
        (LoadSurge(start=half, extra=2,
                   lbs=tuple(lb for lb in SURGE_LBS if lb < K)),),
        n_nodes=K, n_instances=M, base_clients=2)
    removal = Scenario(
        "legacy_removal",
        (InstanceKill(start=half, instances=(M - 1,)),),
        n_nodes=K, n_instances=M, base_clients=4)
    return surge, removal


def _event_suite():
    """{(event, label): StreamOutputs} for the surge/removal events.

    Both events are scenario-DSL specs compiled to driver batches
    (`legacy_event_scenarios`); they share every static shape, so each
    strategy compiles ONE vmapped program with the event axis batched
    instead of one program per (event, strategy) pair. The figures only
    need the rolling-QoS series, so the events stream too.
    """
    if _event_cache:
        return _event_cache
    import jax
    from benchmarks.common import strategy_name
    from repro.continuum import build_sim_fn, compile_scenario, stack_drivers
    topo = get_suite()[("topo", 1)]
    rtt = topo.lb_instance_rtt()

    drivers = stack_drivers(
        [compile_scenario(s, common.CFG, jax.random.PRNGKey(0))
         for s in legacy_event_scenarios(common.CFG)])
    key = jax.random.PRNGKey(11)

    # smoke: per-strategy compiles dominate; two strategies gate the path
    strategies = STRATEGIES[:2] if common.SMOKE else STRATEGIES
    lowered = []
    for label, kw in strategies:
        run = build_sim_fn(strategy_name(label), common.CFG, 30, 10,
                           trace=False, warmup_steps=common.WARM, **kw)
        batched = jax.jit(jax.vmap(run, in_axes=(None, 0, None)))
        lowered.append(batched.lower(rtt, drivers, key))
    for (label, kw), exe in zip(strategies,
                                common.compile_all(lowered)):
        outs = exe(rtt, drivers, key)
        for i, event in enumerate(("surge", "removal")):
            _event_cache[(event, label)] = jax.tree.map(
                lambda x: x[i], outs)
    return _event_cache


def _event_run(event: str):
    suite = _event_suite()
    T = common.CFG.num_steps
    win = int(common.CFG.window / common.CFG.dt)
    out = {}
    for (ev, label), o in suite.items():
        if ev != event:
            continue
        roll = rolling_qos_series(o.series, win)
        pre = roll[T // 2 - win:T // 2].mean()
        dip = roll[T // 2:T // 2 + 3 * win].min()
        # never reach back past the event (smoke horizons are short)
        tail_steps = min(int(20 / common.CFG.dt), T - T // 2)
        tail = roll[-tail_steps:].mean()
        # recovery: first time after the event at >= 0.95*tail
        post = roll[T // 2:]
        rec_idx = int(np.argmax(post >= 0.95 * tail))
        out[label] = {"pre": float(pre), "dip": float(dip),
                      "post_steady": float(tail),
                      "recovery_s": rec_idx * common.CFG.dt}
        # the scenario engine's event-relative windows give the same
        # story straight from the accumulator (no series scan): one
        # mark per legacy event, bucketed at cfg.ev_bucket seconds
        rec = event_recovery(o.acc, common.CFG.ev_bucket)
        if rec:
            out[label]["acc_window"] = rec[0]
    return out


def fig10_client_surge():
    payload, us = timed(_event_run, "surge")
    derived = " ".join(
        f"{k}:post={v['post_steady']:.2f}@{v['recovery_s']:.0f}s"
        for k, v in payload.items())
    emit("fig10_client_surge", us, derived, payload)
    return payload


def fig11_instance_removal():
    payload, us = timed(_event_run, "removal")
    derived = " ".join(
        f"{k}:post={v['post_steady']:.2f}@{v['recovery_s']:.0f}s"
        for k, v in payload.items())
    emit("fig11_instance_removal", us, derived, payload)
    return payload


def regret_curve():
    """§V-E empirics: cumulative regret growth exponent (<1 = sublinear)."""
    suite = get_suite()

    def compute():
        out = {}
        for label, _ in STRATEGIES:
            reg = cumulative_regret_series(suite[(1, label)].series)
            t = np.arange(1, len(reg) + 1)
            sl = slice(len(reg) // 4, None)
            slope = np.polyfit(np.log(t[sl]), np.log(reg[sl] + 1e-9), 1)[0]
            out[label] = {"total_regret": float(reg[-1]),
                          "late_growth_exponent": float(slope)}
        return out

    payload, us = timed(compute)
    derived = " ".join(
        f"{k}:R(T)={v['total_regret']:.0f},exp={v['late_growth_exponent']:.2f}"
        for k, v in payload.items())
    emit("regret_curve", us, derived, payload)
    return payload
