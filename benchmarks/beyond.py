"""Beyond-paper ablations: operating-envelope stress + algorithm variants.

The paper-faithful weight update oscillates when utilization approaches
capacity (synchronized herd -> overload -> flee; see EXPERIMENTS.md
§Perf-algorithms). Variants benchmarked at increasing load:

  paper     : Alg 1 verbatim
  ema       : EMA-damped weight updates (weight_ema=0.7)
  ucb       : + exploration bonus on the KDE estimate
  empirical : windowed success *fraction* instead of KDE (prior work [2])
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.continuum import (SimConfig, client_qos_satisfaction,
                             make_topology, run_sim)
from repro.core import BanditParams

VARIANTS = {
    "paper": {},
    "ema": dict(weight_ema=0.7),
    "ucb": dict(ucb_coef=0.05),
    "ema+ucb": dict(weight_ema=0.7, ucb_coef=0.05),
    "empirical": dict(kde_mode=1),
}
SERVICE_TIMES = (0.0055, 0.006, 0.0065)     # 66% / 72% / 78% utilization


def beyond_paper_variants():
    def compute():
        out = {}
        topo = make_topology(jax.random.PRNGKey(5), 30, 10)  # collapse-prone
        rtt = topo.lb_instance_rtt()
        for st_ in SERVICE_TIMES:
            cfg = SimConfig(horizon=180.0, service_time=st_)
            warm = int(60 / cfg.dt)
            util = 1200 * st_ / 10
            row = {}
            for name, kw in VARIANTS.items():
                params = BanditParams(tau=cfg.tau, rho=cfg.rho,
                                      window=cfg.window, **kw)
                o = run_sim("qedgeproxy", rtt, cfg, jax.random.PRNGKey(105),
                            params=params)
                row[name] = client_qos_satisfaction(o, cfg.rho, warm)
            out[f"util_{util:.0%}"] = row
        return out

    payload, us = timed(compute)
    derived = " | ".join(
        f"{k}: " + " ".join(f"{n}={v:.0f}%" for n, v in row.items())
        for k, row in payload.items())
    emit("beyond_paper_variants", us, derived, payload)
    return payload
