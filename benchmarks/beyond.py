"""Beyond-paper ablations: operating-envelope stress + algorithm variants.

The paper-faithful weight update oscillates when utilization approaches
capacity (synchronized herd -> overload -> flee; see EXPERIMENTS.md
§Perf-algorithms). Variants benchmarked at increasing load:

  paper     : Alg 1 verbatim
  ema       : EMA-damped weight updates (weight_ema=0.7)
  ucb       : + exploration bonus on the KDE estimate
  empirical : windowed success *fraction* instead of KDE (prior work [2])
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import compile_all, emit, timed
from repro.continuum import (SimConfig, build_sim_fn,
                             client_qos_satisfaction_stream, make_topology,
                             neutral_drivers)
from repro.core import BanditParams

VARIANTS = {
    "paper": {},
    "ema": dict(weight_ema=0.7),
    "ucb": dict(ucb_coef=0.05),
    "ema+ucb": dict(weight_ema=0.7, ucb_coef=0.05),
    "empirical": dict(kde_mode=1),
}
SERVICE_TIMES = (0.0055, 0.006, 0.0065)     # 66% / 72% / 78% utilization


def beyond_paper_variants():
    horizon, warm_s = (24.0, 8.0) if common.SMOKE else (180.0, 60.0)
    service_times = SERVICE_TIMES[:1] if common.SMOKE else SERVICE_TIMES
    variants = ({k: VARIANTS[k] for k in ("paper", "ema")}
                if common.SMOKE else VARIANTS)

    def compute():
        topo = make_topology(jax.random.PRNGKey(5), 30, 10)  # collapse-prone
        rtt = topo.lb_instance_rtt()
        cfg = SimConfig(horizon=horizon)
        warm = int(warm_s / cfg.dt)
        drv = neutral_drivers(cfg, 30, 10)
        key = jax.random.PRNGKey(105)
        st_axis = jnp.asarray(service_times, jnp.float32)
        # one compiled program per variant (via the shared — serial, see
        # common.compile_all — choke point); the utilization axis is a
        # traced service_time swept by vmap (3 lanes; it overrides the
        # drivers' s_m row), not 3 programs
        out = {f"util_{1200 * st_ / 10:.0%}": {} for st_ in service_times}
        lowered = []
        for name, kw in variants.items():
            params = BanditParams(tau=cfg.tau, rho=cfg.rho,
                                  window=cfg.window, **kw)
            run = build_sim_fn("qedgeproxy", cfg, 30, 10, trace=False,
                               warmup_steps=warm, params=params)
            batched = jax.jit(jax.vmap(
                lambda s: run(rtt, drv, key, service_time=s)))
            lowered.append(batched.lower(st_axis))
        for name, exe in zip(variants, compile_all(lowered)):
            outs = exe(st_axis)
            for i, st_ in enumerate(service_times):
                o = jax.tree.map(lambda x: x[i], outs)
                out[f"util_{1200 * st_ / 10:.0%}"][name] = \
                    client_qos_satisfaction_stream(o.acc, cfg.rho)
        return out

    payload, us = timed(compute)
    derived = " | ".join(
        f"{k}: " + " ".join(f"{n}={v:.0f}%" for n, v in row.items())
        for k, row in payload.items())
    emit("beyond_paper_variants", us, derived, payload)
    return payload
