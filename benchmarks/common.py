"""Shared benchmark harness: run the paper's evaluation suite once
(5 scenarios x 4 strategies, §VII-A6) and hand trajectories to the
per-figure benches."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.continuum import SimConfig, make_topology, run_sim

SCENARIOS = (1, 2, 3, 4, 5)
STRATEGIES = (
    ("qedgeproxy", {}),
    ("proxy_mity_1.0", dict(alpha=1.0)),
    ("proxy_mity_0.9", dict(alpha=0.9)),
    ("dec_sarsa", {}),
)
CFG = SimConfig(horizon=180.0)
WARM = int(60 / CFG.dt)
RESULTS_DIR = os.environ.get("REPRO_BENCH_OUT", "results/benchmarks")

_cache = {}


def strategy_name(label: str) -> str:
    return "proxy_mity" if label.startswith("proxy_mity") else label


def get_suite():
    """{(scenario, label): SimOutputs} for the full evaluation grid."""
    if _cache:
        return _cache
    for seed in SCENARIOS:
        topo = make_topology(jax.random.PRNGKey(seed), 30, 10)
        rtt = topo.lb_instance_rtt()
        for label, kw in STRATEGIES:
            outs = run_sim(strategy_name(label), rtt, CFG,
                           jax.random.PRNGKey(100 + seed), **kw)
            jax.block_until_ready(outs.rewards)
            _cache[(seed, label)] = outs
        _cache[("topo", seed)] = topo
    return _cache


def emit(name: str, us_per_call: float, derived, payload=None):
    """CSV line per the harness contract + JSON artifact."""
    print(f"{name},{us_per_call:.1f},{derived}")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    if payload is not None:
        with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
            json.dump(payload, f, indent=1, default=float)


def timed(fn, *args, repeat=1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6
