"""Shared benchmark harness: run the paper's evaluation suite once
(5 scenarios x 4 strategies, §VII-A6) and hand results to the
per-figure benches.

The scenario axis is vmapped AND device-sharded: each strategy's 5
seeds compile and run as ONE program (`build_sim_grid_fn`), whose
scenario lanes `shard_map` across every device on the grid mesh — on
the usual single-device container that degrades to the plain vmapped
`run_sim_batch` program. Since the scenario engine, every lane is a
compiled `Drivers` pytree: the paper suite runs the `baseline`
scenario per seed (bit-identical to the old constant fills), and the
dynamic library runs through the same grid in
benchmarks/scenario_suite.py. Compile time is measured separately from run
time via AOT lowering (the old harness conflated them — and stopped
the clock before the async dispatch had even executed).

The suite runs the simulator in **streaming mode** (`trace=False`):
each cell yields a `StreamOutputs` (O(K·M) metric accumulators + O(T)
scalar series) instead of full (T, K, C)/(T, K, M) trajectories —
every Fig 3-11 statistic is computed from those (see
repro/continuum/metrics.py), so suite memory no longer scales with the
horizon, and per-device memory no longer scales with the grid.

To exercise the sharded path on CPU (CI or this container):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m benchmarks.run --only suite_build
"""
from __future__ import annotations

import contextlib
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.continuum import (Scenario, SimConfig, build_sim_grid_fn,
                             compile_scenario, make_topology, stack_drivers)
from repro.launch.mesh import make_grid_mesh
from repro.obs import provenance as obs_provenance

SCENARIOS = (1, 2, 3, 4, 5)
STRATEGIES = (
    ("qedgeproxy", {}),
    ("proxy_mity_1.0", dict(alpha=1.0)),
    ("proxy_mity_0.9", dict(alpha=0.9)),
    ("dec_sarsa", {}),
)
N_LBS, N_INSTANCES = 30, 10
CFG = SimConfig(horizon=180.0)
WARM = int(60 / CFG.dt)
SMOKE = False
RESULTS_DIR = os.environ.get("REPRO_BENCH_OUT", "results/benchmarks")

_cache = {}
SUITE_TIMINGS = {}
_REGISTERED_CACHES = [_cache, SUITE_TIMINGS]


def register_cache(d: dict) -> dict:
    """Register a module-level result cache keyed on the suite config;
    ``configure()`` clears every registered cache so stale trajectories
    can't be sliced with the new horizon (e.g. figures._event_cache)."""
    _REGISTERED_CACHES.append(d)
    return d


def configure(smoke: bool = False) -> None:
    """Switch the whole suite to a tiny grid (--smoke: a seconds-level
    correctness gate). Must run before the first get_suite() call."""
    global SMOKE, CFG, WARM, SCENARIOS
    SMOKE = smoke
    if smoke:
        CFG = SimConfig(horizon=24.0)
        WARM = int(8 / CFG.dt)
        SCENARIOS = (1, 2)
    else:
        CFG = SimConfig(horizon=180.0)
        WARM = int(60 / CFG.dt)
        SCENARIOS = (1, 2, 3, 4, 5)
    for d in _REGISTERED_CACHES:
        d.clear()


def strategy_name(label: str) -> str:
    return "proxy_mity" if label.startswith("proxy_mity") else label


def compile_all(lowered):
    """Compile a list of AOT-lowered programs, in input order.

    Central choke point for the grid's compile phase: every harness
    lowers its programs first (cheap tracing) and compiles here, so
    compile wall-clock is measured apart from run time. Thread-pooled
    compilation was measured SLOWER than serial on XLA:CPU (the
    compile path holds the GIL and LLVM already uses internal
    parallelism), so this stays serial on purpose.
    """
    return [l.compile() for l in lowered]


def executable_memory(exe) -> dict:
    """Per-device peak memory of a compiled executable, from XLA's
    static ``memory_analysis`` (temp + output buffers — the program's
    working set on EACH device of an SPMD grid). Deterministic, no
    execution needed; empty dict on backends without the API."""
    try:
        ma = exe.memory_analysis()
        return {"per_device_peak_mb": (ma.temp_size_in_bytes
                                       + ma.output_size_in_bytes) / 1e6,
                "temp_mb": ma.temp_size_in_bytes / 1e6,
                "output_mb": ma.output_size_in_bytes / 1e6}
    except Exception:       # pragma: no cover - backend without the API
        return {}


def get_suite():
    """{(scenario, label): StreamOutputs} for the full evaluation grid.

    One sharded-grid program per strategy covers all scenarios
    (scenario lanes split across the grid mesh; single device = the
    plain vmap); per-strategy compile/run seconds, device count, grid
    steps/s and per-device peak memory land in SUITE_TIMINGS (emitted
    by the ``suite_build`` benchmark row). Streaming mode: figures read
    the per-cell ``.acc`` / ``.series``, never a trajectory.
    """
    if _cache:
        return _cache
    topos = {s: make_topology(jax.random.PRNGKey(s), N_LBS, N_INSTANCES)
             for s in SCENARIOS}
    rtts = jnp.stack([topos[s].lb_instance_rtt() for s in SCENARIOS])
    keys = jnp.stack([jax.random.PRNGKey(100 + s) for s in SCENARIOS])
    # The paper's evaluation grid is stationary: every seed lane runs
    # the compiled `baseline` scenario (constant clients, all instances
    # up, neutral modulation — bit-for-bit the old constant fills).
    # Dynamic lanes go through the same machinery in scenario_suite.
    T = CFG.num_steps
    base = Scenario("baseline", n_nodes=N_LBS, n_instances=N_INSTANCES)
    drivers = stack_drivers(
        [compile_scenario(base, CFG, jax.random.PRNGKey(s))
         for s in SCENARIOS])
    mesh = make_grid_mesh()

    t0 = time.perf_counter()
    lowered = []
    for label, kw in STRATEGIES:
        run_grid, mesh = build_sim_grid_fn(
            strategy_name(label), CFG, N_LBS, N_INSTANCES, mesh=mesh,
            warmup_steps=WARM, **kw)
        lowered.append(jax.jit(run_grid).lower(rtts, drivers, keys))
    compiled = compile_all(lowered)
    t_compile = time.perf_counter() - t0

    SUITE_TIMINGS["compile_wall_s"] = t_compile      # all 4 programs
    SUITE_TIMINGS["devices"] = int(mesh.devices.size)
    for (label, kw), exe in zip(STRATEGIES, compiled):
        t0 = time.perf_counter()
        with maybe_profile(f"suite_run_{label}"):
            outs = exe(rtts, drivers, keys)
            jax.block_until_ready(outs)
        t_run = time.perf_counter() - t0
        SUITE_TIMINGS[label] = {"run_s": t_run,
                                "scenarios": len(SCENARIOS),
                                "grid_steps_per_s": len(SCENARIOS) * T / t_run,
                                **executable_memory(exe)}
        for i, seed in enumerate(SCENARIOS):
            _cache[(seed, label)] = jax.tree.map(lambda x: x[i], outs)
    for seed in SCENARIOS:
        _cache[("topo", seed)] = topos[seed]
    return _cache


def suite_build():
    """Benchmark row for the suite itself: compile vs run seconds,
    device count, grid steps/s and per-device peak memory per strategy
    (the old harness timed neither compile nor run faithfully)."""
    get_suite()
    per_label = {k: v for k, v in SUITE_TIMINGS.items() if isinstance(v, dict)}
    total_run = sum(v["run_s"] for v in per_label.values())
    derived = (f"compile_wall={SUITE_TIMINGS['compile_wall_s']:.1f}s " +
               " ".join(f"{k}:run={v['run_s']:.1f}s"
                        for k, v in per_label.items()))
    emit("suite_build", total_run * 1e6, derived, SUITE_TIMINGS)
    return SUITE_TIMINGS


def emit(name: str, us_per_call: float, derived, payload=None):
    """CSV line per the harness contract + JSON artifact.

    Every dict payload is stamped with a ``provenance`` block (schema
    version, git sha, jax version, backend, device count, hash of the
    suite's ``SimConfig``) via ``repro.obs.provenance`` — additive keys,
    so artifact readers that index the payload shape are untouched.
    ``repro.obs.provenance.validate_all(RESULTS_DIR)`` round-trips the
    directory (the obs CI lane runs it)."""
    print(f"{name},{us_per_call:.1f},{derived}")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    if payload is not None:
        if isinstance(payload, dict):
            obs_provenance.stamp(payload, CFG,
                                 extra={"benchmark": name, "smoke": SMOKE})
        with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
            json.dump(payload, f, indent=1, default=float)


@contextlib.contextmanager
def maybe_profile(name: str):
    """Optional ``jax.profiler`` capture around a benchmark phase.

    Off unless ``REPRO_PROFILE_DIR`` is set; then each wrapped phase
    writes a TensorBoard-loadable trace under
    ``$REPRO_PROFILE_DIR/<name>/``. Keeping the hook here (the one
    place every benchmark already imports) means any cell can be
    profiled without touching benchmark code."""
    prof_dir = os.environ.get("REPRO_PROFILE_DIR", "")
    if not prof_dir:
        yield
        return
    out = os.path.join(prof_dir, name)
    os.makedirs(out, exist_ok=True)
    with jax.profiler.trace(out):
        yield


def timed(fn, *args, repeat=1, **kw):
    """Wall time per call in µs. Blocks on the result inside the clock:
    JAX dispatch is async, so returning at dispatch time (the old
    behaviour) measured the enqueue, not the execution."""
    out = None
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6
