"""Roofline row for the fused simulator round (EXPERIMENTS.md §Roofline).

Stands the previously dry-run-only ``repro.roofline`` package up
against a *measured* program: the fused streaming cell (K=1000 × M=50
in full mode) is AOT-compiled, its per-step FLOPs / HBM bytes come from
XLA's ``cost_analysis`` and its collective traffic from the optimized
HLO (``roofline.collective_bytes``), and the same executable is then
run so the artifact carries achieved FLOP/s and bytes/s next to the
model's compute/memory/collective bounds.

The peaks in ``roofline.hw`` are the TPU-v5e deployment target, so on
this CPU container the "vs peak" ratios read as *headroom on the
target part*, not host efficiency — the honest quantities measured
here are us/step, the arithmetic intensity of the fused step, and
which roof the program would sit under at deployment. The artifact
lands in results/benchmarks/roofline_round.json.
"""
from __future__ import annotations

import time

import jax

from benchmarks import common
from benchmarks.common import emit, timed
from repro import roofline
from repro.roofline import hw
from repro.continuum import Scenario, SimConfig, build_sim_fn, compile_scenario

FULL_CELL = (1000, 50, 5.0)     # K, M, horizon [s]: the ROADMAP memory cell
SMOKE_CELL = (30, 10, 2.0)


def _cost(exe) -> dict:
    """Normalize ``cost_analysis`` across jax versions (dict vs [dict])."""
    c = exe.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return dict(c or {})


def roofline_round():
    import numpy as np
    import jax.numpy as jnp

    K, M, horizon = SMOKE_CELL if common.SMOKE else FULL_CELL
    cfg = SimConfig(horizon=horizon)        # fused_round on by default
    T = cfg.num_steps
    rng = np.random.default_rng(0)
    rtt = jnp.asarray(rng.uniform(0.002, 0.04, (K, M)), jnp.float32)
    drv = compile_scenario(Scenario("baseline", n_nodes=K, n_instances=M),
                           cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(7)

    t0 = time.perf_counter()
    lowered = jax.jit(build_sim_fn(
        "qedgeproxy", cfg, K, M, trace=False)).lower(rtt, drv, key)
    exe = lowered.compile()
    compile_s = time.perf_counter() - t0

    cost = _cost(exe)
    flops = float(cost.get("flops", 0.0))
    hbm_bytes = float(cost.get("bytes accessed", 0.0))
    coll = roofline.collective_bytes(exe.as_text())
    coll_total = sum(v for k, v in coll.items() if not k.startswith("_"))
    terms = roofline.roofline_terms(flops / T, hbm_bytes / T, coll_total / T)

    _, us = timed(exe, rtt, drv, key)
    us_per_step = us / T
    run_s = us / 1e6
    achieved_flops = flops / run_s
    achieved_bw = hbm_bytes / run_s

    payload = {
        "cell": {"K": K, "M": M, "horizon_s": horizon, "steps": T},
        "compile_s": compile_s,
        "per_step": {
            "flops": flops / T,
            "hbm_bytes": hbm_bytes / T,
            "collective_bytes": coll_total / T,
            "intensity_flops_per_byte": flops / max(hbm_bytes, 1.0),
            "us_per_step": us_per_step,
        },
        "roofline": terms,            # model bounds on the target part
        "measured": {
            "backend": jax.default_backend(),
            "run_s": run_s,
            "steps_per_s": T / run_s,
            "achieved_flops_per_s": achieved_flops,
            "achieved_bytes_per_s": achieved_bw,
            # headroom vs the deployment target's roofs, not host
            # efficiency (see module docstring)
            "peak_flops_ratio": achieved_flops / hw.PEAK_FLOPS_BF16,
            "peak_hbm_ratio": achieved_bw / hw.HBM_BW,
        },
        "collectives": coll,
    }
    derived = (f"K{K}xM{M} {T / run_s:.0f}steps/s "
               f"intensity={flops / max(hbm_bytes, 1.0):.2f}F/B "
               f"bound={terms['dominant']} "
               f"model_step={terms['bound_s'] * 1e6:.1f}us")
    emit("roofline_round", us_per_step, derived, payload)
    return payload
