"""The scenario-library benchmark: every named non-stationarity regime
through the sharded evaluation grid, with QoS + event-recovery columns.

One compiled grid program per strategy covers ALL library scenarios
(lanes = scenarios, stacked drivers; they shard across devices exactly
like seeds do in `get_suite`). Per scenario the payload records client
QoS satisfaction, Jain fairness, and the accumulator's event-relative
recovery statistics (worst dip, slowest recovery over the scenario's
event marks) — the Fig 9/10-style adaptation story for regimes the
paper never measured. EXPERIMENTS.md §Scenario-library holds the
reference table.

The ``graceful_degradation`` lane re-runs the resilience probes
(`retry_storm`, `metastable_overload`, `flash_crowd`) under five
request-lifecycle policies — neutral, deadline-bounded retries with
breakers, bounded without breakers, naive unbounded retries, and a
bounded policy whose timeout sits inside the healthy latency band —
at the relaxed tau=150 ms QoS class that leaves an in-deadline retry
window (EXPERIMENTS.md documents why the paper's tau=80 ms admits
none). Policies change `SimConfig` statics, so each variant is its
own compiled grid over the scenario lanes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import emit, strategy_name, timed
from repro.continuum import (breaker_open_fraction_stream, build_sim_grid_fn,
                             client_qos_satisfaction_stream, compile_scenario,
                             event_recovery, get_library,
                             jain_fairness_stream, make_topology,
                             resilience_stats_stream, stack_drivers)

# contrast pair: the adaptive balancer vs the static-proximity baseline
SUITE_STRATEGIES = (("qedgeproxy", {}), ("proxy_mity_1.0", dict(alpha=1.0)))
SMOKE_SCENARIOS = ("baseline", "surge", "cascade_failure", "everything")

# graceful-degradation lane: scenarios x request-lifecycle policies
DEGRADE_SCENARIOS = ("retry_storm", "metastable_overload", "flash_crowd")
SMOKE_DEGRADE_SCENARIOS = ("retry_storm",)
DEGRADE_POLICIES = (
    ("neutral", {}),
    ("bounded", dict(attempt_timeout=0.090, max_retries=2,
                     retry_backoff=0.002, breaker_threshold=5,
                     breaker_cooldown=1.0)),
    ("bounded_nobrk", dict(attempt_timeout=0.090, max_retries=2,
                           retry_backoff=0.002)),
    ("naive", dict(attempt_timeout=0.090, max_retries=5,
                   retry_deadline=False)),
    # same bounded policy, timeout INSIDE the healthy queue-fluctuation
    # band (p99 latency ~71 ms > 70 ms): records the stability knife
    # edge — a hotspot eventually breaches the timeout depth and the
    # retry feedback loop absorbs (EXPERIMENTS.md §Graceful-degradation)
    ("tight", dict(attempt_timeout=0.070, max_retries=2,
                   retry_backoff=0.002, breaker_threshold=5,
                   breaker_cooldown=1.0)),
)
DEGRADE_TAU = 0.150

_cache = common.register_cache({})


def get_scenario_suite():
    """{(scenario_name, label): StreamOutputs} over the whole library."""
    if _cache:
        return _cache
    K, M = common.N_LBS, common.N_INSTANCES
    cfg = common.CFG
    lib = get_library(cfg.horizon, K, M)
    names = [n for n in lib if not common.SMOKE or n in SMOKE_SCENARIOS]
    topo = make_topology(jax.random.PRNGKey(1), K, M)
    rtt = topo.lb_instance_rtt()
    rtts = jnp.broadcast_to(rtt[None], (len(names),) + rtt.shape)
    drivers = stack_drivers(
        [compile_scenario(lib[n], cfg, jax.random.PRNGKey(500 + i))
         for i, n in enumerate(names)])
    # one key per lane so scenario comparisons share the noise stream
    keys = jnp.broadcast_to(jax.random.PRNGKey(11)[None],
                            (len(names), 2))

    lowered, mesh = [], None
    for label, kw in SUITE_STRATEGIES:
        run_grid, mesh = build_sim_grid_fn(
            strategy_name(label), cfg, K, M, mesh=mesh,
            warmup_steps=common.WARM, **kw)
        lowered.append(jax.jit(run_grid).lower(rtts, drivers, keys))
    for (label, kw), exe in zip(SUITE_STRATEGIES,
                                common.compile_all(lowered)):
        outs = exe(rtts, drivers, keys)
        for i, name in enumerate(names):
            _cache[(name, label)] = jax.tree.map(lambda x: x[i], outs)
    _cache["names"] = names
    return _cache


_degrade_cache = common.register_cache({})


def get_degradation_suite():
    """{(scenario, policy): StreamOutputs} over the resilience probes.

    One compiled grid per policy (resilience knobs are `SimConfig`
    statics), scenario lanes stacked exactly like the library suite;
    shared topology/key/driver streams so the ONLY difference between
    policy rows is the request-lifecycle layer.
    """
    if _degrade_cache:
        return _degrade_cache
    K, M = common.N_LBS, common.N_INSTANCES
    names = list(SMOKE_DEGRADE_SCENARIOS if common.SMOKE
                 else DEGRADE_SCENARIOS)
    lib = get_library(common.CFG.horizon, K, M)
    topo = make_topology(jax.random.PRNGKey(1), K, M)
    rtt = topo.lb_instance_rtt()
    rtts = jnp.broadcast_to(rtt[None], (len(names),) + rtt.shape)
    keys = jnp.broadcast_to(jax.random.PRNGKey(11)[None],
                            (len(names), 2))
    base = dataclasses.replace(common.CFG, tau=DEGRADE_TAU)
    # drivers depend on the schedule statics only, never the
    # resilience knobs: one compile serves every policy row
    drivers = stack_drivers(
        [compile_scenario(lib[n], base, jax.random.PRNGKey(600 + i))
         for i, n in enumerate(names)])

    lowered, mesh = [], None
    for label, knobs in DEGRADE_POLICIES:
        cfg = dataclasses.replace(base, **knobs)
        run_grid, mesh = build_sim_grid_fn(
            "qedgeproxy", cfg, K, M, mesh=mesh,
            warmup_steps=common.WARM)
        lowered.append(jax.jit(run_grid).lower(rtts, drivers, keys))
    for (label, _), exe in zip(DEGRADE_POLICIES,
                               common.compile_all(lowered)):
        outs = exe(rtts, drivers, keys)
        for i, name in enumerate(names):
            _degrade_cache[(name, label)] = jax.tree.map(
                lambda x: x[i], outs)
    _degrade_cache["names"] = names
    return _degrade_cache


def _degradation_payload():
    suite = get_degradation_suite()
    out = {}
    for name in suite["names"]:
        row = {}
        for label, knobs in DEGRADE_POLICIES:
            o = suite[(name, label)]
            rec = event_recovery(o.acc, common.CFG.ev_bucket)
            cell = {
                "qos_sat_pct": client_qos_satisfaction_stream(
                    o.acc, common.CFG.rho),
                **resilience_stats_stream(o.acc),
            }
            if knobs.get("breaker_threshold"):
                cell["breaker_open_frac"] = float(
                    jnp.asarray(breaker_open_fraction_stream(o.acc))
                    .mean())
            if rec:
                cell["worst_dip"] = min(r["dip"] for r in rec)
                cell["unrecovered_events"] = sum(
                    1 for r in rec if not r["recovered"])
            row[label] = cell
        out[name] = row
    return out


def scenario_suite():
    suite = get_scenario_suite()

    def compute():
        out = {}
        for name in suite["names"]:
            row = {}
            for label, _ in SUITE_STRATEGIES:
                o = suite[(name, label)]
                rec = event_recovery(o.acc, common.CFG.ev_bucket)
                cell = {
                    "qos_sat_pct": client_qos_satisfaction_stream(
                        o.acc, common.CFG.rho),
                    "jain": jain_fairness_stream(o.acc),
                    "events": len(rec),
                }
                if rec:
                    cell["worst_dip"] = min(r["dip"] for r in rec)
                    recovered = [r["recovery_s"] for r in rec
                                 if r["recovered"]]
                    cell["unrecovered_events"] = len(rec) - len(recovered)
                    if recovered:
                        cell["max_recovery_s"] = max(recovered)
                row[label] = cell
            out[name] = row
        out["graceful_degradation"] = _degradation_payload()
        return out

    payload, us = timed(compute)
    derived = " ".join(
        f"{n}:qep={row['qedgeproxy']['qos_sat_pct']:.0f}%"
        for n, row in payload.items() if n != "graceful_degradation")
    derived += " " + " ".join(
        f"{n}:dip n={row['neutral'].get('worst_dip', 1.0):.2f}"
        f"/b={row['bounded'].get('worst_dip', 1.0):.2f}"
        for n, row in payload["graceful_degradation"].items())
    emit("scenario_suite", us, derived, payload)
    return payload
