"""The scenario-library benchmark: every named non-stationarity regime
through the sharded evaluation grid, with QoS + event-recovery columns.

One compiled grid program per strategy covers ALL library scenarios
(lanes = scenarios, stacked drivers; they shard across devices exactly
like seeds do in `get_suite`). Per scenario the payload records client
QoS satisfaction, Jain fairness, and the accumulator's event-relative
recovery statistics (worst dip, slowest recovery over the scenario's
event marks) — the Fig 9/10-style adaptation story for regimes the
paper never measured. EXPERIMENTS.md §Scenario-library holds the
reference table.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import emit, strategy_name, timed
from repro.continuum import (build_sim_grid_fn, client_qos_satisfaction_stream,
                             compile_scenario, event_recovery, get_library,
                             jain_fairness_stream, make_topology,
                             stack_drivers)

# contrast pair: the adaptive balancer vs the static-proximity baseline
SUITE_STRATEGIES = (("qedgeproxy", {}), ("proxy_mity_1.0", dict(alpha=1.0)))
SMOKE_SCENARIOS = ("baseline", "surge", "cascade_failure", "everything")

_cache = common.register_cache({})


def get_scenario_suite():
    """{(scenario_name, label): StreamOutputs} over the whole library."""
    if _cache:
        return _cache
    K, M = common.N_LBS, common.N_INSTANCES
    cfg = common.CFG
    lib = get_library(cfg.horizon, K, M)
    names = [n for n in lib if not common.SMOKE or n in SMOKE_SCENARIOS]
    topo = make_topology(jax.random.PRNGKey(1), K, M)
    rtt = topo.lb_instance_rtt()
    rtts = jnp.broadcast_to(rtt[None], (len(names),) + rtt.shape)
    drivers = stack_drivers(
        [compile_scenario(lib[n], cfg, jax.random.PRNGKey(500 + i))
         for i, n in enumerate(names)])
    # one key per lane so scenario comparisons share the noise stream
    keys = jnp.broadcast_to(jax.random.PRNGKey(11)[None],
                            (len(names), 2))

    lowered, mesh = [], None
    for label, kw in SUITE_STRATEGIES:
        run_grid, mesh = build_sim_grid_fn(
            strategy_name(label), cfg, K, M, mesh=mesh,
            warmup_steps=common.WARM, **kw)
        lowered.append(jax.jit(run_grid).lower(rtts, drivers, keys))
    for (label, kw), exe in zip(SUITE_STRATEGIES,
                                common.compile_all(lowered)):
        outs = exe(rtts, drivers, keys)
        for i, name in enumerate(names):
            _cache[(name, label)] = jax.tree.map(lambda x: x[i], outs)
    _cache["names"] = names
    return _cache


def scenario_suite():
    suite = get_scenario_suite()

    def compute():
        out = {}
        for name in suite["names"]:
            row = {}
            for label, _ in SUITE_STRATEGIES:
                o = suite[(name, label)]
                rec = event_recovery(o.acc, common.CFG.ev_bucket)
                cell = {
                    "qos_sat_pct": client_qos_satisfaction_stream(
                        o.acc, common.CFG.rho),
                    "jain": jain_fairness_stream(o.acc),
                    "events": len(rec),
                }
                if rec:
                    cell["worst_dip"] = min(r["dip"] for r in rec)
                    recovered = [r["recovery_s"] for r in rec
                                 if r["recovered"]]
                    cell["unrecovered_events"] = len(rec) - len(recovered)
                    if recovered:
                        cell["max_recovery_s"] = max(recovered)
                row[label] = cell
            out[name] = row
        return out

    payload, us = timed(compute)
    derived = " ".join(
        f"{n}:qep={row['qedgeproxy']['qos_sat_pct']:.0f}%"
        for n, row in payload.items())
    emit("scenario_suite", us, derived, payload)
    return payload
