"""The scenario-library benchmark: every named non-stationarity regime
through the sharded evaluation grid, with QoS + event-recovery columns.

One compiled grid program per strategy covers ALL library scenarios
(lanes = scenarios, stacked drivers; they shard across devices exactly
like seeds do in `get_suite`). Per scenario the payload records client
QoS satisfaction, Jain fairness, and the accumulator's event-relative
recovery statistics (worst dip, slowest recovery over the scenario's
event marks) — the Fig 9/10-style adaptation story for regimes the
paper never measured. EXPERIMENTS.md §Scenario-library holds the
reference table.

The ``graceful_degradation`` lane re-runs the resilience probes
(`retry_storm`, `metastable_overload`, `flash_crowd`) under five
request-lifecycle policies — neutral, deadline-bounded retries with
breakers, bounded without breakers, naive unbounded retries, and a
bounded policy whose timeout sits inside the healthy latency band —
at the relaxed tau=150 ms QoS class that leaves an in-deadline retry
window (EXPERIMENTS.md documents why the paper's tau=80 ms admits
none). Policies change `SimConfig` statics, so each variant is its
own compiled grid over the scenario lanes.

The ``closed_loop`` lane is the controller x scenario grid: the same
overload probes (plus `sustained_overload`, the open-loop-unwinnable
regime) on a fleet widened by a parked standby pool
(`with_standby`), swept over control policies — statically parked
(the open-loop floor), fast/slow/narrow-hysteresis reactive
autoscalers, admission shedding, both combined, capacity migration,
and a pre-warmed fleet (the capacity ceiling). All rows run the
PR 6 deadline-bounded resilient request lifecycle at the paper's
tau=80 ms — the QoS class where EXPERIMENTS.md shows retries have no
deadline budget to rescue anything — so the lane answers whether
*closed-loop control* (capacity, shedding) restores the rescue
window that scheduling + retries alone cannot. Each cell records
event-recovery depth/time plus the thrashing readouts
(scale actions per 1k steps, admission-drop fraction, per-tenant QoS
spread) from the controller counters.
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import emit, strategy_name, timed
from repro.continuum import (ControlConfig, TenancyConfig, build_sim_grid_fn,
                             compile_scenario, compile_tenant_scenario,
                             get_library, get_tenant_library, make_topology,
                             stack_drivers, with_standby)
from repro.obs.registry import stream_cell, tenant_cell

# contrast pair: the adaptive balancer vs the static-proximity baseline
SUITE_STRATEGIES = (("qedgeproxy", {}), ("proxy_mity_1.0", dict(alpha=1.0)))
SMOKE_SCENARIOS = ("baseline", "surge", "cascade_failure", "everything")

# graceful-degradation lane: scenarios x request-lifecycle policies
DEGRADE_SCENARIOS = ("retry_storm", "metastable_overload", "flash_crowd")
SMOKE_DEGRADE_SCENARIOS = ("retry_storm",)
DEGRADE_POLICIES = (
    ("neutral", {}),
    ("bounded", dict(attempt_timeout=0.090, max_retries=2,
                     retry_backoff=0.002, breaker_threshold=5,
                     breaker_cooldown=1.0)),
    ("bounded_nobrk", dict(attempt_timeout=0.090, max_retries=2,
                           retry_backoff=0.002)),
    ("naive", dict(attempt_timeout=0.090, max_retries=5,
                   retry_deadline=False)),
    # same bounded policy, timeout INSIDE the healthy queue-fluctuation
    # band (p99 latency ~71 ms > 70 ms): records the stability knife
    # edge — a hotspot eventually breaches the timeout depth and the
    # retry feedback loop absorbs (EXPERIMENTS.md §Graceful-degradation)
    ("tight", dict(attempt_timeout=0.070, max_retries=2,
                   retry_backoff=0.002, breaker_threshold=5,
                   breaker_cooldown=1.0)),
)
DEGRADE_TAU = 0.150

# closed-loop lane: controller x scenario grid at the paper's tau=80 ms.
# The fleet is the base M instances + CONTROL_STANDBY parked spares
# (with_standby appends them LAST, exactly where ControlConfig.managed
# points); every policy row runs the PR 6 deadline-bounded resilient
# request lifecycle so the only delta across rows is the control plane.
CONTROL_SCENARIOS = ("retry_storm", "metastable_overload",
                     "sustained_overload", "surge", "cascade_failure")
SMOKE_CONTROL_SCENARIOS = ("retry_storm", "metastable_overload")
CONTROL_STANDBY = 4
CONTROL_RES = dict(attempt_timeout=0.055, max_retries=2,
                   retry_backoff=0.002, breaker_threshold=4,
                   breaker_cooldown=1.0)
# reaction-time x hysteresis sweep around one autoscaler shape
_AUTOSCALE = dict(managed=CONTROL_STANDBY, warmup=1.0, up_queue=2.0,
                  down_queue=0.5, hold=0.4, action_cooldown=2.0, batch=2)
# a standby pool nothing ever spawns: the open-loop floor at identical
# program shape (up_queue=inf never fires, down_queue=-1 never fires)
_PARKED = dict(managed=CONTROL_STANDBY, up_queue=math.inf,
               down_queue=-1.0)
CONTROL_POLICIES = (
    ("static", ControlConfig(**_PARKED)),
    ("autoscale_fast", ControlConfig(**_AUTOSCALE)),
    ("autoscale_slow", ControlConfig(**{**_AUTOSCALE, "warmup": 4.0,
                                        "hold": 2.0,
                                        "action_cooldown": 10.0,
                                        "batch": 1})),
    # thresholds nearly touching + short dwell: the thrash probe the
    # scale-actions/1k-steps column exists for
    ("autoscale_narrow", ControlConfig(**{**_AUTOSCALE, "up_queue": 1.2,
                                          "down_queue": 1.0, "hold": 0.2,
                                          "action_cooldown": 1.0})),
    ("admit", ControlConfig(**_PARKED, admit=True, target_queue=1.5)),
    ("autoscale_admit", ControlConfig(**_AUTOSCALE, admit=True,
                                      target_queue=1.5)),
    ("migrate", ControlConfig(**_PARKED, regions=2)),
    # every instance (standby included) live from t=0 with no
    # controller at all: the capacity ceiling closed loops chase
    ("prewarmed", None),
)

# multi-tenant lane: S=4 services sharing one fleet, swept over the
# tenant scenario library. Tenant 0 is the tight-deadline foreground
# service (the paper's tau=80 ms), tenants 1-2 the mid class, tenant 3
# the relaxed batch class; base_clients is PER TENANT, so 4 tenants x
# 30 LBs x 1 client keeps aggregate demand at the library baseline's
# 1200 req/s (~66% of capacity) and the scenarios create the overloads.
MT_TENANTS = 4
MT_TAUS = (0.080, 0.110, 0.110, 0.150)
MT_INTERFERENCE = 0.3
MT_BASE_CLIENTS = 1
MT_POLICIES = (("qedgeproxy", {}), ("proxy_mity_1.0", dict(alpha=1.0)))
SMOKE_MT_SCENARIOS = ("mt_baseline", "mt_tenant_surge")

_cache = common.register_cache({})


def get_scenario_suite():
    """{(scenario_name, label): StreamOutputs} over the whole library."""
    if _cache:
        return _cache
    K, M = common.N_LBS, common.N_INSTANCES
    cfg = common.CFG
    lib = get_library(cfg.horizon, K, M)
    names = [n for n in lib if not common.SMOKE or n in SMOKE_SCENARIOS]
    topo = make_topology(jax.random.PRNGKey(1), K, M)
    rtt = topo.lb_instance_rtt()
    rtts = jnp.broadcast_to(rtt[None], (len(names),) + rtt.shape)
    drivers = stack_drivers(
        [compile_scenario(lib[n], cfg, jax.random.PRNGKey(500 + i))
         for i, n in enumerate(names)])
    # one key per lane so scenario comparisons share the noise stream
    keys = jnp.broadcast_to(jax.random.PRNGKey(11)[None],
                            (len(names), 2))

    lowered, mesh = [], None
    for label, kw in SUITE_STRATEGIES:
        run_grid, mesh = build_sim_grid_fn(
            strategy_name(label), cfg, K, M, mesh=mesh,
            warmup_steps=common.WARM, **kw)
        lowered.append(jax.jit(run_grid).lower(rtts, drivers, keys))
    for (label, kw), exe in zip(SUITE_STRATEGIES,
                                common.compile_all(lowered)):
        outs = exe(rtts, drivers, keys)
        for i, name in enumerate(names):
            _cache[(name, label)] = jax.tree.map(lambda x: x[i], outs)
    _cache["names"] = names
    return _cache


_degrade_cache = common.register_cache({})


def get_degradation_suite():
    """{(scenario, policy): StreamOutputs} over the resilience probes.

    One compiled grid per policy (resilience knobs are `SimConfig`
    statics), scenario lanes stacked exactly like the library suite;
    shared topology/key/driver streams so the ONLY difference between
    policy rows is the request-lifecycle layer.
    """
    if _degrade_cache:
        return _degrade_cache
    K, M = common.N_LBS, common.N_INSTANCES
    names = list(SMOKE_DEGRADE_SCENARIOS if common.SMOKE
                 else DEGRADE_SCENARIOS)
    lib = get_library(common.CFG.horizon, K, M)
    topo = make_topology(jax.random.PRNGKey(1), K, M)
    rtt = topo.lb_instance_rtt()
    rtts = jnp.broadcast_to(rtt[None], (len(names),) + rtt.shape)
    keys = jnp.broadcast_to(jax.random.PRNGKey(11)[None],
                            (len(names), 2))
    base = dataclasses.replace(common.CFG, tau=DEGRADE_TAU)
    # drivers depend on the schedule statics only, never the
    # resilience knobs: one compile serves every policy row
    drivers = stack_drivers(
        [compile_scenario(lib[n], base, jax.random.PRNGKey(600 + i))
         for i, n in enumerate(names)])

    lowered, mesh = [], None
    for label, knobs in DEGRADE_POLICIES:
        cfg = dataclasses.replace(base, **knobs)
        run_grid, mesh = build_sim_grid_fn(
            "qedgeproxy", cfg, K, M, mesh=mesh,
            warmup_steps=common.WARM)
        lowered.append(jax.jit(run_grid).lower(rtts, drivers, keys))
    for (label, _), exe in zip(DEGRADE_POLICIES,
                               common.compile_all(lowered)):
        outs = exe(rtts, drivers, keys)
        for i, name in enumerate(names):
            _degrade_cache[(name, label)] = jax.tree.map(
                lambda x: x[i], outs)
    _degrade_cache["names"] = names
    return _degrade_cache


def _degradation_payload():
    suite = get_degradation_suite()
    out = {}
    for name in suite["names"]:
        row = {}
        for label, knobs in DEGRADE_POLICIES:
            o = suite[(name, label)]
            # shared registry cell builder (repro.obs.registry): same
            # key set the hand-rolled dict produced, so the artifact
            # shape is unchanged
            row[label] = stream_cell(
                o, rho=common.CFG.rho, bucket_s=common.CFG.ev_bucket,
                resilience=True,
                breaker_frac=bool(knobs.get("breaker_threshold")),
                max_recovery=False)
        out[name] = row
    return out


_control_cache = common.register_cache({})


def get_control_suite():
    """{(scenario, policy): StreamOutputs} for the controller grid.

    One compiled grid per control policy (`ControlConfig` is a
    `SimConfig` static), scenario lanes stacked like the other suites;
    shared topology/key/driver streams over the standby-widened fleet
    so the ONLY difference between policy rows is the control plane.
    """
    if _control_cache:
        return _control_cache
    K, M = common.N_LBS, common.N_INSTANCES
    M_tot = M + CONTROL_STANDBY
    names = list(SMOKE_CONTROL_SCENARIOS if common.SMOKE
                 else CONTROL_SCENARIOS)
    lib = get_library(common.CFG.horizon, K, M)
    topo = make_topology(jax.random.PRNGKey(1), K, M_tot)
    rtt = topo.lb_instance_rtt()
    rtts = jnp.broadcast_to(rtt[None], (len(names),) + rtt.shape)
    keys = jnp.broadcast_to(jax.random.PRNGKey(11)[None],
                            (len(names), 2))
    base = dataclasses.replace(common.CFG, **CONTROL_RES)
    # the schedules never depend on the control knobs: one compile of
    # the standby-widened drivers serves every policy row
    drivers = stack_drivers(
        [compile_scenario(with_standby(lib[n], CONTROL_STANDBY), base,
                          jax.random.PRNGKey(700 + i))
         for i, n in enumerate(names)])

    lowered, mesh = [], None
    for label, ctl in CONTROL_POLICIES:
        cfg = dataclasses.replace(base, control=ctl)
        run_grid, mesh = build_sim_grid_fn(
            "qedgeproxy", cfg, K, M_tot, mesh=mesh,
            warmup_steps=common.WARM)
        lowered.append(jax.jit(run_grid).lower(rtts, drivers, keys))
    for (label, _), exe in zip(CONTROL_POLICIES,
                               common.compile_all(lowered)):
        outs = exe(rtts, drivers, keys)
        for i, name in enumerate(names):
            _control_cache[(name, label)] = jax.tree.map(
                lambda x: x[i], outs)
    _control_cache["names"] = names
    return _control_cache


def _control_payload():
    suite = get_control_suite()
    out = {}
    for name in suite["names"]:
        row = {}
        for label, _ in CONTROL_POLICIES:
            o = suite[(name, label)]
            row[label] = stream_cell(
                o, rho=common.CFG.rho, bucket_s=common.CFG.ev_bucket,
                jain=True, tenants=True, drop_rate=True, control=True)
        out[name] = row
    return out


_mt_cache = common.register_cache({})


def get_multi_tenant_suite():
    """{(scenario, label): StreamOutputs} for the S=4 tenant grid.

    One compiled grid per policy (``TenancyConfig`` is a ``SimConfig``
    static shared by every row), tenant-scenario lanes stacked exactly
    like the library suite. Each cell's ``acc`` is the S-tuple of
    per-tenant accumulators; ``tenant_cell`` reads the per-tenant QoS
    and fairness columns. Run wall-clock per policy lands in the cache
    under ``grid_steps_per_s`` for the smoke-floor gate.
    """
    if _mt_cache:
        return _mt_cache
    K, M = common.N_LBS, common.N_INSTANCES
    cfg = dataclasses.replace(
        common.CFG, tenancy=TenancyConfig(taus=MT_TAUS,
                                          interference=MT_INTERFERENCE))
    lib = get_tenant_library(cfg.horizon, K, M, n_tenants=MT_TENANTS,
                             base_clients=MT_BASE_CLIENTS)
    names = [n for n in lib if not common.SMOKE or n in SMOKE_MT_SCENARIOS]
    topo = make_topology(jax.random.PRNGKey(1), K, M)
    rtt = topo.lb_instance_rtt()
    rtts = jnp.broadcast_to(rtt[None], (len(names),) + rtt.shape)
    drivers = stack_drivers(
        [compile_tenant_scenario(lib[n], cfg, jax.random.PRNGKey(800 + i))
         for i, n in enumerate(names)])
    keys = jnp.broadcast_to(jax.random.PRNGKey(11)[None],
                            (len(names), 2))

    lowered, mesh = [], None
    for label, kw in MT_POLICIES:
        run_grid, mesh = build_sim_grid_fn(
            strategy_name(label), cfg, K, M, mesh=mesh,
            warmup_steps=common.WARM, **kw)
        lowered.append(jax.jit(run_grid).lower(rtts, drivers, keys))
    steps_per_s = {}
    for (label, kw), exe in zip(MT_POLICIES, common.compile_all(lowered)):
        t0 = time.perf_counter()
        outs = exe(rtts, drivers, keys)
        jax.block_until_ready(outs)
        t_run = time.perf_counter() - t0
        steps_per_s[label] = len(names) * cfg.num_steps / t_run
        for i, name in enumerate(names):
            _mt_cache[(name, label)] = jax.tree.map(lambda x: x[i], outs)
    _mt_cache["names"] = names
    _mt_cache["grid_steps_per_s"] = steps_per_s
    return _mt_cache


def multi_tenant():
    """S=4 tenants x tenant-scenario library x policy: per-tenant QoS
    columns + cross-tenant fairness indices + self-partitioning."""
    suite = get_multi_tenant_suite()

    def compute():
        out = {"tenants": MT_TENANTS, "taus": list(MT_TAUS),
               "interference": MT_INTERFERENCE,
               "grid_steps_per_s": dict(suite["grid_steps_per_s"])}
        for name in suite["names"]:
            row = {}
            for label, _ in MT_POLICIES:
                row[label] = tenant_cell(suite[(name, label)],
                                         rho=common.CFG.rho)
            out[name] = row
        return out

    payload, us = timed(compute)
    derived = " ".join(
        "{n}:t0={t0:.0f}%/jain={j:.2f}".format(
            n=n, t0=payload[n]["qedgeproxy"]["tenant_qos_sat_pct"][0],
            j=payload[n]["qedgeproxy"]["jain_qos"])
        for n in suite["names"])
    emit("multi_tenant", us, derived, payload)
    if common.SMOKE:
        # same throughput floor as the bandit_scale smoke cells: the
        # S=4 tenant grid must clear 60 grid-steps/s or CI fails
        from benchmarks.bandit_scale import SMOKE_FLOOR_STEPS_PER_S
        slow = {k: v for k, v in suite["grid_steps_per_s"].items()
                if v < SMOKE_FLOOR_STEPS_PER_S}
        if slow:
            raise RuntimeError(
                f"multi-tenant smoke grid under the "
                f"{SMOKE_FLOOR_STEPS_PER_S:.0f} grid-steps/s floor: {slow}")
    return payload


def scenario_suite():
    suite = get_scenario_suite()

    def compute():
        out = {}
        for name in suite["names"]:
            row = {}
            for label, _ in SUITE_STRATEGIES:
                row[label] = stream_cell(
                    suite[(name, label)], rho=common.CFG.rho,
                    bucket_s=common.CFG.ev_bucket, jain=True,
                    n_events=True)
            out[name] = row
        out["graceful_degradation"] = _degradation_payload()
        out["closed_loop"] = _control_payload()
        return out

    payload, us = timed(compute)
    _special = ("graceful_degradation", "closed_loop")
    derived = " ".join(
        f"{n}:qep={row['qedgeproxy']['qos_sat_pct']:.0f}%"
        for n, row in payload.items() if n not in _special)
    derived += " " + " ".join(
        f"{n}:dip n={row['neutral'].get('worst_dip', 1.0):.2f}"
        f"/b={row['bounded'].get('worst_dip', 1.0):.2f}"
        for n, row in payload["graceful_degradation"].items())
    def _best_ctl(row):
        # best closed-loop policy (prewarmed is the open-loop oracle)
        name = max((p for p in row if p not in ("static", "prewarmed")),
                   key=lambda p: row[p]["qos_sat_pct"])
        return name, row[name]["qos_sat_pct"]

    derived += " " + " ".join(
        "{n}:qos s={s:.0f}/c={c:.0f}({p})/p={pre:.0f}%".format(
            n=n, s=row["static"]["qos_sat_pct"],
            c=_best_ctl(row)[1], p=_best_ctl(row)[0],
            pre=row["prewarmed"]["qos_sat_pct"])
        for n, row in payload["closed_loop"].items())
    emit("scenario_suite", us, derived, payload)
    return payload
