"""Serve a small model with batched requests behind QEdgeProxy routing.

Three model replicas (one intentionally degraded), four front-ends;
the router learns per-replica QoS success and shifts traffic off the
straggler — the paper's technique as serving-infra control plane.
Midway, the slow replica "fails" (Alg 4) and later rejoins (Alg 3).

  PYTHONPATH=src python examples/serve_routed.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import BanditParams
from repro.models import build_model
from repro.serving import QEdgeRouter, ServingEngine

ARCH = "qwen3-4b"
TAU = 0.4          # per-request latency SLO (CPU-sized)
REQUESTS = 120
DECODE_STEPS = 4


def main():
    cfg = dataclasses.replace(get_config(ARCH, reduced=True))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = 32 + DECODE_STEPS

    engines = [
        ServingEngine(model, params, max_len, extra_latency=0.0),
        ServingEngine(model, params, max_len, extra_latency=TAU),  # straggler
        ServingEngine(model, params, max_len, extra_latency=0.0),
    ]
    router = QEdgeRouter(4, 3, BanditParams(tau=TAU, rho=0.9, window=20.0,
                                            cooldown=3.0))

    ok = total = 0
    last_maint = time.monotonic()
    for r in range(REQUESTS):
        if r == REQUESTS // 3:
            print(f"[{r}] replica 1 FAILS (Alg 4)")
            router.replica_failed(1)
        if r == 2 * REQUESTS // 3:
            print(f"[{r}] replica 1 REJOINS (Alg 3)")
            engines[1].extra_latency = 0.0      # recovered
            router.replica_joined(1)

        choices = router.route()
        lats = np.zeros(4)
        for k, m in enumerate(choices):
            prompt = jax.random.randint(jax.random.PRNGKey(r * 17 + k),
                                        (2, 32), 0, cfg.vocab_size)
            _, cache, lat = engines[m].prefill({"tokens": prompt})
            tok = jnp.zeros((2, 1), jnp.int32)
            for i in range(DECODE_STEPS):
                _, cache, d = engines[m].decode(cache, tok, 32 + i)
                lat += d
            lats[k] = lat
            total += 1
            ok += int(lat <= TAU)
        router.feedback(choices, lats)
        if time.monotonic() - last_maint > 0.5:
            router.maintenance()
            last_maint = time.monotonic()
        if r % 30 == 29:
            print(f"[{r}] weights:\n{router.weights.round(3)}")

    print(f"\nQoS success {ok}/{total} = {100*ok/total:.1f}% (tau={TAU}s)")
    print("final weights:\n", router.weights.round(3))
    assert router.weights[:, 1].mean() < 0.5   # straggler learned + recovered


if __name__ == "__main__":
    main()
