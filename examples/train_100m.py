"""End-to-end driver: train a ~100M-param LM for a few hundred steps
with the full production path (sharded data pipeline, remat, AdamW,
async checkpointing + restart).

  PYTHONPATH=src python examples/train_100m.py [--steps 300]

On this CPU container the default is CPU-sized; pass --full for the
real ~100M config (slow on 1 core, exact same code path as the
production mesh).
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="real ~100M params (slow on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    argv = ["--arch", "qwen3-4b", "--steps", str(args.steps),
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
            "--log-every", "20"]
    if args.full:
        argv += ["--train-100m", "--seq-len", "512", "--batch", "8"]
    else:
        argv += ["--smoke", "--seq-len", "256", "--batch", "8"]
    losses = train_main(argv)
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
