"""Reproduce the paper's headline experiment (§VII-B) end to end:

30-node CC, 10 PilotNet-like instances, 120 clients @ 10 req/s,
(tau=80ms, rho=0.9, W=10s), comparing QEdgeProxy vs proxy-mity (1.0,
0.9) vs Dec-SARSA — prints the Fig. 3 / Fig. 4 numbers.

  PYTHONPATH=src python examples/continuum_sim.py [--horizon 180]

``--players N`` shards the fleet's player axis over N devices
(streaming engine + `run_sim_players`; on CPU it forces N host
devices, so the whole 2-D scaling story runs on a laptop — see
docs/SCALING.md). Results match the unsharded run: counting
statistics exactly, reduced float sums to f32 tolerance.
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--horizon", type=float, default=180.0)
    ap.add_argument("--scenario", type=int, default=1,
                    help="topology seed")
    ap.add_argument("--events", default=None,
                    help="named library scenario driving the run "
                         "(e.g. surge, cascade_failure; default: "
                         "stationary baseline)")
    ap.add_argument("--players", type=int, default=1,
                    help="shard the 30-player axis over this many "
                         "devices (30 %% N must be 0; forces N host "
                         "devices on CPU)")
    ap.add_argument("--resilient", action="store_true",
                    help="turn on the request-lifecycle resilience "
                         "layer (90ms attempt timeout, 2 deadline-"
                         "bounded retries, 5-strike breakers) at a "
                         "relaxed tau=150ms QoS class")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="checkpoint the streaming carry here every "
                         "--checkpoint-every chunks (forces the "
                         "chunked streaming engine)")
    ap.add_argument("--checkpoint-every", type=int, default=1)
    ap.add_argument("--chunk-steps", type=int, default=200,
                    help="compiled chunk length for the checkpointed "
                         "streaming path")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in "
                         "--checkpoint-dir (bit-exact vs uninterrupted)")
    args = ap.parse_args()
    if args.resume and not args.checkpoint_dir:
        sys.exit("--resume needs --checkpoint-dir")
    if args.checkpoint_dir and args.players > 1:
        sys.exit("--checkpoint-dir does not compose with --players yet")

    if args.players > 1 and "--xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        # must happen before the first jax import in this process
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.players}")

    import jax

    from repro.continuum import (SimConfig, client_qos_satisfaction,
                                 client_qos_satisfaction_stream,
                                 compile_scenario, get_library,
                                 jain_fairness, jain_fairness_stream,
                                 make_topology, rolling_qos,
                                 rolling_qos_series, run_sim,
                                 run_sim_players, run_sim_stream)
    from repro.launch.mesh import make_continuum_mesh

    cfg = SimConfig(horizon=args.horizon)
    if args.resilient:
        cfg = SimConfig(horizon=args.horizon, tau=0.150,
                        attempt_timeout=0.090, max_retries=2,
                        retry_backoff=0.002, breaker_threshold=5,
                        breaker_cooldown=1.0)
    warm = int(min(60.0, args.horizon / 3) / cfg.dt)
    topo = make_topology(jax.random.PRNGKey(args.scenario), 30, 10)
    rtt = topo.lb_instance_rtt()
    drivers = None
    if args.events:
        scn = get_library(cfg.horizon, 30, 10)[args.events]
        drivers = compile_scenario(scn, cfg, jax.random.PRNGKey(0))
    if args.players > 1 and 30 % args.players:
        sys.exit(f"--players {args.players} must divide the 30 LBs")
    print(f"topology: 30 nodes, 10 instances on nodes "
          f"{topo.instance_nodes.tolist()}"
          + (f"; events: {args.events}" if args.events else "")
          + (f"; player axis sharded {args.players} ways"
             if args.players > 1 else ""))
    print(f"QoS: tau={cfg.tau*1e3:.0f}ms rho={cfg.rho} W={cfg.window}s; "
          f"120 clients x 10 req/s\n")

    print(f"{'strategy':18s} {'clients>=rho':>12s} {'fairness':>9s} "
          f"{'steady QoS':>10s}")
    for label, name, kw in [
        ("QEdgeProxy", "qedgeproxy", {}),
        ("proxy-mity 1.0", "proxy_mity", dict(alpha=1.0)),
        ("proxy-mity 0.9", "proxy_mity", dict(alpha=0.9)),
        ("Dec-SARSA", "dec_sarsa", {}),
    ]:
        if args.players > 1:
            mesh = make_continuum_mesh(
                players=args.players,
                devices=jax.devices()[:args.players])
            outs = run_sim_players(name, rtt, cfg, jax.random.PRNGKey(7),
                                   drivers=drivers, warmup_steps=warm,
                                   mesh=mesh, **kw)
            sat = client_qos_satisfaction_stream(outs.acc, cfg.rho)
            fair = jain_fairness_stream(outs.acc)
            roll = rolling_qos_series(
                outs.series, int(cfg.window / cfg.dt))[warm:].mean()
        elif args.checkpoint_dir:
            outs = run_sim_stream(
                name, rtt, cfg, jax.random.PRNGKey(7), drivers=drivers,
                warmup_steps=warm, chunk_steps=args.chunk_steps,
                # key the subdir by the display label, not the strategy
                # name — both proxy-mity variants share one `name`
                checkpoint_dir=os.path.join(
                    args.checkpoint_dir, label.replace(" ", "_").lower()),
                checkpoint_every=args.checkpoint_every,
                resume=args.resume, **kw)
            sat = client_qos_satisfaction_stream(outs.acc, cfg.rho)
            fair = jain_fairness_stream(outs.acc)
            roll = rolling_qos_series(
                outs.series, int(cfg.window / cfg.dt))[warm:].mean()
        else:
            trace = run_sim(name, rtt, cfg, jax.random.PRNGKey(7),
                            drivers=drivers, **kw)
            sat = client_qos_satisfaction(trace, cfg.rho, warm)
            fair = jain_fairness(trace, warmup_steps=warm)
            roll = rolling_qos(trace, int(cfg.window / cfg.dt))[warm:].mean()
        print(f"{label:18s} {sat:11.1f}% {fair:9.3f} {roll:10.3f}")


if __name__ == "__main__":
    main()
