"""Reproduce the paper's headline experiment (§VII-B) end to end:

30-node CC, 10 PilotNet-like instances, 120 clients @ 10 req/s,
(tau=80ms, rho=0.9, W=10s), comparing QEdgeProxy vs proxy-mity (1.0,
0.9) vs Dec-SARSA — prints the Fig. 3 / Fig. 4 numbers.

  PYTHONPATH=src python examples/continuum_sim.py [--horizon 180]
"""
import argparse

import jax

from repro.continuum import (SimConfig, client_qos_satisfaction,
                             compile_scenario, get_library, jain_fairness,
                             make_topology, rolling_qos, run_sim)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--horizon", type=float, default=180.0)
    ap.add_argument("--scenario", type=int, default=1,
                    help="topology seed")
    ap.add_argument("--events", default=None,
                    help="named library scenario driving the run "
                         "(e.g. surge, cascade_failure; default: "
                         "stationary baseline)")
    args = ap.parse_args()

    cfg = SimConfig(horizon=args.horizon)
    warm = int(min(60.0, args.horizon / 3) / cfg.dt)
    topo = make_topology(jax.random.PRNGKey(args.scenario), 30, 10)
    rtt = topo.lb_instance_rtt()
    drivers = None
    if args.events:
        scn = get_library(cfg.horizon, 30, 10)[args.events]
        drivers = compile_scenario(scn, cfg, jax.random.PRNGKey(0))
    print(f"topology: 30 nodes, 10 instances on nodes "
          f"{topo.instance_nodes.tolist()}"
          + (f"; events: {args.events}" if args.events else ""))
    print(f"QoS: tau={cfg.tau*1e3:.0f}ms rho={cfg.rho} W={cfg.window}s; "
          f"120 clients x 10 req/s\n")

    print(f"{'strategy':18s} {'clients>=rho':>12s} {'fairness':>9s} "
          f"{'steady QoS':>10s}")
    for label, name, kw in [
        ("QEdgeProxy", "qedgeproxy", {}),
        ("proxy-mity 1.0", "proxy_mity", dict(alpha=1.0)),
        ("proxy-mity 0.9", "proxy_mity", dict(alpha=0.9)),
        ("Dec-SARSA", "dec_sarsa", {}),
    ]:
        outs = run_sim(name, rtt, cfg, jax.random.PRNGKey(7),
                       drivers=drivers, **kw)
        sat = client_qos_satisfaction(outs, cfg.rho, warm)
        fair = jain_fairness(outs, warmup_steps=warm)
        roll = rolling_qos(outs, int(cfg.window / cfg.dt))[warm:].mean()
        print(f"{label:18s} {sat:11.1f}% {fair:9.3f} {roll:10.3f}")


if __name__ == "__main__":
    main()
