"""Quickstart: the paper's algorithm in 60 seconds.

Builds a 4-LB x 3-instance toy continuum, runs QEdgeProxy (KDE + QoS
pools + SWRR, paper Algs 1-2) against a slow instance, and prints the
learned routing weights + QoS estimates.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BanditParams, init_state, maintenance, record, select

K, M = 4, 3                       # 4 load balancers, 3 service instances
params = BanditParams(tau=0.080, rho=0.9, window=10.0)
state = init_state(K, M, params, ring=64, key=jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
rtt = jnp.asarray(rng.uniform(0.002, 0.02, (K, M)), jnp.float32)
true_proc = np.asarray([0.015, 0.030, 0.250])   # instance 2 violates tau

sel = jax.jit(select)
rec = jax.jit(record, static_argnums=1)
mnt = jax.jit(maintenance, static_argnums=1)

for step in range(400):
    t = jnp.float32(step * 0.1)
    choice, state, _ = sel(state)
    lat = (jnp.asarray(true_proc)[choice]
           * jnp.asarray(rng.lognormal(0, 0.2, K), jnp.float32)
           + rtt[jnp.arange(K), choice])
    state = rec(state, params, choice, lat, t, jnp.ones((K,), bool))
    if step % 10 == 9:            # decision step H_d = 1 s
        state = mnt(state, params, rtt, t)

np.set_printoptions(precision=3, suppress=True)
print("learned QoS success estimates mu_hat (LBs x instances):")
print(np.asarray(state.mu_hat))
print("\nrouting weights (instance 2 should be ~0 everywhere):")
print(np.asarray(state.weights))
print(f"\nexploration rates eps(t): {np.asarray(state.eps).round(4)}")
assert np.asarray(state.weights)[:, 2].max() < 0.05
print("\nOK: QEdgeProxy learned to avoid the QoS-violating instance.")
