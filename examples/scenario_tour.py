"""Tour the scenario library: compile each named non-stationarity
regime, stream it through the simulator, and print QoS + adaptation
numbers per scenario.

  PYTHONPATH=src python examples/scenario_tour.py [--horizon 90]
      [--scenarios surge cascade_failure ...] [--strategy qedgeproxy]

This is the scenario engine end to end: declarative events ->
`compile_scenario` -> dense per-step driver arrays -> the streaming
engine -> event-relative recovery windows read straight off the
metric accumulator (no trajectories anywhere).
"""
import argparse

import jax

from repro.continuum import (SimConfig, client_qos_satisfaction_stream,
                             compile_scenario, event_recovery, get_library,
                             jain_fairness_stream, make_topology,
                             run_sim_stream)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--horizon", type=float, default=90.0)
    ap.add_argument("--strategy", default="qedgeproxy")
    ap.add_argument("--scenarios", nargs="*", default=None)
    args = ap.parse_args()

    cfg = SimConfig(horizon=args.horizon)
    warm = int(min(20.0, args.horizon / 4) / cfg.dt)
    lib = get_library(cfg.horizon, 30, 10)
    names = args.scenarios or list(lib)
    topo = make_topology(jax.random.PRNGKey(1), 30, 10)
    rtt = topo.lb_instance_rtt()

    print(f"{args.strategy} on 30 LBs x 10 instances, "
          f"horizon {args.horizon:.0f}s (tau={cfg.tau * 1e3:.0f}ms, "
          f"rho={cfg.rho})\n")
    print(f"{'scenario':18s} {'clients>=rho':>12s} {'fairness':>9s} "
          f"{'events':>6s} {'worst dip':>9s} {'recovery':>8s}")
    for i, name in enumerate(names):
        drv = compile_scenario(lib[name], cfg, jax.random.PRNGKey(500 + i))
        out = run_sim_stream(args.strategy, rtt, cfg,
                             jax.random.PRNGKey(11), drivers=drv,
                             warmup_steps=warm)
        sat = client_qos_satisfaction_stream(out.acc, cfg.rho)
        fair = jain_fairness_stream(out.acc)
        rec = event_recovery(out.acc, cfg.ev_bucket)
        dip = f"{min(r['dip'] for r in rec):9.3f}" if rec else "        -"
        recovered = [r["recovery_s"] for r in rec if r["recovered"]]
        if rec and len(recovered) < len(rec):
            rcv = "   never"
        elif recovered:
            rcv = f"{max(recovered):7.0f}s"
        else:
            rcv = "       -"
        print(f"{name:18s} {sat:11.1f}% {fair:9.3f} {len(rec):6d} "
              f"{dip} {rcv}")


if __name__ == "__main__":
    main()
