"""Sharded-evaluation-grid parity: `run_sim_grid` vs the vmap program.

Grid lanes (scenario x seed) are independent simulations, so sharding
them over a mesh must not change any result: counting statistics (QoS
successes, arrival/choice histograms, the latency sketch, the
event-recovery windows) are integer-valued float32 sums and must match
the single-device vmap EXACTLY; genuinely float accumulations (regret,
variation budget, prev_mu) get float32 tolerance, per-lane reduction
order being the one thing XLA may legally reassociate.

Since the scenario engine, grid lanes carry *compiled scenarios*
(per-lane Drivers pytrees: time-varying clients, liveness, RTT
modulation, per-instance service times) — the subprocess parity run
drives each lane with a different library scenario so the sharded axis
is exercised with real diversity, not constant fills.

In-process tests cover the single-device fallback (the grid builder
must return the plain vmap program untouched); they require the
default one-CPU-device process and skip if the environment forces more
(e.g. an exported XLA_FLAGS device count). Real multi-device sharding
runs in a subprocess with 8 forced host devices because jax locks the
device count at first init (conftest.run_sub, shared with
tests/test_sharding.py); one subprocess checks 8-, 2- and 1-device
meshes, including the pad path (S=5 lanes never divide evenly).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_sub
from repro.continuum import (SimConfig, build_sim_fn, build_sim_grid_fn,
                             compile_scenario, get_library, make_topology,
                             neutral_drivers, run_sim_grid, stack_drivers)

K, M, S = 8, 4, 5
CFG = SimConfig(horizon=6.0)
WARM = 20

single_device = pytest.mark.skipif(
    len(jax.devices()) != 1,
    reason="fallback tests need the default single-device process")


def _grid_inputs():
    rtts = jnp.stack([make_topology(jax.random.PRNGKey(s), K, M)
                      .lb_instance_rtt() for s in range(S)])
    keys = jnp.stack([jax.random.PRNGKey(100 + s) for s in range(S)])
    return rtts, keys


def _scenario_lanes():
    """One compiled library scenario per lane — the diverse grid."""
    lib = list(get_library(CFG.horizon, K, M).values())
    return stack_drivers(
        [compile_scenario(lib[i % len(lib)], CFG, jax.random.PRNGKey(i))
         for i in range(S)])


@single_device
def test_single_device_fallback_is_the_vmap_program():
    """On a 1-device mesh the grid driver IS the vmapped streaming run:
    identical floats, not just close ones — including with per-lane
    scenario drivers."""
    rtts, keys = _grid_inputs()
    drivers = _scenario_lanes()
    run = build_sim_fn("qedgeproxy", CFG, K, M, trace=False,
                       warmup_steps=WARM)
    ref = jax.jit(jax.vmap(run, in_axes=(0, 0, 0)))(rtts, drivers, keys)
    got = run_sim_grid("qedgeproxy", rtts, CFG, keys, drivers=drivers,
                       warmup_steps=WARM)
    for name, a, b in zip(ref.acc._fields, ref.acc, got.acc):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a),
                                      err_msg=f"acc field {name}")
    for name, a, b in zip(ref.series._fields, ref.series, got.series):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a),
                                      err_msg=f"series field {name}")


@single_device
def test_builder_returns_unwrapped_vmap_on_one_device():
    fn, mesh = build_sim_grid_fn("qedgeproxy", CFG, K, M,
                                 warmup_steps=WARM)
    assert int(mesh.devices.size) == 1
    rtts, keys = _grid_inputs()
    drivers = _scenario_lanes()
    out = jax.jit(fn)(rtts, drivers, keys)
    assert out.acc.succ_kc.shape == (S, K, CFG.max_clients)
    assert out.series.succ.shape == (S, CFG.num_steps)


@single_device
def test_shared_drivers_broadcast_to_lanes():
    """An un-batched Drivers (or the legacy kwargs) drives every lane
    with the same schedule."""
    rtts, keys = _grid_inputs()
    drv = neutral_drivers(CFG, K, M)
    got = run_sim_grid("qedgeproxy", rtts, CFG, keys, drivers=drv,
                       warmup_steps=WARM)
    legacy = run_sim_grid("qedgeproxy", rtts, CFG, keys,
                          warmup_steps=WARM)
    for name, a, b in zip(got.acc._fields, got.acc, legacy.acc):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"acc field {name}")


@pytest.mark.slow
def test_sharded_grid_matches_vmap_8dev():
    """8-, 2- and 1-device meshes against the full-width vmap reference,
    every lane a different compiled scenario, including the pad path
    (S=5 on D=8 pads 3 lanes, on D=2 pads 1)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.continuum import (SimConfig, build_sim_fn,
                                     compile_scenario, get_library,
                                     make_topology, run_sim_grid,
                                     stack_drivers)
        from repro.launch.mesh import make_grid_mesh

        K, M, S, WARM = 8, 4, 5, 20
        cfg = SimConfig(horizon=6.0)
        rtts = jnp.stack([make_topology(jax.random.PRNGKey(s), K, M)
                          .lb_instance_rtt() for s in range(S)])
        keys = jnp.stack([jax.random.PRNGKey(100 + s) for s in range(S)])
        lib = list(get_library(cfg.horizon, K, M).values())
        drivers = stack_drivers(
            [compile_scenario(lib[i % len(lib)], cfg,
                              jax.random.PRNGKey(i)) for i in range(S)])

        run = build_sim_fn("qedgeproxy", cfg, K, M, trace=False,
                           warmup_steps=WARM)
        ref = jax.jit(jax.vmap(run, in_axes=(0, 0, 0)))(
            rtts, drivers, keys)
        COUNTS = {"succ_kc", "n_kc", "arrivals_m", "choice_counts",
                  "proc_hist", "steps_measured", "ev_succ", "ev_n"}
        for ndev in (8, 2, 1):
            mesh = make_grid_mesh(jax.devices()[:ndev])
            got = run_sim_grid("qedgeproxy", rtts, cfg, keys,
                               drivers=drivers,
                               warmup_steps=WARM, mesh=mesh)
            for name in ref.acc._fields:
                a = np.asarray(getattr(ref.acc, name))
                b = np.asarray(getattr(got.acc, name))
                if name in COUNTS:
                    np.testing.assert_array_equal(
                        b, a, err_msg=f"dev{ndev} acc.{name}")
                else:
                    np.testing.assert_allclose(
                        b, a, rtol=1e-5, atol=1e-5,
                        err_msg=f"dev{ndev} acc.{name}")
            np.testing.assert_array_equal(
                np.asarray(got.series.issued),
                np.asarray(ref.series.issued), err_msg=f"dev{ndev}")
            np.testing.assert_array_equal(
                np.asarray(got.series.succ),
                np.asarray(ref.series.succ), err_msg=f"dev{ndev}")
            np.testing.assert_allclose(
                np.asarray(got.series.regret),
                np.asarray(ref.series.regret), rtol=1e-4, atol=1e-4,
                err_msg=f"dev{ndev}")
            print(f"dev{ndev} parity ok")
        print("OK sharded parity")
    """)
    assert "OK sharded parity" in out
