"""Sharded-evaluation-grid parity: `run_sim_grid` vs the vmap program.

Grid lanes (scenario x seed) are independent simulations, so sharding
them over a mesh must not change any result: counting statistics (QoS
successes, arrival/choice histograms, the latency sketch) are
integer-valued float32 sums and must match the single-device vmap
EXACTLY; genuinely float accumulations (regret, variation budget,
prev_mu) get float32 tolerance, per-lane reduction order being the one
thing XLA may legally reassociate.

In-process tests cover the single-device fallback (the grid builder
must return the plain vmap program untouched); they require the
default one-CPU-device process and skip if the environment forces more
(e.g. an exported XLA_FLAGS device count). Real multi-device sharding
runs in a subprocess with 8 forced host devices because jax locks the
device count at first init (conftest.run_sub, shared with
tests/test_sharding.py); one subprocess checks 8-, 2- and 1-device
meshes, including the pad path (S=5 lanes never divide evenly).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_sub
from repro.continuum import (SimConfig, build_sim_fn, build_sim_grid_fn,
                             make_topology, run_sim_grid)

K, M, S = 8, 4, 5
CFG = SimConfig(horizon=6.0)
WARM = 20

single_device = pytest.mark.skipif(
    len(jax.devices()) != 1,
    reason="fallback tests need the default single-device process")


def _grid_inputs():
    rtts = jnp.stack([make_topology(jax.random.PRNGKey(s), K, M)
                      .lb_instance_rtt() for s in range(S)])
    keys = jnp.stack([jax.random.PRNGKey(100 + s) for s in range(S)])
    T = CFG.num_steps
    return rtts, keys, jnp.full((T, K), 4, jnp.int32), jnp.ones((T, M), bool)


@single_device
def test_single_device_fallback_is_the_vmap_program():
    """On a 1-device mesh the grid driver IS the vmapped streaming run:
    identical floats, not just close ones."""
    rtts, keys, n_clients, active = _grid_inputs()
    run = build_sim_fn("qedgeproxy", CFG, K, M, trace=False,
                       warmup_steps=WARM)
    ref = jax.jit(jax.vmap(run, in_axes=(0, None, None, 0)))(
        rtts, n_clients, active, keys)
    got = run_sim_grid("qedgeproxy", rtts, CFG, keys, n_clients=n_clients,
                       active=active, warmup_steps=WARM)
    for name, a, b in zip(ref.acc._fields, ref.acc, got.acc):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a),
                                      err_msg=f"acc field {name}")
    for name, a, b in zip(ref.series._fields, ref.series, got.series):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a),
                                      err_msg=f"series field {name}")


@single_device
def test_builder_returns_unwrapped_vmap_on_one_device():
    fn, mesh = build_sim_grid_fn("qedgeproxy", CFG, K, M,
                                 warmup_steps=WARM)
    assert int(mesh.devices.size) == 1
    rtts, keys, n_clients, active = _grid_inputs()
    out = jax.jit(fn)(rtts, n_clients, active, keys)
    assert out.acc.succ_kc.shape == (S, K, CFG.max_clients)
    assert out.series.succ.shape == (S, CFG.num_steps)


@pytest.mark.slow
def test_sharded_grid_matches_vmap_8dev():
    """8-, 2- and 1-device meshes against the full-width vmap reference,
    including the pad path (S=5 on D=8 pads 3 lanes, on D=2 pads 1)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.continuum import (SimConfig, build_sim_fn,
                                     make_topology, run_sim_grid)
        from repro.launch.mesh import make_grid_mesh

        K, M, S, WARM = 8, 4, 5, 20
        cfg = SimConfig(horizon=6.0)
        rtts = jnp.stack([make_topology(jax.random.PRNGKey(s), K, M)
                          .lb_instance_rtt() for s in range(S)])
        keys = jnp.stack([jax.random.PRNGKey(100 + s) for s in range(S)])
        T = cfg.num_steps
        n_clients = jnp.full((T, K), 4, jnp.int32)
        active = jnp.ones((T, M), bool)

        run = build_sim_fn("qedgeproxy", cfg, K, M, trace=False,
                           warmup_steps=WARM)
        ref = jax.jit(jax.vmap(run, in_axes=(0, None, None, 0)))(
            rtts, n_clients, active, keys)
        COUNTS = {"succ_kc", "n_kc", "arrivals_m", "choice_counts",
                  "proc_hist", "steps_measured"}
        for ndev in (8, 2, 1):
            mesh = make_grid_mesh(jax.devices()[:ndev])
            got = run_sim_grid("qedgeproxy", rtts, cfg, keys,
                               n_clients=n_clients, active=active,
                               warmup_steps=WARM, mesh=mesh)
            for name in ref.acc._fields:
                a = np.asarray(getattr(ref.acc, name))
                b = np.asarray(getattr(got.acc, name))
                if name in COUNTS:
                    np.testing.assert_array_equal(
                        b, a, err_msg=f"dev{ndev} acc.{name}")
                else:
                    np.testing.assert_allclose(
                        b, a, rtol=1e-5, atol=1e-5,
                        err_msg=f"dev{ndev} acc.{name}")
            np.testing.assert_array_equal(
                np.asarray(got.series.issued),
                np.asarray(ref.series.issued), err_msg=f"dev{ndev}")
            np.testing.assert_array_equal(
                np.asarray(got.series.succ),
                np.asarray(ref.series.succ), err_msg=f"dev{ndev}")
            np.testing.assert_allclose(
                np.asarray(got.series.regret),
                np.asarray(ref.series.regret), rtol=1e-4, atol=1e-4,
                err_msg=f"dev{ndev}")
            print(f"dev{ndev} parity ok")
        print("OK sharded parity")
    """)
    assert "OK sharded parity" in out
