"""Player-axis sharding parity: `run_sim_players` / 2-D `run_sim_grid`
vs the unsharded streaming engine.

The MP-MAB state factorizes over players; the ONLY cross-player
coupling is the instance-queue recursion, which the sharded engine
reproduces with a per-round (M,) arrival `psum`. Two engine invariants
make the sharded schedule decompose exactly: every per-player random
draw is keyed by global player id (repro.core.prand), and the staggered
maintenance clocks assign phases per contiguous player block
(`_stagger_groups`). Sharded results must therefore match the
unsharded engine: counting statistics (QoS counts, arrival/choice
histograms, the latency sketch, the event windows — integer-valued f32
sums, and the per-player float fields, which see no cross-shard
reduction at all) EXACTLY; only the psum-reduced regret series gets
f32 reassociation tolerance.

In-process tests cover the single-device fallback and the error paths;
real multi-device parity runs in a subprocess with 8 forced host
devices (conftest.run_sub). One subprocess checks 8-, 2- and 1-way
player meshes on two *dynamic* library scenarios (surge,
rolling_restart) for all three strategies, plus the composed 2-D
(data, players) grid with scenario-diverse lanes — including the
eagerly-padded lane path (S=3 on a 2-way data axis).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_sub
from repro.continuum import (SimConfig, build_sim_players_fn, make_topology,
                             run_sim_players, run_sim_stream)
from repro.launch.mesh import make_continuum_mesh

K, M = 16, 4
CFG = SimConfig(horizon=4.0)
WARM = 10

single_device = pytest.mark.skipif(
    len(jax.devices()) != 1,
    reason="fallback tests need the default single-device process")

# integer-valued f32 sums; sharding must not change them AT ALL
COUNTS = {"succ_kc", "n_kc", "arrivals_m", "choice_counts", "proc_hist",
          "steps_measured", "ev_succ", "ev_n"}


def _inputs():
    rtt = make_topology(jax.random.PRNGKey(0), K, M).lb_instance_rtt()
    return rtt, jax.random.PRNGKey(7)


@single_device
def test_single_device_fallback_is_the_streaming_program():
    """A 1-way players mesh returns the plain streaming program:
    identical floats, not just close ones."""
    rtt, key = _inputs()
    ref = run_sim_stream("qedgeproxy", rtt, CFG, key, warmup_steps=WARM)
    got = run_sim_players("qedgeproxy", rtt, CFG, key, warmup_steps=WARM)
    for name, a, b in zip(ref.acc._fields, ref.acc, got.acc):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a),
                                      err_msg=f"acc field {name}")
    for name, a, b in zip(ref.series._fields, ref.series, got.series):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a),
                                      err_msg=f"series field {name}")


@single_device
def test_builder_returns_plain_run_on_one_device():
    run, mesh = build_sim_players_fn("qedgeproxy", CFG, K, M,
                                     warmup_steps=WARM)
    assert dict(zip(mesh.axis_names,
                    mesh.devices.shape)).get("players", 1) == 1
    rtt, key = _inputs()
    from repro.continuum import neutral_drivers
    out = jax.jit(run)(rtt, neutral_drivers(CFG, K, M), key)
    assert out.acc.succ_kc.shape == (K, CFG.max_clients)
    assert out.series.succ.shape == (CFG.num_steps,)


def test_indivisible_players_axis_raises():
    """The players-axis size must divide K — a silent pad would issue
    phantom requests."""
    from repro.continuum.simulator import PlayerSharding, build_sim_parts
    with pytest.raises(ValueError, match="multiple"):
        build_sim_parts("qedgeproxy", CFG, 10, M, trace=False,
                        pshard=PlayerSharding("players", 4))


def test_player_sharding_is_streaming_only():
    from repro.continuum.simulator import PlayerSharding, build_sim_parts
    with pytest.raises(ValueError, match="streaming"):
        build_sim_parts("qedgeproxy", CFG, K, M, trace=True,
                        pshard=PlayerSharding("players", 4))


def test_continuum_mesh_shapes():
    devs = jax.devices()
    mesh = make_continuum_mesh(players=1, devices=devs)
    assert mesh.axis_names == ("data", "players")
    with pytest.raises(ValueError, match="divide"):
        make_continuum_mesh(players=3 * len(devs), devices=devs)


@pytest.mark.slow
def test_player_sharded_matches_unsharded_8dev():
    """8/2/1-way player meshes vs the unsharded streaming engine on two
    dynamic library scenarios, all three strategies: counting stats
    exact, psum-reduced regret series to f32 tolerance."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.continuum import (SimConfig, compile_scenario,
                                     get_library, make_topology,
                                     run_sim_players, run_sim_stream)
        from repro.launch.mesh import make_continuum_mesh

        K, M, WARM = 16, 4, 10
        cfg = SimConfig(horizon=4.0)
        rtt = make_topology(jax.random.PRNGKey(0), K, M).lb_instance_rtt()
        key = jax.random.PRNGKey(7)
        lib = get_library(cfg.horizon, K, M)
        COUNTS = {"succ_kc", "n_kc", "arrivals_m", "choice_counts",
                  "proc_hist", "steps_measured", "ev_succ", "ev_n"}
        for scn in ("surge", "rolling_restart"):
            drv = compile_scenario(lib[scn], cfg, jax.random.PRNGKey(3))
            for strat, kw in (("qedgeproxy", {}), ("dec_sarsa", {}),
                              ("proxy_mity", dict(alpha=0.9))):
                ref = run_sim_stream(strat, rtt, cfg, key, drivers=drv,
                                     warmup_steps=WARM, **kw)
                for D in (8, 2, 1):
                    mesh = make_continuum_mesh(
                        players=D, devices=jax.devices()[:D])
                    got = run_sim_players(
                        strat, rtt, cfg, key, drivers=drv,
                        warmup_steps=WARM, mesh=mesh, **kw)
                    for name in ref.acc._fields:
                        a = np.asarray(getattr(ref.acc, name))
                        b = np.asarray(getattr(got.acc, name))
                        if name in COUNTS:
                            np.testing.assert_array_equal(
                                b, a, err_msg=f"{scn} {strat} D{D} {name}")
                        else:
                            np.testing.assert_allclose(
                                b, a, rtol=1e-5, atol=1e-5,
                                err_msg=f"{scn} {strat} D{D} {name}")
                    np.testing.assert_array_equal(
                        np.asarray(got.series.succ),
                        np.asarray(ref.series.succ),
                        err_msg=f"{scn} {strat} D{D} series.succ")
                    np.testing.assert_array_equal(
                        np.asarray(got.series.issued),
                        np.asarray(ref.series.issued),
                        err_msg=f"{scn} {strat} D{D} series.issued")
                    np.testing.assert_allclose(
                        np.asarray(got.series.regret),
                        np.asarray(ref.series.regret),
                        rtol=1e-4, atol=1e-4,
                        err_msg=f"{scn} {strat} D{D} series.regret")
                print(scn, strat, "player parity ok")
        print("OK player parity")
    """)
    assert "OK player parity" in out


@pytest.mark.slow
def test_resilient_sharded_matches_unsharded_8dev():
    """Breaker/retry state shards on the players axis with no new
    collectives: the per-player attempt/timeout/drop counters and the
    (K, M) breaker-open occupancy are exact at 8/2/1 shards, on all
    three strategies, under a scenario that actually trips timeouts."""
    out = run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.continuum import (SimConfig, compile_scenario,
                                     get_library, make_topology,
                                     run_sim_players, run_sim_stream)
        from repro.launch.mesh import make_continuum_mesh

        K, M, WARM = 16, 4, 10
        cfg = SimConfig(horizon=4.0, attempt_timeout=0.055, max_retries=2,
                        retry_backoff=0.002, breaker_threshold=4,
                        breaker_cooldown=1.0)
        rtt = make_topology(jax.random.PRNGKey(0), K, M).lb_instance_rtt()
        key = jax.random.PRNGKey(7)
        lib = get_library(cfg.horizon, K, M)
        drv = compile_scenario(lib["hetero_slowdown"], cfg,
                               jax.random.PRNGKey(3))
        COUNTS = {"succ_kc", "n_kc", "arrivals_m", "choice_counts",
                  "proc_hist", "steps_measured", "ev_succ", "ev_n",
                  "att_k", "timeout_k", "drop_k", "open_km"}
        for strat, kw in (("qedgeproxy", {}), ("dec_sarsa", {}),
                          ("proxy_mity", dict(alpha=0.9))):
            ref = run_sim_stream(strat, rtt, cfg, key, drivers=drv,
                                 warmup_steps=WARM, **kw)
            assert float(np.asarray(ref.acc.timeout_k).sum()) > 0, \\
                "scenario must trip timeouts for this test to bite"
            for D in (8, 2, 1):
                mesh = make_continuum_mesh(
                    players=D, devices=jax.devices()[:D])
                got = run_sim_players(
                    strat, rtt, cfg, key, drivers=drv,
                    warmup_steps=WARM, mesh=mesh, **kw)
                for name in ref.acc._fields:
                    a = np.asarray(getattr(ref.acc, name))
                    b = np.asarray(getattr(got.acc, name))
                    if name in COUNTS:
                        np.testing.assert_array_equal(
                            b, a, err_msg=f"{strat} D{D} {name}")
                    else:
                        np.testing.assert_allclose(
                            b, a, rtol=1e-5, atol=1e-5,
                            err_msg=f"{strat} D{D} {name}")
                np.testing.assert_array_equal(
                    np.asarray(got.series.attempts),
                    np.asarray(ref.series.attempts),
                    err_msg=f"{strat} D{D} series.attempts")
            print(strat, "resilient parity ok")
        print("OK resilient parity")
    """)
    assert "OK resilient parity" in out


@pytest.mark.slow
def test_2d_grid_composition_matches_vmap_8dev():
    """The composed 2-D (data, players) grid: scenario-diverse lanes
    over `data`, every lane's K players over `players`, against the
    plain vmap reference — 2x4, 4x2 and 2x2 meshes, S=3 lanes so the
    eager lane-pad path is exercised on every data axis > 1."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.continuum import (SimConfig, build_sim_fn,
                                     compile_scenario, get_library,
                                     make_topology, run_sim_grid,
                                     stack_drivers)
        from repro.launch.mesh import make_continuum_mesh

        K, M, S, WARM = 16, 4, 3, 10
        cfg = SimConfig(horizon=3.0)
        rtts = jnp.stack([make_topology(jax.random.PRNGKey(s), K, M)
                          .lb_instance_rtt() for s in range(S)])
        keys = jnp.stack([jax.random.PRNGKey(100 + s) for s in range(S)])
        lib = list(get_library(cfg.horizon, K, M).values())
        drivers = stack_drivers(
            [compile_scenario(lib[i % len(lib)], cfg,
                              jax.random.PRNGKey(i)) for i in range(S)])
        run = build_sim_fn("qedgeproxy", cfg, K, M, trace=False,
                           warmup_steps=WARM)
        ref = jax.jit(jax.vmap(run, in_axes=(0, 0, 0)))(
            rtts, drivers, keys)
        COUNTS = {"succ_kc", "n_kc", "arrivals_m", "choice_counts",
                  "proc_hist", "steps_measured", "ev_succ", "ev_n"}
        for dd, dp in ((2, 4), (4, 2), (2, 2)):
            mesh = make_continuum_mesh(players=dp,
                                       devices=jax.devices()[:dd * dp])
            got = run_sim_grid("qedgeproxy", rtts, cfg, keys,
                               drivers=drivers, warmup_steps=WARM,
                               mesh=mesh)
            for name in ref.acc._fields:
                a = np.asarray(getattr(ref.acc, name))
                b = np.asarray(getattr(got.acc, name))
                if name in COUNTS:
                    np.testing.assert_array_equal(
                        b, a, err_msg=f"{dd}x{dp} acc.{name}")
                else:
                    np.testing.assert_allclose(
                        b, a, rtol=1e-5, atol=1e-5,
                        err_msg=f"{dd}x{dp} acc.{name}")
            np.testing.assert_array_equal(
                np.asarray(got.series.succ), np.asarray(ref.series.succ),
                err_msg=f"{dd}x{dp} series.succ")
            np.testing.assert_allclose(
                np.asarray(got.series.regret),
                np.asarray(ref.series.regret), rtol=1e-4, atol=1e-4,
                err_msg=f"{dd}x{dp} series.regret")
            print(f"mesh {dd}x{dp} grid parity ok")
        print("OK 2d grid parity")
    """)
    assert "OK 2d grid parity" in out
