"""Streaming-vs-trace equivalence for the simulation engine.

The same seed is run both ways; every streaming accumulator / series
statistic must match the corresponding metric computed post-hoc from
the full ``trace=True`` trajectory to float32 tolerance. Counts (QoS
successes, arrivals, routing histograms) are integer-valued float32
sums, so they must match exactly; regret and the variation budget are
genuine float accumulations, so they get float32 tolerance; the
latency-quantile sketch is bin-resolution by design and is checked
against the exact percentile within the documented bin spacing.

The chunked driver must reproduce the unchunked streaming run exactly:
same per-step program, same PRNG stream, only the scan boundaries move.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.continuum import (SimConfig, client_qos_satisfaction,
                             client_qos_satisfaction_stream, compile_scenario,
                             cumulative_regret, cumulative_regret_series,
                             event_recovery, event_windows_from_series,
                             get_library, jain_fairness, jain_fairness_stream,
                             make_topology, p90_proc_latency,
                             per_client_success, per_client_success_stream,
                             per_lb_request_distribution,
                             per_lb_request_distribution_stream,
                             proc_latency_quantile_stream,
                             request_rate_per_instance,
                             request_rate_per_instance_stream, rolling_qos,
                             rolling_qos_series, run_sim, run_sim_stream,
                             variation_budget_emp, variation_budget_stream)

CFG = SimConfig(horizon=15.0)
WARM = 50                       # 5 s of the 15 s horizon
K, M = 8, 4
WIN = int(CFG.window / CFG.dt)


@pytest.fixture(scope="module")
def rtt():
    return make_topology(jax.random.PRNGKey(2), K, M).lb_instance_rtt()


def _both(rtt, name, **kw):
    # run_sim donates its inputs: hand each run its own key array
    trace = run_sim(name, rtt, CFG, jax.random.PRNGKey(5), **kw)
    stream = run_sim_stream(name, rtt, CFG, jax.random.PRNGKey(5),
                            warmup_steps=WARM, **kw)
    return trace, stream


@pytest.fixture(scope="module")
def qep(rtt):
    return _both(rtt, "qedgeproxy")


@pytest.fixture(scope="module")
def sarsa(rtt):
    """Dec-SARSA exercises the sequential (non-fused) streaming path."""
    return _both(rtt, "dec_sarsa")


def test_per_client_success_matches(qep):
    trace, stream = qep
    want, want_present = per_client_success(trace, WARM)
    got, got_present = per_client_success_stream(stream.acc)
    np.testing.assert_array_equal(got_present, want_present)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_qos_satisfaction_matches(qep):
    trace, stream = qep
    assert client_qos_satisfaction_stream(stream.acc, CFG.rho) == \
        client_qos_satisfaction(trace, CFG.rho, WARM)


def test_arrival_histogram_matches(qep):
    trace, stream = qep
    want = np.asarray(trace.arrivals)[WARM:].sum(0)
    np.testing.assert_allclose(np.asarray(stream.acc.arrivals_m), want,
                               atol=1e-5)
    assert jain_fairness_stream(stream.acc) == \
        pytest.approx(jain_fairness(trace, warmup_steps=WARM), rel=1e-6)
    np.testing.assert_allclose(
        request_rate_per_instance_stream(stream.acc, CFG.dt),
        request_rate_per_instance(trace, CFG.dt, WARM), rtol=1e-6)


def test_choice_histogram_matches(qep):
    trace, stream = qep
    ch = np.asarray(trace.choices)[WARM:]
    m = np.asarray(trace.issued)[WARM:]
    for lb in range(K):
        want = np.bincount(ch[:, lb][m[:, lb]], minlength=M)
        np.testing.assert_allclose(
            np.asarray(stream.acc.choice_counts)[lb], want, atol=1e-5,
            err_msg=f"lb {lb}")
        np.testing.assert_allclose(
            per_lb_request_distribution_stream(stream.acc, lb),
            per_lb_request_distribution(trace, lb, WARM), atol=1e-6)


def test_rolling_qos_matches(qep):
    trace, stream = qep
    np.testing.assert_allclose(rolling_qos_series(stream.series, WIN),
                               rolling_qos(trace, WIN), atol=1e-6)


def test_regret_matches(qep):
    trace, stream = qep
    want = cumulative_regret(trace)
    got = cumulative_regret_series(stream.series)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # the (K,) accumulator splits the same total by player
    np.testing.assert_allclose(np.asarray(stream.acc.regret_k).sum(),
                               want[-1], rtol=1e-4, atol=1e-4)


def test_variation_budget_matches(qep):
    trace, stream = qep
    np.testing.assert_allclose(variation_budget_stream(stream.acc),
                               variation_budget_emp(trace),
                               rtol=1e-4, atol=1e-5)


def test_latency_sketch_within_bin_resolution(qep):
    trace, stream = qep
    want = p90_proc_latency(trace, WARM)
    got = proc_latency_quantile_stream(stream.acc, 0.9)
    present = np.asarray(stream.acc.arrivals_m) > 0
    # geometric bins at ~9.5% spacing: the sketch readout may be off by
    # up to one bin from the interpolated exact percentile
    np.testing.assert_allclose(got[present], want[present], rtol=0.15)
    assert (got[~present] == 0).all()


def test_steps_measured(qep):
    _, stream = qep
    assert float(stream.acc.steps_measured) == CFG.num_steps - WARM


def test_chunked_matches_unchunked(rtt):
    full = run_sim_stream("qedgeproxy", rtt, CFG, jax.random.PRNGKey(5),
                          warmup_steps=WARM)
    # 64 does not divide T=150: exercises the remainder-chunk compile
    chunked = run_sim_stream("qedgeproxy", rtt, CFG, jax.random.PRNGKey(5),
                             warmup_steps=WARM, chunk_steps=64)
    for name, a, b in zip(full.acc._fields, full.acc, chunked.acc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   err_msg=f"acc field {name}")
    for name, a, b in zip(full.series._fields, full.series, chunked.series):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   err_msg=f"series field {name}")


def test_sequential_strategy_streams(sarsa):
    """The non-fused request path (Dec-SARSA) streams identically."""
    trace, stream = sarsa
    assert client_qos_satisfaction_stream(stream.acc, CFG.rho) == \
        client_qos_satisfaction(trace, CFG.rho, WARM)
    np.testing.assert_allclose(rolling_qos_series(stream.series, WIN),
                               rolling_qos(trace, WIN), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(stream.acc.arrivals_m),
        np.asarray(trace.arrivals)[WARM:].sum(0), atol=1e-5)


# ---------------------------------------------------------------------------
# Dynamic-scenario parity: the same stream==trace guarantees must hold
# when the drivers vary every step (surge + failure + RTT drift +
# per-instance slowdown + churn all at once), and the event-relative
# recovery windows must equal their post-hoc reference.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dynamic(rtt):
    scn = get_library(CFG.horizon, K, M)["everything"]
    drv = compile_scenario(scn, CFG, jax.random.PRNGKey(9))
    trace = run_sim("qedgeproxy", rtt, CFG, jax.random.PRNGKey(5),
                    drivers=drv)
    stream = run_sim_stream("qedgeproxy", rtt, CFG, jax.random.PRNGKey(5),
                            drivers=drv, warmup_steps=WARM)
    return trace, stream, drv


def test_dynamic_scenario_stream_matches_trace(dynamic):
    trace, stream, _ = dynamic
    want, want_present = per_client_success(trace, WARM)
    got, got_present = per_client_success_stream(stream.acc)
    np.testing.assert_array_equal(got_present, want_present)
    np.testing.assert_allclose(got, want, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(stream.acc.arrivals_m),
        np.asarray(trace.arrivals)[WARM:].sum(0), atol=1e-5)
    np.testing.assert_allclose(rolling_qos_series(stream.series, WIN),
                               rolling_qos(trace, WIN), atol=1e-6)
    np.testing.assert_allclose(cumulative_regret_series(stream.series),
                               cumulative_regret(trace), rtol=1e-4,
                               atol=1e-4)
    # a dynamic scenario must actually move the variation budget
    assert float(np.asarray(stream.acc.vb_k).sum()) > 0.1
    np.testing.assert_allclose(variation_budget_stream(stream.acc),
                               variation_budget_emp(trace),
                               rtol=1e-4, atol=1e-5)


def test_dynamic_scenario_chunked_matches(rtt, dynamic):
    _, full, drv = dynamic
    chunked = run_sim_stream("qedgeproxy", rtt, CFG, jax.random.PRNGKey(5),
                             drivers=drv, warmup_steps=WARM, chunk_steps=64)
    for name, a, b in zip(full.acc._fields, full.acc, chunked.acc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   err_msg=f"acc field {name}")


def test_event_windows_match_series_reference(dynamic):
    trace, stream, drv = dynamic
    succ = (np.asarray(trace.rewards) * np.asarray(trace.issued)).sum((1, 2))
    issued = np.asarray(trace.issued).sum((1, 2)).astype(np.float64)
    pre = int(round(CFG.ev_pre / CFG.dt))
    bstep = int(round(CFG.ev_bucket / CFG.dt))
    want_s, want_n = event_windows_from_series(
        succ, issued, np.asarray(drv.marks), pre, bstep, CFG.ev_buckets)
    np.testing.assert_allclose(np.asarray(stream.acc.ev_succ), want_s,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(stream.acc.ev_n), want_n,
                               atol=1e-5)
    # the readout produces one entry per data-bearing mark
    rec = event_recovery(stream.acc, CFG.ev_bucket)
    n_real = int((np.asarray(drv.marks) >= 0).sum())
    assert 0 < len(rec) <= n_real
    for r in rec:
        assert 0.0 <= r["dip"] <= 1.0
        assert (r["recovery_s"] is None) == (not r["recovered"])
        if r["recovered"]:
            assert r["recovery_s"] >= 0.0


def test_no_marks_means_empty_event_stats(qep):
    """Legacy driver paths (no scenario) leave the windows zero."""
    _, stream = qep
    assert float(np.abs(np.asarray(stream.acc.ev_n)).sum()) == 0.0
    assert event_recovery(stream.acc, CFG.ev_bucket) == []


# ---------------------------------------------------------------------------
# Resilience-layer parity: the attempt/timeout/drop counters and the
# breaker-state carry must stream, and must survive chunk boundaries
# (the breaker joins the donated carry) exactly like every other field.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def resilient(rtt):
    import dataclasses
    cfg = dataclasses.replace(CFG, attempt_timeout=0.055, max_retries=2,
                              retry_backoff=0.002, breaker_threshold=4,
                              breaker_cooldown=1.0)
    scn = get_library(CFG.horizon, K, M)["everything"]
    drv = compile_scenario(scn, cfg, jax.random.PRNGKey(9))
    trace = run_sim("qedgeproxy", rtt, cfg, jax.random.PRNGKey(5),
                    drivers=drv)
    stream = run_sim_stream("qedgeproxy", rtt, cfg, jax.random.PRNGKey(5),
                            drivers=drv, warmup_steps=WARM)
    return cfg, drv, trace, stream


def test_resilient_stream_matches_trace(resilient):
    from repro.continuum.metrics import (resilience_stats,
                                         resilience_stats_stream)
    cfg, _, trace, stream = resilient
    att = np.asarray(trace.attempts)[WARM:]
    drop = np.asarray(trace.dropped)[WARM:]
    iss = np.asarray(trace.issued)[WARM:]
    np.testing.assert_allclose(np.asarray(stream.acc.att_k),
                               att.sum((0, 2)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(stream.acc.drop_k),
                               drop.sum((0, 2)), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(stream.acc.timeout_k),
        (att - (iss & ~drop)).sum((0, 2)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(stream.series.attempts),
                               np.asarray(trace.attempts).sum((1, 2)),
                               atol=1e-5)
    a = resilience_stats(trace, WARM)
    b = resilience_stats_stream(stream.acc)
    for k in a:
        assert a[k] == pytest.approx(b[k], rel=1e-5, abs=1e-6), k
    # QoS parity holds under censoring too (drops carry the sentinel)
    assert client_qos_satisfaction_stream(stream.acc, cfg.rho) == \
        client_qos_satisfaction(trace, cfg.rho, WARM)


def test_resilient_chunked_matches(rtt, resilient):
    cfg, drv, _, full = resilient
    chunked = run_sim_stream("qedgeproxy", rtt, cfg, jax.random.PRNGKey(5),
                             drivers=drv, warmup_steps=WARM, chunk_steps=64)
    for name, a, b in zip(full.acc._fields, full.acc, chunked.acc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   err_msg=f"acc field {name}")
    for name, a, b in zip(full.series._fields, full.series, chunked.series):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   err_msg=f"series field {name}")
