"""Request-lifecycle resilience: timeouts, censored feedback, bounded
retries, circuit breakers — and the two invariants the layer ships
under: the neutral config traces the byte-identical pre-resilience
program (checked against a committed HEAD reference), and a
checkpointed-and-resumed chunked run reproduces the uninterrupted run
exactly.

The committed golden `tests/data/neutral_stream_ref.npz` holds the
full streaming accumulator + per-step series of the pre-resilience
engine (all three strategies, K=10 M=4, horizon 12 s). Bit-identity is
structural — `attempt_timeout == 0` is a Python-level static, so the
neutral trace never touches resilience code — but this test pins it
against drift.
"""
import dataclasses
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.continuum import SimConfig, make_topology, run_sim, run_sim_stream
from repro.continuum.metrics import (breaker_open_fraction_stream,
                                     goodput_offered_series,
                                     resilience_stats, resilience_stats_stream)
from repro.core import bandit as qb

K, M = 10, 4
CFG = SimConfig(horizon=12.0)
WARM = 30
STRATEGIES = (("qedgeproxy", {}), ("proxy_mity", dict(alpha=0.9)),
              ("dec_sarsa", {}))
REF = os.path.join(os.path.dirname(__file__), "data",
                   "neutral_stream_ref.npz")
# deadline-bounded policy at this testbed's scale (timeout between the
# healthy tail and tau, budget left for one in-deadline retry)
RES = dict(attempt_timeout=0.055, max_retries=2, retry_backoff=0.002,
           breaker_threshold=4, breaker_cooldown=1.0)


def _inputs():
    rtt = make_topology(jax.random.PRNGKey(2), K, M).lb_instance_rtt()
    return rtt, jax.random.PRNGKey(5)


# -- invariant 1: neutral config is the HEAD engine, bit for bit ------

@pytest.mark.parametrize("strat,kw", STRATEGIES,
                         ids=[s for s, _ in STRATEGIES])
def test_neutral_bit_identity_vs_head(strat, kw):
    rtt, key = _inputs()
    ref = np.load(REF)
    out = run_sim_stream(strat, rtt, CFG, key, warmup_steps=WARM, **kw)
    for f in out.acc._fields:
        got = np.asarray(getattr(out.acc, f))
        if f"{strat}.acc.{f}" in ref.files:
            np.testing.assert_array_equal(got, ref[f"{strat}.acc.{f}"],
                                          err_msg=f"{strat} acc.{f}")
    for f in out.series._fields:
        got = np.asarray(getattr(out.series, f))
        if f"{strat}.series.{f}" in ref.files:
            np.testing.assert_array_equal(got, ref[f"{strat}.series.{f}"],
                                          err_msg=f"{strat} series.{f}")
    # the new counters exist but are inert in the neutral program
    np.testing.assert_array_equal(np.asarray(out.acc.att_k),
                                  np.asarray(out.acc.n_kc).sum(-1))
    assert float(np.asarray(out.acc.timeout_k).sum()) == 0.0
    assert float(np.asarray(out.acc.drop_k).sum()) == 0.0
    assert float(np.asarray(out.acc.open_km).sum()) == 0.0
    np.testing.assert_array_equal(np.asarray(out.series.attempts),
                                  np.asarray(out.series.issued))


def test_resilience_knobs_need_timeout():
    rtt, key = _inputs()
    with pytest.raises(ValueError, match="attempt_timeout"):
        run_sim("qedgeproxy", rtt,
                dataclasses.replace(CFG, max_retries=2), key)


# -- timeout / retry / drop semantics ---------------------------------

def test_unreachable_timeout_matches_neutral_trace():
    """With a timeout no latency ever crosses, the resilient program
    must reproduce the neutral trace value-for-value: attempt 0 uses
    the exact neutral PRNG derivation and retries never fire."""
    rtt, key = _inputs()
    ref = run_sim("qedgeproxy", rtt, CFG, key)
    got = run_sim("qedgeproxy", rtt,
                  dataclasses.replace(CFG, attempt_timeout=10.0,
                                      max_retries=2), key)
    iss = np.asarray(ref.issued)
    np.testing.assert_array_equal(np.asarray(got.issued), iss)
    np.testing.assert_array_equal(np.asarray(got.choices)[iss],
                                  np.asarray(ref.choices)[iss])
    # unissued slots are meaningless (neutral: raw noise draw,
    # resilient: censor sentinel) — compare where a request exists
    np.testing.assert_array_equal(np.asarray(got.latency)[iss],
                                  np.asarray(ref.latency)[iss])
    np.testing.assert_array_equal(np.asarray(got.rewards)[iss],
                                  np.asarray(ref.rewards)[iss])
    np.testing.assert_array_equal(np.asarray(got.attempts),
                                  iss.astype(np.int32))
    assert not np.asarray(got.dropped).any()


def test_censored_feedback_semantics():
    """A timed-out attempt yields only a lower bound: the trace records
    the static censor sentinel (> tau, so reward 0 with no special
    case) and the KDE sees a pessimistic point mass past the deadline."""
    censor = qb.censored_latency(0.02, CFG.tau)
    assert censor > CFG.tau and censor >= 0.02 + CFG.tau
    rtt, key = _inputs()
    # timeout below the minimum RTT: every attempt times out, every
    # request exhausts its budget and drops
    out = run_sim("qedgeproxy", rtt,
                  dataclasses.replace(CFG, attempt_timeout=1e-4,
                                      max_retries=1), key)
    iss = np.asarray(out.issued)
    assert np.asarray(out.dropped)[iss].all()
    np.testing.assert_allclose(np.asarray(out.latency)[iss],
                               qb.censored_latency(1e-4, CFG.tau))
    assert np.asarray(out.rewards)[iss].max() == 0.0
    st = resilience_stats(out, WARM)
    assert st["timeout_rate"] == pytest.approx(1.0)
    assert st["drop_rate"] == pytest.approx(1.0)


def test_censored_kde_update_is_pessimistic():
    """Recording the censor sentinel drives the arm's P(lat <= tau)
    estimate down — the safe direction for a lower bound."""
    params = qb.BanditParams(tau=CFG.tau)
    state = qb.init_state(1, 2, params, key=jax.random.PRNGKey(0))
    censor = jnp.float32(qb.censored_latency(0.055, params.tau))
    good = jnp.float32(0.01)
    for i in range(8):
        t = jnp.float32(0.1 * i)
        state = qb.record(state, params, jnp.zeros((1,), jnp.int32),
                          censor[None], t, jnp.ones((1,), bool))
        state = qb.record(state, params, jnp.ones((1,), jnp.int32),
                          good[None], t, jnp.ones((1,), bool))
    state = qb.maintenance(state, params, jnp.zeros((1, 2), jnp.float32),
                           jnp.float32(1.0))
    mu = np.asarray(state.mu_hat)[0]
    assert mu[0] < 0.2 < 0.8 < mu[1], mu


def test_bounded_vs_naive_amplification():
    """On an overloaded fleet the deadline budget caps amplification;
    the naive policy (no budget) multiplies offered load."""
    rtt, key = _inputs()
    slow = dataclasses.replace(CFG, service_time=0.012)
    bounded = run_sim_stream(
        "qedgeproxy", rtt, dataclasses.replace(slow, **RES), key,
        warmup_steps=WARM)
    naive = run_sim_stream(
        "qedgeproxy", rtt,
        dataclasses.replace(slow, attempt_timeout=0.055, max_retries=5,
                            retry_deadline=False), key,
        warmup_steps=WARM)
    sb = resilience_stats_stream(bounded.acc)
    sn = resilience_stats_stream(naive.acc)
    assert sb["requests"] == sn["requests"]
    assert sb["retry_rate"] <= 1.0 + 1e-6          # deadline-capped
    assert sn["retry_rate"] > 2 * sb["retry_rate"]  # amplification
    good, offered = goodput_offered_series(naive.series, CFG.dt, 10)
    assert (offered >= good - 1e-6).all()


def test_stream_trace_parity_resilient():
    rtt, key = _inputs()
    cfg = dataclasses.replace(CFG, **RES)
    tr = run_sim("qedgeproxy", rtt, cfg, key)
    st = run_sim_stream("qedgeproxy", rtt, cfg, key, warmup_steps=WARM)
    a = resilience_stats(tr, WARM)
    b = resilience_stats_stream(st.acc)
    for k in a:
        assert a[k] == pytest.approx(b[k], rel=1e-5, abs=1e-6), k
    frac = breaker_open_fraction_stream(st.acc)
    assert frac.shape == (K, M) and float(frac.max()) <= 1.0


# -- circuit breaker unit behaviour -----------------------------------

def test_breaker_state_machine():
    thr, cd = 3, 2.0
    brk = qb.breaker_init(1, 2)
    choice = jnp.zeros((1,), jnp.int32)
    yes = jnp.ones((1,), bool)
    t0 = jnp.float32(1.0)
    for _ in range(thr - 1):
        brk = qb.breaker_update(brk, choice, yes, yes, t0, thr, cd)
    assert not bool(qb.breaker_is_open(brk, t0)[0, 0])
    brk = qb.breaker_update(brk, choice, yes, yes, t0, thr, cd)   # trips
    assert bool(qb.breaker_is_open(brk, t0)[0, 0])
    assert not bool(qb.breaker_is_open(brk, t0 + cd + 1e-3)[0, 0])
    # half-open: one more failure re-trips immediately
    brk = qb.breaker_update(brk, choice, yes, yes, t0 + cd + 0.1, thr, cd)
    assert bool(qb.breaker_is_open(brk, t0 + cd + 0.2)[0, 0])
    # a success fully closes and resets the strike count
    brk = qb.breaker_update(brk, choice, jnp.zeros((1,), bool), yes,
                            t0 + 2 * cd + 0.2, thr, cd)
    assert not bool(qb.breaker_is_open(brk, t0 + 2 * cd + 0.3)[0, 0])
    assert int(np.asarray(brk.fails)[0, 0]) == 0
    # untouched arm never moved
    assert int(np.asarray(brk.fails)[0, 1]) == 0


def test_breaker_veto_and_retry_pick():
    w = jnp.array([[0.9, 0.1, 0.0]])
    active = jnp.array([True, True, True])
    g = jnp.zeros((1, 3))
    brk = qb.breaker_init(1, 3)
    open_arm0 = qb.BreakerState(
        fails=brk.fails, open_until=brk.open_until.at[:, 0].set(jnp.inf))
    t = jnp.float32(0.0)
    # veto re-routes an open choice to the best closed arm
    ch = qb.breaker_veto(jnp.zeros((1,), jnp.int32), open_arm0, t, w,
                         active, g, jnp.ones((1,), bool))
    assert int(ch[0]) == 1
    # fail-open: every active arm ejected -> keep the original choice
    all_open = qb.BreakerState(fails=brk.fails,
                               open_until=jnp.full((1, 3), jnp.inf))
    ch = qb.breaker_veto(jnp.zeros((1,), jnp.int32), all_open, t, w,
                         active, g, jnp.ones((1,), bool))
    assert int(ch[0]) == 0
    # retry never lands on the arm that just timed out
    open_now = qb.breaker_is_open(open_arm0, t)
    alt = qb.retry_pick(w, active, jnp.ones((1,), jnp.int32), open_now, g)
    assert int(alt[0]) == 2          # arm 0 open, arm 1 just failed
    # ...unless there is literally nowhere else to go
    alt = qb.retry_pick(w, jnp.array([False, True, False]),
                        jnp.ones((1,), jnp.int32),
                        qb.breaker_is_open(brk, t), g)
    assert int(alt[0]) == 1


# -- invariant 2: killed-and-resumed == uninterrupted, exactly --------

def test_checkpoint_resume_exact(tmp_path):
    """Chunked run checkpointed every chunk, killed mid-horizon via
    stop_at_step, resumed from disk: every accumulator and series field
    equals the uninterrupted run bit-for-bit — including the breaker
    state in the carry, and under a DIFFERENT resumed chunk length."""
    rtt, key = _inputs()
    cfg = dataclasses.replace(CFG, **RES)
    d = str(tmp_path / "ck")
    full = run_sim_stream("qedgeproxy", rtt, cfg, key, warmup_steps=WARM,
                          chunk_steps=40)
    part = run_sim_stream("qedgeproxy", rtt, cfg, key, warmup_steps=WARM,
                          chunk_steps=40, checkpoint_dir=d,
                          stop_at_step=80)
    assert len(np.asarray(part.series.succ)) == 80
    res = run_sim_stream("qedgeproxy", rtt, cfg, key, warmup_steps=WARM,
                         chunk_steps=25, checkpoint_dir=d, resume=True)
    for f in full.acc._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res.acc, f)),
            np.asarray(getattr(full.acc, f)), err_msg=f"acc.{f}")
    for f in full.series._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res.series, f)),
            np.asarray(getattr(full.series, f)), err_msg=f"series.{f}")
    shutil.rmtree(d)


def test_checkpoint_needs_chunked_loop():
    rtt, key = _inputs()
    with pytest.raises(ValueError, match="chunk"):
        run_sim_stream("qedgeproxy", rtt, CFG, key, checkpoint_dir="/tmp/x")
