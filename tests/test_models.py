"""Per-arch smoke tests (reduced configs, one forward/train step on CPU,
shape + finiteness assertions) and prefill+decode == full-forward
equivalence in f32 — the serving-correctness contract.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_shape
from repro.models import build_model


def _batch_for(cfg, model, S=32, B=2, train=True, key=None):
    key = key or jax.random.PRNGKey(1)
    if cfg.family == "audio":
        Sd = min(cfg.max_decode_len, S)
        b = {"frames": jax.random.normal(key, (B, S // 2, cfg.d_model),
                                         jnp.float32),
             "tokens": jax.random.randint(key, (B, Sd), 0, cfg.vocab_size)}
        if train:
            b["targets"] = jax.random.randint(key, (B, Sd), 0,
                                              cfg.vocab_size)
    elif cfg.family == "vlm":
        St = S - cfg.num_patches
        b = {"patches": jax.random.normal(key, (B, cfg.num_patches,
                                                cfg.d_model), jnp.float32),
             "tokens": jax.random.randint(key, (B, St), 0, cfg.vocab_size)}
        if train:
            b["targets"] = jax.random.randint(key, (B, St), 0,
                                              cfg.vocab_size)
    else:
        b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
        if train:
            b["targets"] = jax.random.randint(key, (B, S), 0,
                                              cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + loss + grad step, outputs finite."""
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, model, S=32, B=2)
    logits, aux = model.forward(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.abs(g.astype(jnp.float32)).sum())
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_axes_mirror_params(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    axes = model.param_axes()
    from repro.sharding.partitioning import is_axes_leaf
    s1 = jax.tree.structure(params)
    s2 = jax.tree.structure(axes, is_leaf=is_axes_leaf)
    assert s1 == s2
    # every leaf's axis tuple must match its rank
    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.leaves(axes, is_leaf=is_axes_leaf)
    for p, a in zip(flat_p, flat_a):
        assert len(a) == len(p.shape), (a, p.shape)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_matches_full_forward(arch):
    S, B = 24, 2
    over = dict(dtype="float32")
    cfg0 = get_config(arch, reduced=True)
    if cfg0.is_moe:
        over["moe_capacity_factor"] = 8.0     # dropless => exact
    cfg = dataclasses.replace(cfg0, **over)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)

    if cfg.family == "audio":
        Sd = min(cfg.max_decode_len, S)
        frames = jax.random.normal(key, (B, S // 2, cfg.d_model), jnp.float32)
        toks = jax.random.randint(key, (B, Sd), 0, cfg.vocab_size)
        full, _ = model.forward(params, {"frames": frames, "tokens": toks})
        _, cache = model.prefill(params,
                                 {"frames": frames, "tokens": toks[:, :-1]})
        lg, _ = model.decode(params, cache,
                             {"token": toks[:, -1:], "pos": jnp.int32(Sd - 1)})
    elif cfg.family == "vlm":
        P = cfg.num_patches
        patches = jax.random.normal(key, (B, P, cfg.d_model), jnp.float32)
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        full, _ = model.forward(params, {"patches": patches, "tokens": toks})
        _, cache = model.prefill(
            params, {"patches": patches, "tokens": toks[:, :-1]},
            max_len=P + S)
        lg, _ = model.decode(params, cache, {"token": toks[:, -1:],
                                             "pos": jnp.int32(P + S - 1)})
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        full, _ = model.forward(params, {"tokens": toks})
        _, cache = model.prefill(params, {"tokens": toks[:, :-1]}, max_len=S)
        lg, _ = model.decode(params, cache, {"token": toks[:, -1:],
                                             "pos": jnp.int32(S - 1)})
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


def test_gemma3_local_global_pattern():
    cfg = get_config("gemma3-1b")
    kinds = cfg.layer_kinds()
    assert len(kinds) == 26
    assert kinds.count("global") == 4
    assert kinds[:6] == ("local",) * 5 + ("global",)


def test_sliding_window_limits_attention():
    """Tokens beyond the window must not influence the output."""
    cfg = dataclasses.replace(get_config("gemma3-1b", reduced=True),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0,
                              cfg.vocab_size)
    toks2 = toks.at[:, 0].set((toks[:, 0] + 7) % cfg.vocab_size)
    l1, _ = model.forward(params, {"tokens": toks})
    l2, _ = model.forward(params, {"tokens": toks2})
    # global layers exist, so late tokens DO differ...
    assert float(jnp.abs(l1[:, -1] - l2[:, -1]).max()) > 0
    # ...but a pure-local stack would not: check window masking directly
    from repro.kernels import ref
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 2, S, 8))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 2, S, 8))
    v = jax.random.normal(jax.random.PRNGKey(4), (1, 2, S, 8))
    o1 = ref.attention(q, k, v, causal=True, window=8)
    k2 = k.at[:, :, 0].set(99.0)
    v2 = v.at[:, :, 0].set(-99.0)
    o2 = ref.attention(q, k2, v2, causal=True, window=8)
    np.testing.assert_allclose(o1[:, :, 9:], o2[:, :, 9:], atol=1e-5)


def test_moe_capacity_drops_tokens_when_overloaded():
    import repro.models.moe as M
    cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b", reduced=True),
                              dtype="float32", moe_capacity_factor=0.1)
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out_low, _ = M.moe(p, cfg, x, jnp.float32, capacity_factor=0.1)
    out_high, _ = M.moe(p, cfg, x, jnp.float32, capacity_factor=8.0)
    # low capacity must actually drop something
    assert float(jnp.abs(out_low - out_high).max()) > 0


def test_moe_dispatch_matches_dense_onehot():
    """Sort-based ragged dispatch == dense one-hot einsum (dropless)."""
    import repro.models.moe as M
    cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b", reduced=True),
                              dtype="float32")
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    B, S, d = 2, 8, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    got, _ = M.moe(p, cfg, x, jnp.float32, capacity_factor=16.0)

    # dense reference
    E, k = cfg.num_experts, cfg.experts_per_token
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / topv.sum(-1, keepdims=True)
    h = jnp.einsum("td,edf->tef", xt, p["wi"])
    g = jnp.einsum("td,edf->tef", xt, p["wg"])
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * h, p["wo"])
    gate = jnp.zeros((xt.shape[0], E)).at[
        jnp.arange(xt.shape[0])[:, None], topi].set(topv)
    want = jnp.einsum("te,ted->td", gate, y).reshape(B, S, d)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
