"""Fused request/maintenance paths vs the sequential references.

Acceptance for the fleet-scale throughput work: ``record_batch`` must
be bit-for-bit a loop of C ``record`` calls, the fused maintenance
kernel (interpret mode) must match the pure-jnp Silverman/KDE/quantile
composition, and the subset/batched drivers must commit exactly what
the full-width versions do.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BanditParams, init_state, maintenance,
                        maintenance_subset, record, record_batch)
from repro.core import kde as core_kde
from repro.kernels import ref
from repro.kernels.kde import fused_maintenance

P = BanditParams()


def _random_trace(rng, K, M, C, full_mask=False):
    choices = jnp.asarray(rng.integers(0, M, (K, C)), jnp.int32)
    lats = jnp.asarray(rng.uniform(0.005, 0.3, (K, C)), jnp.float32)
    if full_mask:
        mask = jnp.ones((K, C), bool)
    else:
        mask = jnp.asarray(rng.random((K, C)) < 0.7)
    return choices, lats, mask


def _assert_states_equal(a, b):
    for name, xa, xb in zip(a._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(xa), np.asarray(xb), err_msg=f"field {name}")


@pytest.mark.parametrize("K,M,C,ring,steps", [
    (5, 4, 6, 8, 12),       # ring wraps across steps
    (3, 2, 8, 64, 4),       # multiple writes per (k, arm) per batch
    (7, 5, 4, 16, 6),
])
def test_record_batch_matches_sequential(K, M, C, ring, steps):
    rng = np.random.default_rng(0)
    st_a = init_state(K, M, P, ring=ring, reward_ring=16,
                      key=jax.random.PRNGKey(0))
    st_b = st_a
    for i in range(steps):
        choices, lats, mask = _random_trace(rng, K, M, C)
        t = jnp.float32(i * 0.1)
        st_a = record_batch(st_a, P, choices, lats, t, mask)
        for c in range(C):
            st_b = record(st_b, P, choices[:, c], lats[:, c], t, mask[:, c])
        _assert_states_equal(st_a, st_b)


def test_record_batch_overflow_within_batch():
    """C > R on a single arm: the batch overwrites its own oldest
    writes exactly like the sequential ring does."""
    K, M, C, ring = 2, 3, 6, 2
    rng = np.random.default_rng(1)
    st_a = init_state(K, M, P, ring=ring, reward_ring=4)
    st_b = st_a
    choices = jnp.zeros((K, C), jnp.int32)          # everyone hammers arm 0
    lats = jnp.asarray(rng.uniform(0.005, 0.3, (K, C)), jnp.float32)
    mask = jnp.ones((K, C), bool)
    t = jnp.float32(0.5)
    st_a = record_batch(st_a, P, choices, lats, t, mask)
    for c in range(C):
        st_b = record(st_b, P, choices[:, c], lats[:, c], t, mask[:, c])
    _assert_states_equal(st_a, st_b)


def test_record_batch_trips_cooldown_like_sequential():
    params = BanditParams(err_thresh=3, cooldown=5.0)
    K, M, C = 2, 2, 5
    st_a = init_state(K, M, params, ring=8, reward_ring=8)
    st_a = st_a._replace(weights=jnp.asarray([[1.0, 0.0], [1.0, 0.0]]))
    st_b = st_a
    choices = jnp.zeros((K, C), jnp.int32)
    lats = jnp.full((K, C), 1.0, jnp.float32)       # always violates tau
    mask = jnp.ones((K, C), bool)
    t = jnp.float32(0.2)
    st_a = record_batch(st_a, params, choices, lats, t, mask)
    for c in range(C):
        st_b = record(st_b, params, choices[:, c], lats[:, c], t, mask[:, c])
    _assert_states_equal(st_a, st_b)
    assert float(st_a.cooldown_until[0, 0]) > 0.2   # tripped mid-batch


def _driven_state(rng, K, M, ring=32, steps=60):
    st = init_state(K, M, P, ring=ring, reward_ring=64,
                    key=jax.random.PRNGKey(3))
    for i in range(steps):
        choices, lats, mask = _random_trace(rng, K, M, 4)
        st = record_batch(st, P, choices, lats, jnp.float32(i * 0.1), mask)
    return st


def test_maintenance_subset_matches_lb_mask():
    K, M = 6, 4
    rng = np.random.default_rng(2)
    st = _driven_state(rng, K, M)
    rtt = jnp.asarray(rng.uniform(0.002, 0.02, (K, M)), jnp.float32)
    t = jnp.float32(7.0)
    idx = jnp.asarray([4, 1, K, K], jnp.int32)      # padded group
    got = maintenance_subset(st, P, rtt, t, idx)
    lb_mask = jnp.asarray([False, True, False, False, True, False])
    want = maintenance(st, P, rtt, t, lb_mask=lb_mask)
    _assert_states_equal(got, want)


def test_maintenance_fused_stats_path_matches_composition():
    """maintenance() routes KDE+quantile through kernels.ops; on CPU the
    ref path must reproduce the core/kde composition bit for bit."""
    K, M, R = 5, 3, 16
    rng = np.random.default_rng(4)
    st = _driven_state(rng, K, M, ring=R)
    rtt = jnp.asarray(rng.uniform(0.002, 0.02, (K, M)), jnp.float32)
    t = jnp.float32(9.0)
    win = (st.ts_buf >= t - P.window) & (st.ts_buf < t) \
        & (st.ts_buf > -1e30 / 2)
    mu_ref, q_ref = ref.bandit_maintenance_stats(
        st.lat_buf.reshape(K * M, R), win.reshape(K * M, R),
        rtt.reshape(K * M), P.tau, P.rho, P.min_bandwidth)
    bw = core_kde.silverman_bandwidth(st.lat_buf, win, P.min_bandwidth)
    mu_core = core_kde.kde_success_prob(st.lat_buf, win, P.tau, bandwidth=bw)
    proc = jnp.maximum(st.lat_buf - rtt[..., None], 0.0)
    q_core = core_kde.masked_quantile(proc, win, P.rho)
    np.testing.assert_array_equal(np.asarray(mu_ref).reshape(K, M),
                                  np.asarray(mu_core))
    np.testing.assert_array_equal(np.asarray(q_ref).reshape(K, M),
                                  np.asarray(q_core))


@pytest.mark.parametrize("rows,R", [(8, 16), (300, 64), (130, 128)])
def test_fused_maintenance_kernel_matches_ref(rows, R):
    rng = np.random.default_rng(5)
    lat = jnp.asarray(rng.exponential(0.03, (rows, R)), jnp.float32)
    mask = jnp.asarray(rng.random((rows, R)) < 0.7)
    rtt = jnp.asarray(rng.uniform(0.001, 0.02, rows), jnp.float32)
    mu_k, q_k = fused_maintenance(lat, mask, rtt, 0.08, 0.9,
                                  interpret=True)
    mu_r, q_r = ref.bandit_maintenance_stats(lat, mask, rtt, 0.08, 0.9)
    np.testing.assert_allclose(mu_k, mu_r, rtol=2e-5, atol=2e-6)
    # quantile is pure value selection: exact, including empty rows
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))


def test_fused_maintenance_kernel_ties_and_empty_rows():
    lat = jnp.asarray([
        [0.05, 0.05, 0.05, 0.05],       # all ties
        [0.01, 0.02, 0.03, 0.04],
        [0.10, 0.10, 0.20, 0.20],       # duplicate pairs
        [0.00, 0.00, 0.00, 0.00],
    ], jnp.float32)
    mask = jnp.asarray([
        [True, True, True, True],
        [True, False, True, False],
        [True, True, True, True],
        [False, False, False, False],   # empty window
    ])
    rtt = jnp.asarray([0.0, 0.005, 0.02, 0.01], jnp.float32)
    mu_k, q_k = fused_maintenance(lat, mask, rtt, 0.08, 0.9,
                                  interpret=True)
    mu_r, q_r = ref.bandit_maintenance_stats(lat, mask, rtt, 0.08, 0.9)
    np.testing.assert_allclose(mu_k, mu_r, rtol=2e-5, atol=2e-6)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    assert float(q_k[3]) == np.finfo(np.float32).max


def test_sim_fused_matches_sequential_step_structure():
    """The fused step (deferred ring scatter + interleaved control)
    must be bit-for-bit the sequential fallback, including in the
    overloaded regime where in-step cooldown trips steer later
    rounds."""
    from repro.continuum import (SimConfig, build_sim_fn, make_topology,
                                 neutral_drivers)
    cfg = SimConfig(horizon=12.0, service_time=0.009)   # overloaded
    topo = make_topology(jax.random.PRNGKey(3), 8, 3)
    rtt = topo.lb_instance_rtt()
    T = cfg.num_steps
    drv = neutral_drivers(cfg, 8, 3,
                          n_clients=jnp.full((T, 8), 6, jnp.int32))
    key = jax.random.PRNGKey(42)
    outs_f = jax.jit(build_sim_fn("qedgeproxy", cfg, 8, 3, fused=True))(
        rtt, drv, key)
    outs_s = jax.jit(build_sim_fn("qedgeproxy", cfg, 8, 3, fused=False))(
        rtt, drv, key)
    for name, xf, xs in zip(outs_f._fields, outs_f, outs_s):
        np.testing.assert_array_equal(
            np.asarray(xf), np.asarray(xs), err_msg=f"field {name}")
    # overload must actually have tripped arms, or this test is vacuous
    assert float(np.asarray(outs_f.rewards).mean()) < 0.9


def test_run_sim_batch_matches_per_seed():
    from repro.continuum import SimConfig, run_sim, run_sim_batch
    from repro.continuum import make_topology
    cfg = SimConfig(horizon=6.0)
    rtts, keys = [], []
    for seed in (1, 2):
        topo = make_topology(jax.random.PRNGKey(seed), 8, 4)
        rtts.append(topo.lb_instance_rtt())
        keys.append(jax.random.PRNGKey(100 + seed))
    batched = run_sim_batch("qedgeproxy", jnp.stack(rtts), cfg,
                            jnp.stack(keys))
    for i, seed in enumerate((1, 2)):
        single = run_sim("qedgeproxy", rtts[i], cfg, keys[i])
        for name, xb, xs in zip(single._fields, batched, single):
            np.testing.assert_allclose(
                np.asarray(xb[i]), np.asarray(xs), atol=1e-6,
                err_msg=f"field {name} seed {seed}")
