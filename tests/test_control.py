"""Closed-loop control plane: reactive autoscaling, admission control,
capacity migration — and the parity contract the layer ships under.

Three invariant families:

1. **Neutral parity** — ``control=None`` and a neutral
   :class:`ControlConfig` lower to the byte-identical HLO for every
   strategy (the gate is Python-level static config, not a traced
   branch), and the neutral program reproduces the committed HEAD
   golden (``tests/data/neutral_stream_ref.npz``) bit-for-bit,
   including through the chunked streaming loop and (subprocess) the
   2x2 (data, players) sharded grid.
2. **Controller semantics** — unit tests drive ``control_actuate`` /
   ``control_observe`` directly: warm-up + dwell + hysteresis +
   cooldown on the autoscaler, AIMD + token buckets on admission,
   conserved clipped deltas on migration, fail-open when the
   controller would darken the fleet.
3. **Engine composition** — closed-loop runs heal a sustained
   overload that no open-loop policy can (standby capacity spawns,
   shed requests count as issued QoS misses but never pollute routing
   stats), stream through chunking + checkpoint/resume bit-exactly,
   and reproduce the unsharded run under player sharding (subprocess,
   8 forced host devices) with the control counters exact.
"""
import dataclasses
import math
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_sub
from repro.continuum import (SimConfig, compile_scenario, get_library,
                             make_topology, neutral_drivers, run_sim,
                             run_sim_stream, with_standby)
from repro.continuum import control as qc
from repro.continuum.control import (ControlConfig, control_stats_stream,
                                     per_tenant_qos_spread)
from repro.continuum.simulator import build_sim_fn

K, M = 10, 4
CFG = SimConfig(horizon=12.0)
WARM = 30
STRATEGIES = (("qedgeproxy", {}), ("proxy_mity", dict(alpha=0.9)),
              ("dec_sarsa", {}))
REF = os.path.join(os.path.dirname(__file__), "data",
                   "neutral_stream_ref.npz")
# a closed-loop policy exercising every mechanism at this testbed's
# scale: 2 standby instances, admission shedding, 2 regions
CTL = ControlConfig(managed=2, warmup=0.5, up_queue=2.0, down_queue=0.3,
                    hold=0.3, action_cooldown=1.0, batch=1,
                    admit=True, target_queue=3.0, admit_floor=0.3,
                    regions=2, mig_threshold=2.0, mig_step=0.1)


def _inputs():
    rtt = make_topology(jax.random.PRNGKey(2), K, M).lb_instance_rtt()
    return rtt, jax.random.PRNGKey(5)


# -- invariant 1: neutral control is the open-loop engine, bit for bit --

def test_neutral_config_is_disabled():
    assert not ControlConfig().enabled
    assert ControlConfig(managed=1).enabled
    assert ControlConfig(admit=True).enabled
    assert ControlConfig(regions=2).enabled
    assert not ControlConfig(regions=1).enabled
    assert not SimConfig().control_on
    assert not dataclasses.replace(CFG, control=ControlConfig()).control_on
    assert dataclasses.replace(CFG, control=CTL).control_on


@pytest.mark.parametrize("strat,kw", STRATEGIES,
                         ids=[s for s, _ in STRATEGIES])
def test_neutral_hlo_byte_identity(strat, kw):
    """``control=None`` and a neutral ControlConfig lower to the SAME
    program text: parity is structural, not numerical luck."""
    rtt, key = _inputs()
    drv = neutral_drivers(CFG, K, M)
    texts = []
    for control in (None, ControlConfig()):
        cfg = dataclasses.replace(CFG, control=control)
        run = build_sim_fn(strat, cfg, K, M, trace=False,
                           warmup_steps=WARM, **kw)
        texts.append(jax.jit(run).lower(rtt, drv, key).as_text())
    assert texts[0] == texts[1]


@pytest.mark.parametrize("strat,kw", STRATEGIES,
                         ids=[s for s, _ in STRATEGIES])
def test_neutral_bit_identity_vs_head(strat, kw):
    """The neutral-ControlConfig program reproduces the committed HEAD
    golden bit-for-bit — also through the chunked streaming loop — and
    carries no control state out (``ctrl is None``)."""
    rtt, key = _inputs()
    ref = np.load(REF)
    cfg = dataclasses.replace(CFG, control=ControlConfig())
    for chunk in (None, 25):
        out = run_sim_stream(strat, rtt, cfg, key, warmup_steps=WARM,
                             chunk_steps=chunk, **kw)
        assert out.ctrl is None
        for f in out.acc._fields:
            if f"{strat}.acc.{f}" in ref.files:
                np.testing.assert_array_equal(
                    np.asarray(getattr(out.acc, f)),
                    ref[f"{strat}.acc.{f}"],
                    err_msg=f"{strat} chunk={chunk} acc.{f}")
        for f in out.series._fields:
            if f"{strat}.series.{f}" in ref.files:
                np.testing.assert_array_equal(
                    np.asarray(getattr(out.series, f)),
                    ref[f"{strat}.series.{f}"],
                    err_msg=f"{strat} chunk={chunk} series.{f}")


@pytest.mark.slow
def test_neutral_parity_sharded_2x2_8dev():
    """On a 2x2 (data, players) mesh the neutral-control grid program
    lowers byte-identically to control=None and produces bit-identical
    outputs — the static gate composes with shard_map."""
    out = run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.continuum import (SimConfig, compile_scenario,
                                     get_library, make_topology,
                                     run_sim_grid, stack_drivers)
        from repro.continuum.control import ControlConfig
        from repro.continuum.simulator import build_sim_grid_fn
        from repro.launch.mesh import make_continuum_mesh

        K, M, S, WARM = 16, 4, 2, 10
        cfg0 = SimConfig(horizon=3.0)
        rtts = jnp.stack([make_topology(jax.random.PRNGKey(s), K, M)
                          .lb_instance_rtt() for s in range(S)])
        keys = jnp.stack([jax.random.PRNGKey(100 + s) for s in range(S)])
        lib = get_library(cfg0.horizon, K, M)
        drivers = stack_drivers(
            [compile_scenario(lib[n], cfg0, jax.random.PRNGKey(i))
             for i, n in enumerate(("surge", "rolling_restart"))])
        mesh = make_continuum_mesh(players=2, devices=jax.devices()[:4])
        outs, texts = [], []
        for control in (None, ControlConfig()):
            cfg = dataclasses.replace(cfg0, control=control)
            run, _ = build_sim_grid_fn("qedgeproxy", cfg, K, M,
                                       warmup_steps=WARM, mesh=mesh)
            texts.append(jax.jit(run).lower(rtts, drivers, keys).as_text())
            outs.append(run_sim_grid("qedgeproxy", rtts, cfg, keys,
                                     drivers=drivers, warmup_steps=WARM,
                                     mesh=mesh))
        assert texts[0] == texts[1], "sharded HLO differs"
        ref, got = outs
        for f in ref.acc._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(got.acc, f)),
                np.asarray(getattr(ref.acc, f)), err_msg=f"acc.{f}")
        for f in ref.series._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(got.series, f)),
                np.asarray(getattr(ref.series, f)),
                err_msg=f"series.{f}")
        assert got.ctrl is None
        print("OK sharded neutral parity")
    """)
    assert "OK sharded neutral parity" in out


# -- invariant 2: controller semantics (direct state-machine drives) ---

def _drive(ccfg, carry, q, t, act=None, nc=None, s_m=None, dt=0.1,
           measf=1.0):
    M_ = q.shape[0]
    act = jnp.ones((M_,), bool) if act is None else act
    nc = jnp.full((3,), 4, jnp.int32) if nc is None else nc
    s_m = jnp.full((M_,), 0.0055, jnp.float32) if s_m is None else s_m
    return qc.control_actuate(ccfg, dt, jnp.float32(t), carry, q, act,
                              nc, s_m, jnp.float32(measf))


def test_autoscaler_warmup_dwell_hysteresis_cooldown():
    ccfg = ControlConfig(managed=2, warmup=0.5, up_queue=4.0,
                         down_queue=0.5, hold=0.2, action_cooldown=1.0,
                         batch=1)
    carry = qc.control_init(ccfg, K=3, M=4)
    hot = jnp.full((4,), 20.0)
    # standby parked at t=0: only the 2 base instances serve
    carry, act_eff, *_ = _drive(ccfg, carry, hot, t=0.0)
    np.testing.assert_array_equal(np.asarray(act_eff),
                                  [True, True, False, False])
    # dwell not yet met after one hot step -> still no spawn
    assert float(carry.counters.scale_up) == 0.0
    # second hot step satisfies hold=0.2 -> spawn instance 2 (first
    # parked), but it stays dark until warmup elapses
    carry, act_eff, *_ = _drive(ccfg, carry, hot, t=0.1)
    assert float(carry.counters.scale_up) == 1.0
    assert bool(np.asarray(carry.state.ctrl_on)[2])
    assert not bool(np.asarray(act_eff)[2]), "must wait out warmup"
    # past ready_at the spawn serves; cooldown blocks a second action
    carry, act_eff, *_ = _drive(ccfg, carry, hot, t=0.8)
    assert bool(np.asarray(act_eff)[2])
    assert float(carry.counters.scale_up) == 1.0
    # cold signal after cooldown: dwell then kill the LAST on instance
    carry, *_ = _drive(ccfg, carry, jnp.zeros((4,)), t=1.2)
    carry, act_eff, *_ = _drive(ccfg, carry, jnp.zeros((4,)), t=1.3)
    assert float(carry.counters.scale_down) == 1.0
    np.testing.assert_array_equal(np.asarray(act_eff),
                                  [True, True, False, False])


def test_autoscaler_fail_open_never_darkens_fleet():
    # every instance managed and parked -> the veto would kill the
    # whole fleet; the controller must fail open to scenario liveness
    ccfg = ControlConfig(managed=4)
    carry = qc.control_init(ccfg, K=3, M=4)
    carry, act_eff, *_ = _drive(ccfg, carry, jnp.zeros((4,)), t=0.0)
    assert bool(np.asarray(act_eff).all())


def test_autoscaler_cannot_resurrect_scenario_kills():
    ccfg = ControlConfig(managed=2, start_up=True, warmup=0.0)
    carry = qc.control_init(ccfg, K=3, M=4)
    act = jnp.array([True, True, True, False])   # scenario killed #3
    carry, act_eff, *_ = _drive(ccfg, carry, jnp.zeros((4,)), t=0.0,
                                act=act)
    np.testing.assert_array_equal(np.asarray(act_eff),
                                  [True, True, True, False])


def test_admission_aimd_and_token_buckets():
    ccfg = ControlConfig(admit=True, target_queue=1.0, admit_md=0.5,
                         admit_ai=1.0, admit_floor=0.1, burst=4.0)
    carry = qc.control_init(ccfg, K=2, M=2)
    nc = jnp.full((2,), 4, jnp.int32)
    hot = jnp.full((2,), 10.0)
    # first hot step: frac halves but full buckets absorb the burst
    carry, _, nc_adm, _, shed = _drive(ccfg, carry, hot, t=0.0, nc=nc)
    assert float(carry.state.admit_frac) == pytest.approx(0.5)
    np.testing.assert_array_equal(np.asarray(nc_adm), [4, 4])
    np.testing.assert_array_equal(np.asarray(shed), [0.0, 0.0])
    # buckets drained: refill at frac*nc -> admit 1 of 4, shed 3
    carry, _, nc_adm, _, shed = _drive(ccfg, carry, hot, t=0.1, nc=nc)
    np.testing.assert_array_equal(np.asarray(nc_adm), [1, 1])
    np.testing.assert_array_equal(np.asarray(shed), [3.0, 3.0])
    assert float(carry.state.admit_frac) == pytest.approx(0.25)
    # sustained hot clamps at the floor, never 0 (starvation guard)
    for i in range(10):
        carry, _, nc_adm, _, _ = _drive(ccfg, carry, hot, t=0.2 + 0.1 * i,
                                        nc=nc)
    assert float(carry.state.admit_frac) == pytest.approx(0.1)
    assert int(np.asarray(nc_adm).min()) >= 0
    # healthy signal: additive increase climbs back toward 1
    f0 = float(carry.state.admit_frac)
    carry, *_ = _drive(ccfg, carry, jnp.zeros((2,)), t=2.0, nc=nc)
    assert float(carry.state.admit_frac) == pytest.approx(f0 + 1.0 * 0.1)
    # shed accounting respects the measurement gate
    shed0 = np.asarray(carry.counters.shed_k).sum()
    carry, _, _, _, shed = _drive(ccfg, carry, hot, t=3.0, nc=nc,
                                  measf=0.0)
    assert np.asarray(carry.counters.shed_k).sum() == shed0


def test_migration_conserves_capacity():
    ccfg = ControlConfig(regions=2, mig_threshold=1.0, mig_step=0.25,
                         mig_cooldown=5.0, share_min=0.5, share_max=1.5)
    carry = qc.control_init(ccfg, K=3, M=4)
    s_m = jnp.full((4,), 0.0055, jnp.float32)
    q = jnp.array([10.0, 10.0, 0.0, 0.0])        # region 0 hot
    carry, _, _, s_m_eff, _ = _drive(ccfg, carry, q, t=0.0, s_m=s_m)
    share = np.asarray(carry.state.share)
    np.testing.assert_allclose(share, [1.25, 0.75])
    assert share.sum() == pytest.approx(2.0)      # conserved
    assert float(carry.counters.migrations) == 1.0
    # the hot region's instances now process faster
    e = np.asarray(s_m_eff)
    assert (e[:2] < 0.0055).all() and (e[2:] > 0.0055).all()
    # cooldown: an immediate second gap does not move capacity again
    carry, *_ = _drive(ccfg, carry, q, t=0.1, s_m=s_m)
    np.testing.assert_allclose(np.asarray(carry.state.share), share)
    # clip at share_min/share_max even after cooldown expires
    for i in range(4):
        carry, *_ = _drive(ccfg, carry, q, t=6.0 + 6.0 * i, s_m=s_m)
    share = np.asarray(carry.state.share)
    assert share.max() <= 1.5 + 1e-6 and share.min() >= 0.5 - 1e-6
    assert share.sum() == pytest.approx(2.0)


def test_observe_folds_qos_ema():
    ccfg = ControlConfig(admit=True, qos_window=1.0)
    carry = qc.control_init(ccfg, K=2, M=2)
    assert float(carry.state.ema_qos) == 1.0
    # obs = [succ, issued, timeouts, attempts]: total QoS failure
    obs = jnp.array([0.0, 10.0, 10.0, 10.0])
    for _ in range(50):
        carry = qc.control_observe(ccfg, carry, obs, dt=0.1)
    assert float(carry.state.ema_qos) < 0.02
    assert float(carry.state.ema_timeout) > 0.98


# -- invariant 3: engine composition -----------------------------------

def _overload_cfg(control, service_time=0.0275):
    # service_time 5x the provisioned default: the base fleet is
    # genuinely over capacity, only standby spawns or shedding help
    return dataclasses.replace(CFG, service_time=service_time,
                               control=control)


def test_control_is_streaming_only():
    rtt, key = _inputs()
    with pytest.raises(ValueError, match="streaming"):
        run_sim("qedgeproxy", rtt, dataclasses.replace(CFG, control=CTL),
                key)


def test_closed_loop_heals_sustained_overload():
    """Under an over-capacity fleet the autoscaler buys back QoS that a
    statically-parked control plane cannot: same program shape, only
    the thresholds differ."""
    rtt, key = _inputs()
    # 0.008 s/req: the 2 base instances carry ~250 req/s against the
    # ~400 req/s demand (overload); all 4 carry ~500 (healthy) — the
    # standby pool is exactly the missing capacity. down_queue=0 so
    # the spawned capacity stays up for the rest of the horizon.
    scale = ControlConfig(managed=2, warmup=0.3, up_queue=1.5,
                          down_queue=0.0, hold=0.2, action_cooldown=1.0,
                          batch=2)
    # up_queue=inf never fires: the standby pool stays parked — the
    # open-loop baseline at identical fleet shape
    parked = dataclasses.replace(scale, up_queue=math.inf)
    # warmup_steps=0: the overload is immediate, so the scale-up fires
    # inside the usual measurement warm-up — count everything here
    out_c = run_sim_stream("qedgeproxy", rtt,
                           _overload_cfg(scale, 0.008), key)
    out_p = run_sim_stream("qedgeproxy", rtt,
                           _overload_cfg(parked, 0.008), key)
    st_c = control_stats_stream(out_c.acc, out_c.ctrl)
    st_p = control_stats_stream(out_p.acc, out_p.ctrl)
    assert st_c["scale_up"] >= 1.0
    assert st_c["standby_up_mean"] > 0.5
    assert st_p["scale_up"] == 0.0 and st_p["standby_up_mean"] == 0.0
    qos_c = (np.asarray(out_c.acc.succ_kc).sum()
             / max(np.asarray(out_c.acc.n_kc).sum(), 1.0))
    qos_p = (np.asarray(out_p.acc.succ_kc).sum()
             / max(np.asarray(out_p.acc.n_kc).sum(), 1.0))
    assert qos_c > qos_p + 0.02, (qos_c, qos_p)
    spread = per_tenant_qos_spread(out_c.acc)
    assert 0.0 <= spread["min"] <= spread["max"] <= 1.0


def test_shed_requests_are_issued_misses_not_routing_noise():
    """Admission shedding must not shrink the QoS denominator (a denied
    client is a failed client) and must never pollute the routing
    stats: n_kc matches the open-loop schedule exactly while
    choice_counts drops exactly the shed slots."""
    rtt, key = _inputs()
    admit = ControlConfig(admit=True, target_queue=1.0, admit_floor=0.2)
    out = run_sim_stream("qedgeproxy", rtt, _overload_cfg(admit), key,
                         warmup_steps=WARM)
    base = run_sim_stream("qedgeproxy", rtt, _overload_cfg(None), key,
                          warmup_steps=WARM)
    st = control_stats_stream(out.acc, out.ctrl)
    assert st["shed"] > 0
    assert 0.0 < st["admission_drop_frac"] < 1.0
    assert st["mean_admit_frac"] < 1.0
    # scheduled-request accounting is untouched by shedding
    np.testing.assert_array_equal(np.asarray(out.acc.n_kc),
                                  np.asarray(base.acc.n_kc))
    served = np.asarray(out.acc.choice_counts).sum()
    scheduled = np.asarray(out.acc.n_kc).sum()
    assert served == pytest.approx(scheduled - st["shed"])
    # a shed request can never succeed
    assert (np.asarray(out.acc.succ_kc) <= np.asarray(out.acc.n_kc)).all()


def test_chunked_matches_unchunked_with_control():
    rtt, key = _inputs()
    cfg = _overload_cfg(CTL)
    full = run_sim_stream("qedgeproxy", rtt, cfg, key, warmup_steps=WARM)
    chun = run_sim_stream("qedgeproxy", rtt, cfg, key, warmup_steps=WARM,
                          chunk_steps=25)
    for f in full.acc._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(chun.acc, f)),
            np.asarray(getattr(full.acc, f)), err_msg=f"acc.{f}")
    for f in full.ctrl._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(chun.ctrl, f)),
            np.asarray(getattr(full.ctrl, f)), err_msg=f"ctrl.{f}")


def test_checkpoint_resume_exact_with_control(tmp_path):
    """Killed-and-resumed == uninterrupted with the controller state in
    the carry — including under a different resumed chunk length."""
    rtt, key = _inputs()
    cfg = _overload_cfg(CTL)
    d = str(tmp_path / "ck")
    full = run_sim_stream("qedgeproxy", rtt, cfg, key, warmup_steps=WARM,
                          chunk_steps=40)
    part = run_sim_stream("qedgeproxy", rtt, cfg, key, warmup_steps=WARM,
                          chunk_steps=40, checkpoint_dir=d,
                          stop_at_step=80)
    assert len(np.asarray(part.series.succ)) == 80
    res = run_sim_stream("qedgeproxy", rtt, cfg, key, warmup_steps=WARM,
                         chunk_steps=25, checkpoint_dir=d, resume=True)
    for f in full.acc._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res.acc, f)),
            np.asarray(getattr(full.acc, f)), err_msg=f"acc.{f}")
    for f in full.series._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res.series, f)),
            np.asarray(getattr(full.series, f)), err_msg=f"series.{f}")
    for f in full.ctrl._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res.ctrl, f)),
            np.asarray(getattr(full.ctrl, f)), err_msg=f"ctrl.{f}")
    shutil.rmtree(d)


def test_with_standby_extends_fleet():
    lib = get_library(12.0, K, M)
    scn = with_standby(lib["metastable_overload"], 3)
    assert scn.n_instances == M + 3
    assert scn.events == lib["metastable_overload"].events
    with pytest.raises(ValueError):
        with_standby(lib["baseline"], -1)
    # compiled standby drivers: the extra instances are live, and the
    # engine accepts the widened fleet
    cfg = dataclasses.replace(CFG, horizon=3.0)
    drv = compile_scenario(scn, cfg, jax.random.PRNGKey(0))
    assert drv.active.shape[1] == M + 3
    assert bool(np.asarray(drv.active)[:, M:].all())


@pytest.mark.slow
def test_control_sharded_matches_unsharded_8dev():
    """Player-sharded closed-loop runs reproduce the unsharded stream:
    counting stats and every control counter exact, float fields to f32
    reassociation tolerance — the psum'd observation keeps the
    replicated controller state identical on every shard."""
    out = run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.continuum import (SimConfig, compile_scenario,
                                     get_library, make_topology,
                                     run_sim_players, run_sim_stream,
                                     with_standby)
        from repro.continuum.control import ControlConfig
        from repro.launch.mesh import make_continuum_mesh

        K, M, WARM = 16, 6, 10
        ctl = ControlConfig(managed=2, warmup=0.3, up_queue=1.5,
                            down_queue=0.2, hold=0.2,
                            action_cooldown=1.0, batch=2, admit=True,
                            target_queue=3.0, admit_floor=0.3,
                            regions=2, mig_threshold=2.0)
        cfg = SimConfig(horizon=4.0, service_time=0.0275,
                        attempt_timeout=0.055, max_retries=2,
                        retry_backoff=0.002, breaker_threshold=4,
                        breaker_cooldown=1.0, control=ctl)
        rtt = make_topology(jax.random.PRNGKey(0), K, M).lb_instance_rtt()
        key = jax.random.PRNGKey(7)
        lib = get_library(cfg.horizon, K, M - 2)
        scn = with_standby(lib["metastable_overload"], 2)
        drv = compile_scenario(scn, cfg, jax.random.PRNGKey(3))
        COUNTS = {"succ_kc", "n_kc", "arrivals_m", "choice_counts",
                  "proc_hist", "steps_measured", "ev_succ", "ev_n",
                  "att_k", "timeout_k", "drop_k", "open_km"}
        for strat, kw in (("qedgeproxy", {}), ("dec_sarsa", {}),
                          ("proxy_mity", dict(alpha=0.9))):
            ref = run_sim_stream(strat, rtt, cfg, key, drivers=drv,
                                 warmup_steps=WARM, **kw)
            assert float(np.asarray(ref.ctrl.shed_k).sum()) > 0, \\
                "scenario must shed for this test to bite"
            for D in (8, 2, 1):
                mesh = make_continuum_mesh(
                    players=D, devices=jax.devices()[:D])
                got = run_sim_players(
                    strat, rtt, cfg, key, drivers=drv,
                    warmup_steps=WARM, mesh=mesh, **kw)
                for name in ref.acc._fields:
                    a = np.asarray(getattr(ref.acc, name))
                    b = np.asarray(getattr(got.acc, name))
                    if name in COUNTS:
                        np.testing.assert_array_equal(
                            b, a, err_msg=f"{strat} D{D} {name}")
                    else:
                        np.testing.assert_allclose(
                            b, a, rtol=2e-5, atol=2e-5,
                            err_msg=f"{strat} D{D} {name}")
                for name in ref.ctrl._fields:
                    np.testing.assert_array_equal(
                        np.asarray(getattr(got.ctrl, name)),
                        np.asarray(getattr(ref.ctrl, name)),
                        err_msg=f"{strat} D{D} ctrl.{name}")
                np.testing.assert_array_equal(
                    np.asarray(got.series.issued),
                    np.asarray(ref.series.issued),
                    err_msg=f"{strat} D{D} series.issued")
            print(strat, "control parity ok")
        print("OK control parity")
    """)
    assert "OK control parity" in out
