"""Telemetry export contracts: registry schema, Prometheus/JSON
round-trips, Chrome trace validity, artifact provenance, and the run
directory as one validated unit.

The export layer is pure host-side code, so these tests drive it with
small synthetic inputs plus ONE real (tiny) simulator run that flows
through ``collect_stream`` -> ``write_run`` -> ``load_run`` ->
``report.render`` end to end.
"""
import dataclasses
import json
import math
import os

import jax
import numpy as np
import pytest

from repro.continuum import (SimConfig, compile_scenario, get_library,
                             make_topology, run_sim_stream)
from repro.obs import (RecorderConfig, provenance, registry, report,
                       runlog, trace)
from repro.obs.registry import Metric, MetricSet

K, M = 8, 3


@pytest.fixture(scope="module")
def storm_out():
    cfg = SimConfig(horizon=8.0, tau=0.150, attempt_timeout=0.090,
                    max_retries=2, retry_backoff=0.002,
                    breaker_threshold=5, breaker_cooldown=1.0,
                    recorder=RecorderConfig(capacity=512))
    rtt = make_topology(jax.random.PRNGKey(1), K, M).lb_instance_rtt()
    drv = compile_scenario(get_library(cfg.horizon, K, M)["retry_storm"],
                           cfg, jax.random.PRNGKey(7))
    out = run_sim_stream("qedgeproxy", rtt, cfg, jax.random.PRNGKey(11),
                         drivers=drv, warmup_steps=20)
    return cfg, out


# -- registry ----------------------------------------------------------

def test_metric_validation():
    with pytest.raises(ValueError, match="kind"):
        Metric("x", 1.0, kind="histogram")
    with pytest.raises(ValueError, match="name"):
        Metric("2bad", 1.0)
    with pytest.raises(ValueError, match="label"):
        Metric("ok", 1.0, labels={"bad-label": "v"})
    ms = MetricSet()
    ms.add("repro_x", 1.0, instance="0")
    ms.add("repro_x", 2.0, instance="1")    # same name, new labels: fine
    with pytest.raises(ValueError, match="duplicate"):
        ms.add("repro_x", 3.0, instance="0")


def test_json_round_trip_preserves_nan():
    ms = MetricSet()
    ms.add("repro_a", float("nan"), help="a nan gauge")
    ms.add("repro_b", 2.5, kind="counter")
    ms.add("repro_s", [1.0, float("nan"), 3.0], kind="series")
    doc = ms.to_json()
    # strict-JSON parseable: no bare NaN tokens
    doc2 = json.loads(json.dumps(doc, allow_nan=False))
    assert registry.validate_metrics_json(doc2) == []
    back = registry.metricset_from_json(doc2)
    vals = {m.name: m for m in back}
    assert math.isnan(vals["repro_a"].value)
    assert vals["repro_b"].value == 2.5
    assert math.isnan(vals["repro_s"].value[1])
    assert vals["repro_s"].value[2] == 3.0


def test_json_round_trip_preserves_inf():
    """+/-Infinity must export under allow_nan=False like NaN does —
    a ratio with a zero denominator must not kill the write."""
    ms = MetricSet()
    ms.add("repro_pos", float("inf"))
    ms.add("repro_neg", float("-inf"))
    ms.add("repro_s", [float("inf"), 2.0, float("-inf")], kind="series")
    doc = json.loads(json.dumps(ms.to_json(), allow_nan=False))
    assert registry.validate_metrics_json(doc) == []
    vals = {m.name: m for m in registry.metricset_from_json(doc)}
    assert vals["repro_pos"].value == float("inf")
    assert vals["repro_neg"].value == float("-inf")
    assert vals["repro_s"].value[0] == float("inf")
    assert vals["repro_s"].value[2] == float("-inf")


def test_prometheus_format_and_validator():
    ms = MetricSet()
    ms.add("repro_qos", 93.5, help="QoS satisfaction")
    ms.add("repro_rate", float("nan"), instance="2")
    ms.add("repro_series", [1, 2], kind="series")
    text = ms.to_prometheus()
    assert registry.validate_prometheus(text) == []
    assert "# TYPE repro_qos gauge" in text
    assert 'repro_rate{instance="2"} NaN' in text
    assert "repro_series" not in text       # series have no prom sample
    assert registry.validate_prometheus("not a metric line\n")
    assert registry.validate_metrics_json({"schema": "other"})


def test_collect_stream_covers_the_run(storm_out):
    cfg, out = storm_out
    ms = registry.collect_stream(out, rho=cfg.rho, dt=cfg.dt,
                                 bucket_s=cfg.ev_bucket)
    s = ms.scalars()
    assert 0.0 <= s["repro_qos_satisfaction_pct"] <= 100.0
    assert 0.0 <= s["repro_jain_fairness"] <= 1.0
    assert s["repro_recorder_events_appended"] > 0
    names = {m.name for m in ms}
    assert "repro_step_succ" in names           # series rode along
    assert registry.validate_metrics_json(ms.to_json()) == []
    assert registry.validate_prometheus(ms.to_prometheus()) == []


def test_stream_cell_matches_legacy_shape(storm_out):
    """The registry cell builder reproduces the scenario_suite payload
    key sets exactly — the artifact contract the figures read."""
    cfg, out = storm_out
    base = registry.stream_cell(out, rho=cfg.rho, bucket_s=cfg.ev_bucket,
                                jain=True, n_events=True)
    assert {"qos_sat_pct", "jain", "events"} <= set(base)
    deg = registry.stream_cell(out, rho=cfg.rho, bucket_s=cfg.ev_bucket,
                               resilience=True, breaker_frac=True,
                               max_recovery=False)
    assert {"qos_sat_pct", "drop_rate", "timeout_rate",
            "breaker_open_frac"} <= set(deg)
    assert "max_recovery_s" not in deg
    assert "jain" not in deg
    ctl = registry.stream_cell(out, rho=cfg.rho, bucket_s=cfg.ev_bucket,
                               jain=True, tenants=True, drop_rate=True,
                               control=True)
    assert {"tenant_qos_spread", "tenant_qos_min", "drop_rate"} <= set(ctl)
    # open-loop run: no controller counters in the cell
    assert "scale_up" not in ctl


# -- trace -------------------------------------------------------------

def test_recorder_trace_and_host_timeline(storm_out):
    cfg, out = storm_out
    evs = trace.recorder_trace_events(out.rec, cfg.dt)
    tl = trace.HostTimeline()
    with tl.span("phase", "test"):
        tl.instant("ping")
    doc = trace.chrome_trace(evs, tl.events, meta={"run": "t"})
    assert trace.validate_chrome_trace(doc) == []
    insts = [e for e in doc["traceEvents"] if e["ph"] == "i"
             and e.get("cat") == "recorder"]
    assert insts, "storm run must emit recorder instants"
    # simulated µs timestamps: ts / (dt * 1e6) is an integer step
    for e in insts:
        assert abs(e["ts"] / (cfg.dt * 1e6) - e["args"]["step"]) < 1e-6
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert spans and spans[0]["dur"] >= 0
    assert trace.validate_chrome_trace({"traceEvents": [{"ph": "?"}]})


# -- provenance --------------------------------------------------------

def test_provenance_stamp_and_validate(tmp_path):
    payload = {"cell": {"x": 1.0}}
    provenance.stamp(payload, SimConfig(horizon=6.0),
                     extra={"benchmark": "t"})
    pv = payload["provenance"]
    assert pv["schema_version"] == provenance.ARTIFACT_SCHEMA_VERSION
    assert pv["benchmark"] == "t"
    assert len(pv["config_hash"]) == 16
    assert payload["cell"] == {"x": 1.0}     # additive, not an envelope
    p = tmp_path / "t.json"
    p.write_text(json.dumps(payload))
    assert provenance.validate_artifact(str(p)) == []
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"cell": 1}))
    assert provenance.validate_artifact(str(bad))
    res = provenance.validate_all(str(tmp_path))
    assert res["t.json"] == [] and res["bad.json"]


def test_config_hash_is_stable_and_sensitive():
    a = provenance.config_hash(SimConfig(horizon=6.0))
    assert a == provenance.config_hash(SimConfig(horizon=6.0))
    assert a != provenance.config_hash(SimConfig(horizon=7.0))
    assert a != provenance.config_hash(
        dataclasses.replace(SimConfig(horizon=6.0),
                            recorder=RecorderConfig()))


def test_committed_artifacts_carry_provenance():
    """Every benchmark artifact in the repo must validate — the CI obs
    lane runs the same check on freshly generated ones."""
    d = "results/benchmarks"
    res = provenance.validate_all(d)
    assert res, f"no artifacts under {d}"
    bad = {f: p for f, p in res.items() if p}
    assert not bad, bad


# -- run directory -----------------------------------------------------

def test_write_load_validate_report_run(tmp_path, storm_out):
    cfg, out = storm_out
    ms = registry.collect_stream(out, rho=cfg.rho, dt=cfg.dt,
                                 bucket_s=cfg.ev_bucket)
    tl = trace.HostTimeline()
    with tl.span("export", "host"):
        pass
    d = str(tmp_path / "run")
    runlog.write_run(d, metrics=ms, rec=out.rec, dt=cfg.dt, timeline=tl,
                     config=cfg, manifest_extra={"label": "export-test"})
    for f in ("manifest.json", "metrics.json", "metrics.prom",
              "events.json", "trace.json"):
        assert os.path.exists(os.path.join(d, f)), f
    assert {k: v for k, v in runlog.validate_run(d).items() if v} == {}
    run = runlog.load_run(d)
    assert run["manifest"]["label"] == "export-test"
    assert run["events"], "storm events must export"
    text = report.render(d)
    assert "export-test" in text
    assert "qos_satisfaction" in text
    assert "flight recorder" in text.lower()
    # corruption is caught, not rendered over: load_run degrades (no
    # parsed MetricSet, raw doc kept) and validate_run reports instead
    # of raising
    with open(os.path.join(d, "metrics.json"), "w") as f:
        json.dump({"schema": "wrong"}, f)
    run = runlog.load_run(d)
    assert "metrics" not in run and "metrics_doc" in run
    assert any(runlog.validate_run(d).values())
