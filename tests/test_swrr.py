"""SWRR properties: proportional shares + burst smoothness (§V-B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:        # pragma: no cover - exercised in slim containers
    HAVE_HYPOTHESIS = False

from repro.core.swrr import swrr_select


def _run(weights, steps):
    K, M = weights.shape
    cw = jnp.zeros_like(weights)
    counts = np.zeros((K, M))
    fn = jax.jit(swrr_select)
    for _ in range(steps):
        c, cw, valid = fn(weights, cw)
        for k in range(K):
            counts[k, int(c[k])] += 1
    return counts


def test_proportional_shares():
    w = jnp.asarray([[0.5, 0.3, 0.2]])
    counts = _run(w, 1000)
    np.testing.assert_allclose(counts[0] / 1000, [0.5, 0.3, 0.2], atol=0.01)


def test_smoothness_no_bursts():
    # weight 2/5: classic SWRR never schedules the same arm 3x in a row
    w = jnp.asarray([[0.4, 0.3, 0.3]])
    cw = jnp.zeros_like(w)
    last, run_len, max_run = -1, 0, 0
    for _ in range(500):
        c, cw, _ = swrr_select(w, cw)
        c = int(c[0])
        run_len = run_len + 1 if c == last else 1
        last = c
        max_run = max(max_run, run_len)
    assert max_run <= 2


def test_zero_weights_flagged_invalid():
    w = jnp.zeros((2, 3))
    c, cw, valid = swrr_select(w, jnp.zeros_like(w))
    assert not bool(valid[0]) and not bool(valid[1])


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=20)
    @given(st.lists(st.floats(0.01, 1.0), min_size=2, max_size=6),
           st.integers(200, 400))
    def test_share_error_bounded(ws, steps):
        w = np.asarray(ws)
        w = w / w.sum()
        counts = _run(jnp.asarray(w[None]), steps)
        # SWRR share error is O(1) per arm, not O(steps)
        err = np.abs(counts[0] - w * steps)
        assert (err <= len(ws) + 1).all()
else:
    def test_share_error_property_needs_hypothesis():
        pytest.importorskip("hypothesis")
