"""XLA blockwise flash attention (the non-TPU production path) vs ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.xla_flash import attention_blockwise, decode_attention_lowcast

# XLA-only impls (no Pallas body): the marker keeps them in the CI
# kernel lane, but there is no interpret variant to parametrize over.
pytestmark = pytest.mark.kernels

RNG = np.random.default_rng(3)


@pytest.mark.parametrize("B,Hq,Hkv,S,D,win,blk", [
    (2, 4, 2, 512, 32, None, 128),
    (1, 8, 2, 384, 64, None, 128),     # ragged
    (1, 4, 1, 512, 32, 100, 128),      # window
    (1, 2, 2, 256, 32, None, 256),     # single block pair
])
def test_blockwise_matches_ref(B, Hq, Hkv, S, D, win, blk):
    q = jnp.asarray(RNG.normal(0, 1, (B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (B, Hkv, S, D)), jnp.float32)
    got = attention_blockwise(q, k, v, causal=True, window=win, block=blk)
    want = ref.attention(q, k, v, causal=True, window=win)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_blockwise_noncausal():
    q = jnp.asarray(RNG.normal(0, 1, (1, 2, 256, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (1, 2, 256, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (1, 2, 256, 32)), jnp.float32)
    got = attention_blockwise(q, k, v, causal=False, block=64)
    want = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_lowcast_decode_matches_ref():
    B, Hq, Hkv, S, D = 2, 8, 2, 300, 64
    q = jnp.asarray(RNG.normal(0, 1, (B, Hq, D)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(0, 1, (B, Hkv, S, D)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(0, 1, (B, Hkv, S, D)), jnp.bfloat16)
    ln = jnp.asarray([250, 30], jnp.int32)
    got = decode_attention_lowcast(q, k, v, ln)
    want = ref.decode_attention(q, k, v, ln)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)
