"""Config registry: exact published numbers + applicability matrix."""
import pytest

from repro.configs import (ARCH_NAMES, SHAPES, all_cells, get_config,
                           get_shape, shape_applicable)

PUBLISHED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
    "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
    "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
    "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
    "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
}

PARAM_BANDS = {     # billions, generous bands around published sizes
    "mistral-nemo-12b": (11.5, 13.0),
    "gemma3-1b": (0.9, 1.1),
    "qwen2.5-14b": (13.5, 15.5),
    "qwen3-4b": (3.8, 4.6),
    "hymba-1.5b": (1.3, 1.6),
    "qwen3-moe-235b-a22b": (225, 245),
    "qwen3-moe-30b-a3b": (29, 32),
    "internvl2-1b": (0.4, 0.6),
    "whisper-tiny": (0.03, 0.08),
    "mamba2-1.3b": (1.2, 1.5),
}


@pytest.mark.parametrize("arch", sorted(PUBLISHED))
def test_published_dims(arch):
    c = get_config(arch)
    L, d, h, kv, ff, v = PUBLISHED[arch]
    assert c.num_layers == L and c.d_model == d
    assert c.num_heads == h and c.num_kv_heads == kv
    assert c.d_ff == ff and c.vocab_size == v


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_counts_in_band(arch):
    c = get_config(arch)
    lo, hi = PARAM_BANDS[arch]
    n = c.param_count() / 1e9
    assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    c = get_config("qwen3-moe-235b-a22b")
    assert c.num_experts == 128 and c.experts_per_token == 8
    assert 20 <= c.active_param_count() / 1e9 <= 24      # A22B


def test_applicability_matrix():
    cells = all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    assert len(runnable) == 33
    skipped = {(a, s) for a, s, ok, _ in cells if not ok}
    assert all(s == "long_500k" for _, s in skipped)
    assert ("mamba2-1.3b", "long_500k") not in skipped
    assert ("hymba-1.5b", "long_500k") not in skipped
    assert ("gemma3-1b", "long_500k") not in skipped


def test_reduced_preserves_family_structure():
    for arch in ARCH_NAMES:
        c = get_config(arch)
        r = get_config(arch, reduced=True)
        assert r.family == c.family
        assert r.is_moe == c.is_moe
        assert (r.local_global_pattern is None) == \
            (c.local_global_pattern is None)
        assert r.num_layers <= 2 or c.local_global_pattern


def test_shapes_exact():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].kind == "decode"
