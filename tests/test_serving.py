"""Serving runtime: generation loop + QEdgeProxy replica routing."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import BanditParams
from repro.models import build_model
from repro.serving import QEdgeRouter, ServingEngine, generate


def test_generate_produces_tokens():
    cfg = dataclasses.replace(get_config("qwen3-4b", reduced=True),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    toks = generate(model, params, prompt, steps=5)
    assert toks.shape == (2, 5)
    assert bool(((toks >= 0) & (toks < cfg.vocab_size)).all())


def test_generate_deterministic_greedy():
    cfg = dataclasses.replace(get_config("mamba2-1.3b", reduced=True),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    t1 = generate(model, params, prompt, steps=4)
    t2 = generate(model, params, prompt, steps=4)
    np.testing.assert_array_equal(t1, t2)


def test_router_learns_to_avoid_slow_replica():
    """The paper's mechanism as straggler mitigation (virtual time)."""
    from repro.core import bandit as qb
    router = QEdgeRouter(
        2, 3, BanditParams(tau=0.1, rho=0.9, window=5.0, cooldown=2.0),
        seed=0)
    rng = np.random.default_rng(0)
    t = 0.0
    slow_hits = total = 0
    for step in range(400):
        choices = router.route()
        if step >= 200:
            slow_hits += int((np.asarray(choices) == 1).sum())
            total += 2
        lat = np.where(np.asarray(choices) == 1,
                       0.5, rng.uniform(0.01, 0.05, 2))
        router.state = qb.record(
            router.state, router.params, jnp.asarray(choices),
            jnp.asarray(lat, jnp.float32), jnp.float32(t),
            jnp.ones((2,), bool))
        if step % 10 == 9:
            router.state = qb.maintenance(
                router.state, router.params, router.rtt, jnp.float32(t))
        t += 0.05
    # the straggler is learned (mu ~ 0) and its traffic share is bounded
    # by the exploration budget + cooldown duty cycle (paper Alg 1/2)
    assert router.qos_estimates[:, 1].max() < 0.05
    assert slow_hits / total < 0.15, (slow_hits, total)


def test_router_masks_dead_replicas_on_mesh_shrink():
    """elastic.py step 3: a data-axis shrink reaches the router at
    once via surviving_replicas — no cooldown trip needed."""
    from repro.fault.elastic import surviving_replicas
    router = QEdgeRouter(3, 4, BanditParams(), seed=2)
    router.mesh_resized(2)          # lost the last two replica groups
    np.testing.assert_array_equal(np.asarray(router.state.active),
                                  surviving_replicas(4, 2))
    w = router.weights
    assert np.abs(w[:, 2:]).max() == 0.0
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)
    for _ in range(20):             # no microbatch routes to the dead rows
        assert np.asarray(router.route()).max() < 2
    router.mesh_resized(4)          # capacity returns: Alg 3 ramp
    assert bool(np.asarray(router.state.active).all())
    assert np.abs(router.weights[:, 2:]).max() == 0.0


def test_router_failover_and_rejoin():
    router = QEdgeRouter(2, 3, BanditParams(), seed=1)
    router.replica_failed(2)
    w = router.weights
    assert np.abs(w[:, 2]).max() == 0.0
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)
    router.replica_joined(2)
    assert bool(router.state.active[2])
    # joins with zero weight until feedback accrues (Alg 3)
    assert np.abs(router.weights[:, 2]).max() == 0.0
