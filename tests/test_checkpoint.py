"""Checkpointer: round trip, atomicity, GC, async, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


@pytest.fixture
def tree():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4),
                   "b": jnp.ones((4,))},
        "step": jnp.int32(7),
        "nested": [jnp.zeros((2, 2)), jnp.full((3,), 5.0)],
    }


def test_roundtrip(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(10, tree)
    restored, step = ck.restore(tree)
    assert step == 10
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 tree, restored)


def test_latest_and_gc(tmp_path, tree):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_async_save(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, tree, blocking=False)
    ck.wait()
    restored, step = ck.restore(tree)
    assert step == 5


def test_no_tmp_dirs_left(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree)
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_restore_specific_step(tmp_path, tree):
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(1, tree)
    tree2 = jax.tree.map(lambda x: x + 1, tree)
    ck.save(2, tree2)
    restored, step = ck.restore(tree, step=1)
    np.testing.assert_array_equal(restored["params"]["w"],
                                  tree["params"]["w"])


def test_restore_missing_raises(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore(tree)
