"""Checkpointer: round trip, atomicity, GC, async, elastic restore,
and integrity (checksum + schema version refuse corrupted resumes)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (SCHEMA_VERSION, CheckpointCorruptError,
                              Checkpointer)


@pytest.fixture
def tree():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4),
                   "b": jnp.ones((4,))},
        "step": jnp.int32(7),
        "nested": [jnp.zeros((2, 2)), jnp.full((3,), 5.0)],
    }


def test_roundtrip(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(10, tree)
    restored, step = ck.restore(tree)
    assert step == 10
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 tree, restored)


def test_latest_and_gc(tmp_path, tree):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_async_save(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, tree, blocking=False)
    ck.wait()
    restored, step = ck.restore(tree)
    assert step == 5


def test_no_tmp_dirs_left(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree)
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_restore_specific_step(tmp_path, tree):
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(1, tree)
    tree2 = jax.tree.map(lambda x: x + 1, tree)
    ck.save(2, tree2)
    restored, step = ck.restore(tree, step=1)
    np.testing.assert_array_equal(restored["params"]["w"],
                                  tree["params"]["w"])


def test_restore_missing_raises(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore(tree)


# ---------------------------------------------------------------------------
# Integrity: schema version + content checksum.
# ---------------------------------------------------------------------------

def _npz_path(tmp_path, step):
    return os.path.join(str(tmp_path), f"step_{step:08d}", "arrays.npz")


def _manifest_path(tmp_path, step):
    return os.path.join(str(tmp_path), f"step_{step:08d}", "manifest.json")


def test_manifest_carries_schema_and_checksum(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, tree)
    with open(_manifest_path(tmp_path, 3)) as f:
        m = json.load(f)
    assert m["schema"] == SCHEMA_VERSION
    assert m["checksum"].startswith("sha256:")
    assert ck.verify(3)["step"] == 3


def test_truncated_checkpoint_refused(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(4, tree)
    npz = _npz_path(tmp_path, 4)
    size = os.path.getsize(npz)
    with open(npz, "r+b") as f:
        f.truncate(size // 2)
    with pytest.raises(CheckpointCorruptError, match="checksum mismatch"):
        ck.restore(tree)


def test_bitflipped_checkpoint_refused(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, tree)
    npz = _npz_path(tmp_path, 5)
    with open(npz, "r+b") as f:
        f.seek(os.path.getsize(npz) // 2)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(CheckpointCorruptError, match="checksum mismatch"):
        ck.restore(tree)


def test_garbage_manifest_refused(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(6, tree)
    with open(_manifest_path(tmp_path, 6), "w") as f:
        f.write("{not json")
    with pytest.raises(CheckpointCorruptError, match="unreadable manifest"):
        ck.restore(tree)


def test_future_schema_refused(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(7, tree)
    path = _manifest_path(tmp_path, 7)
    with open(path) as f:
        m = json.load(f)
    m["schema"] = SCHEMA_VERSION + 1
    with open(path, "w") as f:
        json.dump(m, f)
    with pytest.raises(CheckpointCorruptError, match="schema version"):
        ck.restore(tree)


def test_v1_checkpoint_without_checksum_still_restores(tmp_path, tree):
    # pre-integrity checkpoints have neither schema nor checksum fields;
    # they must keep restoring (manifest-only check)
    ck = Checkpointer(str(tmp_path))
    ck.save(8, tree)
    path = _manifest_path(tmp_path, 8)
    with open(path) as f:
        m = json.load(f)
    del m["schema"], m["checksum"]
    with open(path, "w") as f:
        json.dump(m, f)
    restored, step = ck.restore(tree)
    assert step == 8
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 tree, restored)
