"""Multi-tenant continuum: S services on one shared fleet — and the
degenerate-parity contract the tenant axis ships under.

Four invariant families:

1. **S=1 degenerate parity** — ``tenancy=None`` and a degenerate
   ``TenancyConfig(taus=(cfg.tau,))`` lower to the byte-identical HLO
   for every strategy x {plain, resilient, controlled} x fused/unfused
   (the gate is Python-level static config, not a traced branch), the
   degenerate program reproduces the committed HEAD golden
   (``tests/data/neutral_stream_ref.npz``) bit-for-bit including
   through the chunked streaming loop, and (subprocess) the
   player-sharded program text stays byte-identical at 8/2/1-way.
2. **S>1 execution parity** — player-sharded tenant runs reproduce the
   unsharded stream exactly on every counting stat at 8/2/1-way,
   chunked == unchunked bit-for-bit, and killed-and-resumed
   checkpoint streams match the uninterrupted run on every per-tenant
   accumulator field.
3. **Tenant-engine semantics** — per-tenant issued counts follow the
   per-tenant client schedules, cross-service interference and
   per-tenant service scales degrade QoS monotonically, and the
   compositions the engine statically refuses (trace mode, resilience,
   control plane, flight recorder, explicit params) raise.
4. **Fairness indices** — Gini/Jain/Herfindahl property tests: bounds,
   permutation and scale invariance, all-equal and one-hot degenerate
   cases, the Jain = 1/(n*HHI) identity, and agreement with the O(S^2)
   mean-absolute-difference Gini reference. Driven by ``hypothesis``
   when installed, and by a seeded 300-vector random sweep through the
   SAME property checkers when it is not (this container ships no
   hypothesis), so the properties are exercised either way.
"""
import dataclasses
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_sub
from repro.continuum import (SimConfig, TenancyConfig, broadcast_tenants,
                             compile_tenant_scenario, get_tenant_library,
                             gini_index, herfindahl_index, jain_index,
                             make_topology, neutral_drivers, run_sim_stream,
                             tenant_drivers, tenant_neutral_drivers)
from repro.continuum import metrics as qm
from repro.continuum import scenarios as qs
from repro.continuum.control import ControlConfig
from repro.continuum.simulator import build_sim_fn
from repro.obs import RecorderConfig

K, M = 10, 4
CFG = SimConfig(horizon=12.0)
WARM = 30
STRATEGIES = (("qedgeproxy", {}), ("proxy_mity", dict(alpha=0.9)),
              ("dec_sarsa", {}))
REF = os.path.join(os.path.dirname(__file__), "data",
                   "neutral_stream_ref.npz")
# the engine-layer variants the degenerate config must not perturb
VARIANTS = (
    ("plain", {}),
    ("resilient", dict(attempt_timeout=0.090, max_retries=2,
                       retry_backoff=0.002, breaker_threshold=5,
                       breaker_cooldown=1.0)),
    ("controlled", dict(control=ControlConfig(
        managed=2, warmup=0.5, up_queue=2.0, down_queue=0.3, hold=0.3,
        action_cooldown=1.0, batch=1, admit=True, target_queue=3.0,
        admit_floor=0.3))),
)
# an honestly multi-tenant config: tight foreground + relaxed batch
TN2 = TenancyConfig(taus=(CFG.tau, 0.150), interference=0.3)
CFG2 = dataclasses.replace(CFG, tenancy=TN2)


def _inputs():
    rtt = make_topology(jax.random.PRNGKey(2), K, M).lb_instance_rtt()
    return rtt, jax.random.PRNGKey(5)


def _tenant_qos(acc) -> float:
    return (np.asarray(acc.succ_kc, np.float64).sum()
            / max(np.asarray(acc.n_kc, np.float64).sum(), 1.0))


# -- invariant 1: S=1 degenerate parity ---------------------------------

def test_tenancy_config_validation():
    assert TenancyConfig(taus=(0.08,)).S == 1
    assert not TenancyConfig(taus=(0.08,)).enabled
    assert TenancyConfig(taus=(0.08, 0.15)).enabled
    assert TenancyConfig(taus=(0.08, 0.15)).scales == (1.0, 1.0)
    assert not SimConfig().tenancy_on
    assert not dataclasses.replace(
        CFG, tenancy=TenancyConfig(taus=(CFG.tau,))).tenancy_on
    assert CFG2.tenancy_on
    with pytest.raises(ValueError, match="at least one"):
        TenancyConfig(taus=())
    with pytest.raises(ValueError, match="positive"):
        TenancyConfig(taus=(0.08, -0.1))
    with pytest.raises(ValueError, match="service_scale"):
        TenancyConfig(taus=(0.08, 0.15), service_scale=(1.0,))
    with pytest.raises(ValueError, match="interference"):
        TenancyConfig(taus=(0.08,), interference=-0.5)


def test_degenerate_s1_must_match_scalar_knobs():
    """An S=1 config that disagrees with the scalar tau/s_m the
    single-service path reads is refused, not silently ignored."""
    rtt, key = _inputs()
    for tn in (TenancyConfig(taus=(0.999,)),
               TenancyConfig(taus=(CFG.tau,), service_scale=(2.0,))):
        cfg = dataclasses.replace(CFG, tenancy=tn)
        with pytest.raises(ValueError, match="S=1 TenancyConfig"):
            build_sim_fn("qedgeproxy", cfg, K, M, trace=False,
                         warmup_steps=WARM)


@pytest.mark.parametrize("fused", (False, True), ids=("scan", "fusedround"))
@pytest.mark.parametrize("vlabel,vkw", VARIANTS, ids=[v for v, _ in VARIANTS])
@pytest.mark.parametrize("strat,kw", STRATEGIES,
                         ids=[s for s, _ in STRATEGIES])
def test_neutral_hlo_byte_identity(strat, kw, vlabel, vkw, fused):
    """``tenancy=None`` and the degenerate S=1 TenancyConfig lower to
    the SAME program text across strategies x engine variants x
    fused/unfused: parity is structural, not numerical luck."""
    rtt, key = _inputs()
    drv = neutral_drivers(CFG, K, M)
    texts = []
    for tn in (None, TenancyConfig(taus=(CFG.tau,))):
        cfg = dataclasses.replace(CFG, tenancy=tn, **vkw)
        run = build_sim_fn(strat, cfg, K, M, fused=fused, trace=False,
                           warmup_steps=WARM, **kw)
        texts.append(jax.jit(run).lower(rtt, drv, key).as_text())
    assert texts[0] == texts[1], f"{strat}/{vlabel}/fused={fused}"


@pytest.mark.parametrize("strat,kw", STRATEGIES,
                         ids=[s for s, _ in STRATEGIES])
def test_degenerate_bit_identity_vs_head(strat, kw):
    """The degenerate S=1 program reproduces the committed HEAD golden
    bit-for-bit — also through the chunked streaming loop — and keeps
    the single-service output shape (one accumulator, (T,) series)."""
    rtt, key = _inputs()
    ref = np.load(REF)
    cfg = dataclasses.replace(CFG, tenancy=TenancyConfig(taus=(CFG.tau,)))
    for chunk in (None, 25):
        out = run_sim_stream(strat, rtt, cfg, key, warmup_steps=WARM,
                             chunk_steps=chunk, **kw)
        assert isinstance(out.acc, qm.MetricAccumulator)
        assert np.asarray(out.series.succ).ndim == 1
        for f in out.acc._fields:
            if f"{strat}.acc.{f}" in ref.files:
                np.testing.assert_array_equal(
                    np.asarray(getattr(out.acc, f)),
                    ref[f"{strat}.acc.{f}"],
                    err_msg=f"{strat} chunk={chunk} acc.{f}")
        for f in out.series._fields:
            if f"{strat}.series.{f}" in ref.files:
                np.testing.assert_array_equal(
                    np.asarray(getattr(out.series, f)),
                    ref[f"{strat}.series.{f}"],
                    err_msg=f"{strat} chunk={chunk} series.{f}")


@pytest.mark.slow
def test_degenerate_sharded_hlo_byte_identity_8dev():
    """The player-sharded program text stays byte-identical between
    ``tenancy=None`` and the degenerate S=1 config at 8-, 2- and 1-way
    player sharding: the static gate composes with shard_map."""
    out = run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.continuum import (SimConfig, TenancyConfig,
                                     make_topology, neutral_drivers)
        from repro.continuum.simulator import build_sim_players_fn
        from repro.launch.mesh import make_continuum_mesh

        K, M, WARM = 16, 4, 10
        cfg0 = SimConfig(horizon=3.0)
        rtt = make_topology(jax.random.PRNGKey(0), K, M).lb_instance_rtt()
        key = jax.random.PRNGKey(7)
        drv = neutral_drivers(cfg0, K, M)
        for D in (8, 2, 1):
            mesh = make_continuum_mesh(players=D,
                                       devices=jax.devices()[:D])
            texts = []
            for tn in (None, TenancyConfig(taus=(cfg0.tau,))):
                cfg = dataclasses.replace(cfg0, tenancy=tn)
                run, _ = build_sim_players_fn("qedgeproxy", cfg, K, M,
                                              mesh=mesh,
                                              warmup_steps=WARM)
                texts.append(
                    jax.jit(run).lower(rtt, drv, key).as_text())
            assert texts[0] == texts[1], f"D={D} sharded HLO differs"
            print(f"D={D} identical")
        print("OK degenerate sharded parity")
    """)
    assert "OK degenerate sharded parity" in out


# -- invariant 2: S>1 execution parity ----------------------------------

def test_tenant_chunked_matches_unchunked():
    rtt, key = _inputs()
    drv = tenant_neutral_drivers(CFG2, 2, K, M, base_clients=1)
    full = run_sim_stream("qedgeproxy", rtt, CFG2, key, drivers=drv,
                          warmup_steps=WARM)
    chun = run_sim_stream("qedgeproxy", rtt, CFG2, key, drivers=drv,
                          warmup_steps=WARM, chunk_steps=25)
    assert isinstance(full.acc, tuple) and len(full.acc) == 2
    for s, (a_full, a_chun) in enumerate(zip(full.acc, chun.acc)):
        for f in a_full._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(a_chun, f)),
                np.asarray(getattr(a_full, f)),
                err_msg=f"tenant {s} acc.{f}")
    for f in full.series._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(chun.series, f)),
            np.asarray(getattr(full.series, f)), err_msg=f"series.{f}")


def test_tenant_checkpoint_resume_exact(tmp_path):
    """Killed-and-resumed == uninterrupted with the per-tenant bandit
    fleets and the (S, M) queue in the carry — including under a
    different resumed chunk length."""
    rtt, key = _inputs()
    drv = tenant_neutral_drivers(CFG2, 2, K, M, base_clients=1)
    d = str(tmp_path / "ck")
    full = run_sim_stream("qedgeproxy", rtt, CFG2, key, drivers=drv,
                          warmup_steps=WARM, chunk_steps=40)
    part = run_sim_stream("qedgeproxy", rtt, CFG2, key, drivers=drv,
                          warmup_steps=WARM, chunk_steps=40,
                          checkpoint_dir=d, stop_at_step=80)
    assert len(np.asarray(part.series.succ)) == 80
    res = run_sim_stream("qedgeproxy", rtt, CFG2, key, drivers=drv,
                         warmup_steps=WARM, chunk_steps=25,
                         checkpoint_dir=d, resume=True)
    for s, (a_full, a_res) in enumerate(zip(full.acc, res.acc)):
        for f in a_full._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(a_res, f)),
                np.asarray(getattr(a_full, f)),
                err_msg=f"tenant {s} acc.{f}")
    for f in full.series._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res.series, f)),
            np.asarray(getattr(full.series, f)), err_msg=f"series.{f}")
    shutil.rmtree(d)


@pytest.mark.slow
def test_tenant_sharded_matches_unsharded_8dev():
    """Player-sharded S=2 tenant runs reproduce the unsharded stream:
    every counting stat exact at 8/2/1-way (float fields to f32
    reassociation tolerance) — per-player noise is keyed by global
    player id and the single per-round psum carries the stacked (S, M)
    arrival matrix, so shard width never changes the round."""
    out = run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.continuum import (SimConfig, TenancyConfig,
                                     compile_tenant_scenario,
                                     get_tenant_library, make_topology,
                                     run_sim_players, run_sim_stream)
        from repro.launch.mesh import make_continuum_mesh

        K, M, WARM = 16, 6, 10
        tn = TenancyConfig(taus=(0.080, 0.150), interference=0.3)
        cfg = SimConfig(horizon=4.0, tenancy=tn)
        rtt = make_topology(jax.random.PRNGKey(0), K, M).lb_instance_rtt()
        key = jax.random.PRNGKey(7)
        lib = get_tenant_library(cfg.horizon, K, M, n_tenants=2)
        drv = compile_tenant_scenario(lib["mt_tenant_surge"], cfg,
                                      jax.random.PRNGKey(3))
        COUNTS = {"succ_kc", "n_kc", "arrivals_m", "choice_counts",
                  "proc_hist", "steps_measured", "ev_succ", "ev_n",
                  "att_k", "timeout_k", "drop_k", "open_km"}
        for strat, kw in (("qedgeproxy", {}), ("dec_sarsa", {}),
                          ("proxy_mity", dict(alpha=0.9))):
            ref = run_sim_stream(strat, rtt, cfg, key, drivers=drv,
                                 warmup_steps=WARM, **kw)
            for D in (8, 2, 1):
                mesh = make_continuum_mesh(
                    players=D, devices=jax.devices()[:D])
                got = run_sim_players(
                    strat, rtt, cfg, key, drivers=drv,
                    warmup_steps=WARM, mesh=mesh, **kw)
                for s in range(2):
                    for name in ref.acc[s]._fields:
                        a = np.asarray(getattr(ref.acc[s], name))
                        b = np.asarray(getattr(got.acc[s], name))
                        if name in COUNTS:
                            np.testing.assert_array_equal(
                                b, a,
                                err_msg=f"{strat} D{D} t{s} {name}")
                        else:
                            np.testing.assert_allclose(
                                b, a, rtol=2e-5, atol=2e-5,
                                err_msg=f"{strat} D{D} t{s} {name}")
                np.testing.assert_array_equal(
                    np.asarray(got.series.issued),
                    np.asarray(ref.series.issued),
                    err_msg=f"{strat} D{D} series.issued")
            print(strat, "tenant parity ok")
        print("OK tenant parity")
    """)
    assert "OK tenant parity" in out


# -- invariant 3: tenant-engine semantics -------------------------------

def test_tenant_counts_follow_schedules():
    """Each tenant's issued/arrival totals follow ITS client schedule,
    and the (T, S) series columns agree with the per-tenant accs."""
    rtt, key = _inputs()
    drv = tenant_neutral_drivers(CFG2, 2, K, M, base_clients=1)
    # give tenant 1 twice the clients of tenant 0
    nc = np.asarray(drv.n_clients).copy()
    nc[:, 1, :] *= 2
    drv = drv._replace(n_clients=jnp.asarray(nc))
    out = run_sim_stream("qedgeproxy", rtt, CFG2, key, drivers=drv,
                         warmup_steps=WARM)
    T_meas = CFG2.num_steps - WARM
    issued = [float(np.asarray(a.n_kc).sum()) for a in out.acc]
    assert issued[0] == T_meas * K * 1
    assert issued[1] == T_meas * K * 2
    for s, a in enumerate(out.acc):
        assert float(np.asarray(a.arrivals_m).sum()) == issued[s]
    # series columns are per-tenant: full-horizon totals dominate the
    # post-warmup accumulator totals, in the same 1:2 ratio
    col = np.asarray(out.series.issued)
    assert col.shape == (CFG2.num_steps, 2)
    np.testing.assert_array_equal(col.sum(0),
                                  [CFG2.num_steps * K, CFG2.num_steps * K * 2])


def test_interference_degrades_qos_monotonically():
    rtt, key = _inputs()
    qos = []
    for xi in (0.0, 1.0):
        cfg = dataclasses.replace(
            CFG, tenancy=TenancyConfig(taus=(CFG.tau, CFG.tau),
                                       interference=xi))
        drv = tenant_neutral_drivers(cfg, 2, K, M, base_clients=2)
        out = run_sim_stream("qedgeproxy", rtt, cfg, key, drivers=drv,
                             warmup_steps=WARM)
        qos.append(np.mean([_tenant_qos(a) for a in out.acc]))
    assert qos[1] < qos[0], qos


def test_service_scale_slows_heavy_tenant():
    """Same tau, but tenant 1's requests are 4x heavier: its QoS must
    come out no better — and the shared queue drags tenant 0 too, so
    both sit below the all-light baseline."""
    rtt, key = _inputs()
    base_tn = TenancyConfig(taus=(CFG.tau, CFG.tau))
    heavy_tn = TenancyConfig(taus=(CFG.tau, CFG.tau),
                             service_scale=(1.0, 4.0))
    qos = {}
    for name, tn in (("base", base_tn), ("heavy", heavy_tn)):
        cfg = dataclasses.replace(CFG, tenancy=tn)
        drv = tenant_neutral_drivers(cfg, 2, K, M, base_clients=2)
        out = run_sim_stream("qedgeproxy", rtt, cfg, key, drivers=drv,
                             warmup_steps=WARM)
        qos[name] = [_tenant_qos(a) for a in out.acc]
    assert qos["heavy"][1] <= qos["base"][1]
    assert np.mean(qos["heavy"]) < np.mean(qos["base"])


def test_tenant_composition_refusals():
    rtt, key = _inputs()
    with pytest.raises(ValueError, match="streaming-only"):
        build_sim_fn("qedgeproxy", CFG2, K, M, trace=True)
    with pytest.raises(ValueError, match="resilience"):
        build_sim_fn("qedgeproxy",
                     dataclasses.replace(CFG2, attempt_timeout=0.09,
                                         max_retries=2),
                     K, M, trace=False)
    with pytest.raises(ValueError, match="control"):
        build_sim_fn("qedgeproxy",
                     dataclasses.replace(CFG2, control=ControlConfig(
                         admit=True)),
                     K, M, trace=False)
    with pytest.raises(ValueError, match="recorder"):
        build_sim_fn("qedgeproxy",
                     dataclasses.replace(CFG2, recorder=RecorderConfig(
                         capacity=64)),
                     K, M, trace=False)
    with pytest.raises(ValueError, match="params"):
        from repro.core.bandit import BanditParams
        build_sim_fn("qedgeproxy", CFG2, K, M, trace=False,
                     params=BanditParams(tau=CFG.tau))
    # tenant configs need tenant-axis drivers: a (T, K) schedule from
    # the single-service path is refused with guidance
    run = build_sim_fn("qedgeproxy", CFG2, K, M, trace=False,
                       warmup_steps=WARM)
    with pytest.raises(ValueError, match="tenant"):
        run(rtt, neutral_drivers(CFG2, K, M), key)


def test_tenant_driver_merge():
    """``tenant_drivers`` stacks client schedules on axis 1, ANDs the
    activity masks, and takes the pessimal (max) modulation rows."""
    cfg = dataclasses.replace(CFG2, horizon=2.0)
    base = qs.neutral_drivers(cfg, K, M, base_clients=1)
    a = np.asarray(base.active).copy()
    a[:, 0] = False
    other = base._replace(
        active=jnp.asarray(a),
        rtt_scale=base.rtt_scale * 2.0,
        n_clients=base.n_clients * 3)
    drv = tenant_drivers([base, other])
    assert drv.n_clients.shape == (cfg.num_steps, 2, K)
    np.testing.assert_array_equal(np.asarray(drv.n_clients[:, 1]),
                                  np.asarray(other.n_clients))
    assert not np.asarray(drv.active)[:, 0].any()
    np.testing.assert_array_equal(np.asarray(drv.rtt_scale),
                                  np.asarray(other.rtt_scale))
    # ANDing to a dead fleet is refused
    dead = base._replace(active=jnp.zeros_like(base.active, bool))
    with pytest.raises(ValueError, match="no instance"):
        tenant_drivers([base, dead])
    # broadcast_tenants replicates a (T, K) schedule per tenant
    b = broadcast_tenants(base, 3)
    assert b.n_clients.shape == (cfg.num_steps, 3, K)
    with pytest.raises(ValueError, match="tenant"):
        broadcast_tenants(b, 2)


def test_tenant_library_compiles():
    cfg = dataclasses.replace(CFG2, horizon=3.0)
    lib = get_tenant_library(cfg.horizon, K, M, n_tenants=2)
    assert set(lib) == {"mt_baseline", "mt_tenant_surge",
                       "mt_noisy_neighbor", "mt_priority_inversion"}
    for name, tscn in lib.items():
        drv = compile_tenant_scenario(tscn, cfg, jax.random.PRNGKey(0))
        assert drv.n_clients.shape == (cfg.num_steps, 2, K), name
        assert drv.active.shape == (cfg.num_steps, M), name
    with pytest.raises(ValueError, match="tenants"):
        get_tenant_library(cfg.horizon, K, M, n_tenants=1)


# -- invariant 4: fairness-index properties -----------------------------

def _gini_reference(x: np.ndarray) -> float:
    """O(S^2) mean-absolute-difference definition."""
    x = np.asarray(x, np.float64)
    n = x.size
    mu = x.mean()
    if n == 0 or mu <= 0:
        return 0.0
    return float(np.abs(x[:, None] - x[None, :]).sum() / (2 * n * n * mu))


def _check_fairness_properties(x: np.ndarray, rng: np.random.Generator):
    """The full property battery on one non-negative vector — shared by
    the hypothesis harness and the seeded fallback sweep."""
    n = x.size
    g, j, h = gini_index(x), jain_index(x), herfindahl_index(x)
    # bounds
    assert 0.0 <= g <= 1.0 + 1e-9
    assert 1.0 / n - 1e-9 <= j <= 1.0 + 1e-9
    assert 1.0 / n - 1e-9 <= h <= 1.0 + 1e-9
    # permutation invariance
    p = rng.permutation(x)
    assert gini_index(p) == pytest.approx(g, abs=1e-9)
    assert jain_index(p) == pytest.approx(j, abs=1e-9)
    assert herfindahl_index(p) == pytest.approx(h, abs=1e-9)
    # scale invariance
    for c in (7.5, 1e-3):
        assert gini_index(c * x) == pytest.approx(g, rel=1e-6, abs=1e-9)
        assert jain_index(c * x) == pytest.approx(j, rel=1e-6, abs=1e-9)
        assert herfindahl_index(c * x) == pytest.approx(h, rel=1e-6,
                                                       abs=1e-9)
    # O(S^2) Gini reference
    assert g == pytest.approx(_gini_reference(x), abs=1e-7)
    # Jain = 1/(n*HHI) on non-degenerate vectors
    if x.sum() > 0:
        assert j == pytest.approx(1.0 / (n * h), rel=1e-9)


def test_fairness_degenerate_cases():
    for n in (1, 2, 5, 64):
        eq = np.full(n, 3.7)
        assert gini_index(eq) == pytest.approx(0.0, abs=1e-9)
        assert jain_index(eq) == pytest.approx(1.0)
        assert herfindahl_index(eq) == pytest.approx(1.0 / n)
        hot = np.zeros(n)
        hot[0] = 1.0
        assert gini_index(hot) == pytest.approx(1.0 - 1.0 / n, abs=1e-9)
        assert jain_index(hot) == pytest.approx(1.0 / n)
        assert herfindahl_index(hot) == pytest.approx(1.0)
    # zero/empty conventions
    assert gini_index([]) == 0.0
    assert jain_index([]) == 1.0
    assert herfindahl_index([]) == 0.0
    assert gini_index(np.zeros(4)) == 0.0
    assert jain_index(np.zeros(4)) == 1.0
    assert herfindahl_index(np.zeros(4)) == pytest.approx(0.25)


def test_fairness_properties_seeded_sweep():
    """300 seeded random vectors through the property battery — the
    always-on counterpart of the hypothesis harness below."""
    rng = np.random.default_rng(0)
    for i in range(300):
        n = int(rng.integers(1, 40))
        kind = i % 3
        if kind == 0:
            x = rng.uniform(0.0, 100.0, n)
        elif kind == 1:
            x = rng.exponential(5.0, n)     # heavy-tailed
        else:
            x = np.where(rng.uniform(size=n) < 0.5, 0.0,
                         rng.uniform(0.0, 10.0, n))  # sparse
        _check_fairness_properties(x, rng)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:                          # container has no hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(hst.lists(hst.floats(min_value=0.0, max_value=1e6,
                                allow_nan=False, allow_infinity=False),
                     min_size=1, max_size=64))
    def test_fairness_properties_hypothesis(xs):
        _check_fairness_properties(np.asarray(xs, np.float64),
                                   np.random.default_rng(1))
