"""Differential conformance harness for the fused round megakernel.

The repo's credibility rests on bit-exact parity claims, so the fused
round (kernels/ops.round_step behind ``SimConfig.fused_round``) ships
INSIDE this harness, not next to it: every counting statistic of a
fused run must equal the unfused round-scan engine bit for bit across
the full differential matrix — 3 strategies × resilience on/off ×
control on/off × 8/2/1-way player shards × chunked/unchunked — and the
same assertion must hold for every kernel backend (``ref`` oracle and
the Pallas body in interpret mode, via the shared ``kernel_mode``
fixture).

Two cells of the matrix exercise the fused kernel's *fallback*
contract rather than the kernel itself: resilience unrolls attempts
inside the round and player sharding needs the per-round (M,) arrival
psum (a collective cannot live inside a pallas_call), so there
``fused_round=True`` must statically fall back to the scan and change
nothing. Everywhere else the fused call is live and the comparison is
kernel-vs-scan.

Under CI's interpret lane (REPRO_KERNEL_MODE=interpret) the whole
module runs with the Pallas kernel body executing every fused round,
which is what "verified in interpret mode on CPU CI" means.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:        # pragma: no cover - exercised in slim containers
    HAVE_HYPOTHESIS = False

from conftest import run_sub
from repro.continuum import SimConfig, make_topology, run_sim_stream
from repro.continuum.control import ControlConfig
from repro.continuum.simulator import PlayerSharding, build_sim_parts

pytestmark = pytest.mark.kernels

K, M = 12, 4
HORIZON = 3.0
WARM = 5

STRATEGIES = (("qedgeproxy", {}), ("proxy_mity", dict(alpha=0.9)),
              ("dec_sarsa", {}))
# the closed-loop policy from tests/test_control.py, scaled to this
# testbed: standby instances, admission shedding, 2 regions
CTL = ControlConfig(managed=2, warmup=0.5, up_queue=2.0, down_queue=0.3,
                    hold=0.3, action_cooldown=1.0, batch=1,
                    admit=True, target_queue=3.0, admit_floor=0.3,
                    regions=2, mig_threshold=2.0, mig_step=0.1)
RES = dict(attempt_timeout=0.06, max_retries=1, breaker_threshold=3)


def _inputs(seed=0, k=K, m=M):
    rtt = make_topology(jax.random.PRNGKey(seed), k, m).lb_instance_rtt()
    return rtt, jax.random.PRNGKey(seed + 7)


def _assert_identical(fused, unfused, ctx=""):
    """Fused == unfused bit for bit: no cross-shard reduction separates
    the two programs, so EVERY accumulator field and series is exact —
    counting stats and floats alike."""
    for name in fused.acc._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(fused.acc, name)),
            np.asarray(getattr(unfused.acc, name)),
            err_msg=f"{ctx} acc.{name}")
    for name in fused.series._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(fused.series, name)),
            np.asarray(getattr(unfused.series, name)),
            err_msg=f"{ctx} series.{name}")


def _pair(strategy, seed=0, chunk=None, **cfg_kw):
    rtt, key = _inputs(seed)
    out = {}
    for fr in (True, False):
        cfg = SimConfig(horizon=HORIZON, fused_round=fr, **cfg_kw)
        kw = dict(STRATEGIES)[strategy]
        out[fr] = run_sim_stream(strategy, rtt, cfg, key,
                                 warmup_steps=WARM,
                                 chunk_steps=chunk if fr else None, **kw)
    return out[True], out[False]


# ---------------------------------------------------------------------------
# the core matrix: strategies × {open-loop, resilient, closed-loop}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", [s for s, _ in STRATEGIES])
@pytest.mark.parametrize("variant", ["plain", "resilient", "controlled"])
def test_fused_matches_unfused(strategy, variant):
    cfg_kw = {}
    if variant == "resilient":
        cfg_kw = dict(RES)       # fused statically falls back: must be a no-op
    elif variant == "controlled":
        cfg_kw = dict(control=CTL)
    fused, unfused = _pair(strategy, **cfg_kw)
    _assert_identical(fused, unfused, f"{strategy}/{variant}")


def test_gating_is_static():
    """The fused path must be OFF whenever a feature needs the
    per-round structure — asserted on the builder's traced program, not
    on run outputs (a fallback bug would otherwise only show as a perf
    regression)."""
    from repro.continuum.simulator import make_strategy
    cfg = SimConfig(horizon=HORIZON)
    # sequential engine (fused=False) never uses the megakernel
    init_fn, _ = build_sim_parts("qedgeproxy", cfg, K, M, fused=False,
                                 trace=False)
    # resilience on: build must succeed and stay bit-exact (covered
    # above); player sharding: must also build
    build_sim_parts("qedgeproxy", SimConfig(horizon=HORIZON, **RES),
                    K, M, trace=False)
    build_sim_parts("qedgeproxy", cfg, K, M, trace=False,
                    pshard=PlayerSharding("players", 2))
    # dec_sarsa advertises no fused_round closure
    assert make_strategy("dec_sarsa", cfg, K, M).get("fused_round") is None
    assert make_strategy("qedgeproxy", cfg, K, M).get("fused_round")
    assert make_strategy("proxy_mity", cfg, K, M).get("fused_round")


# ---------------------------------------------------------------------------
# kernel backends: the same differential assertion per ops mode
# ---------------------------------------------------------------------------

def test_round_kernel_conformance_per_mode(kernel_mode):
    """ref oracle AND Pallas-interpret kernel body, against the unfused
    scan — shorter horizon, interpret executes the kernel per step."""
    rtt, key = _inputs(3)
    cfg_f = SimConfig(horizon=1.5, fused_round=True)
    cfg_u = SimConfig(horizon=1.5, fused_round=False)
    fused = run_sim_stream("qedgeproxy", rtt, cfg_f, key)
    unfused = run_sim_stream("qedgeproxy", rtt, cfg_u, key)
    _assert_identical(fused, unfused, f"mode={kernel_mode}")


def test_round_kernel_block_padding(kernel_mode):
    """K not a multiple of the player block: padded rows must issue
    nothing and leave every output row untouched."""
    if kernel_mode == "ref":
        pytest.skip("direct kernel call: interpret covers the body; "
                    "the ref oracle IS the expected value")
    from repro.kernels import ref, round_fused
    k, m, C, R, Rq = 5, 3, 4, 8, 16
    rng = np.random.default_rng(11)
    args = dict(
        weights=jnp.asarray(rng.dirichlet(np.ones(m), k), jnp.float32),
        cw=jnp.asarray(rng.normal(0, 0.1, (k, m)), jnp.float32),
        err=jnp.asarray(rng.integers(0, 3, (k, m)), jnp.int32),
        cooldown_until=jnp.full((k, m), -1e30, jnp.float32),
        in_pool=jnp.ones((k, m), bool),
        active=jnp.ones((m,), bool),
        lat_buf=jnp.zeros((k, m, R), jnp.float32),
        ts_buf=jnp.full((k, m, R), -1e30, jnp.float32),
        ptr=jnp.asarray(rng.integers(0, R, (k, m)), jnp.int32),
        r_buf=jnp.zeros((k, Rq), jnp.float32),
        rts_buf=jnp.full((k, Rq), -1e30, jnp.float32),
        rptr=jnp.asarray(rng.integers(0, Rq, (k,)), jnp.int32),
        q=jnp.asarray(rng.uniform(0, 2, (m,)), jnp.float32),
        nc=jnp.asarray(rng.integers(0, C + 1, (k,)), jnp.int32),
        z=jnp.asarray(rng.lognormal(0, 0.25, (C, k)), jnp.float32),
        rtt_t=jnp.asarray(rng.uniform(0.005, 0.08, (k, m)), jnp.float32),
        s_m=jnp.full((m,), 0.0055, jnp.float32),
        served_per_round=jnp.full((m,), 0.1 / (C * 0.0055), jnp.float32),
        t=jnp.float32(2.0),
    )
    statics = dict(tau=0.08, err_thresh=2, cooldown=1.0)
    want = ref.round_step_swrr(**args, **statics)
    got = round_fused.round_step_swrr(
        **args, **statics, interpret=True,
        block_k=4)    # forces one padded block (5 -> 8 rows)
    for name, a, b in zip(want._fields, want, got):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a),
                                      err_msg=f"round_fused {name}")


# ---------------------------------------------------------------------------
# chunked horizons
# ---------------------------------------------------------------------------

def test_fused_chunked_matches_unfused_unchunked():
    fused_chunked, unfused = _pair("qedgeproxy", chunk=7)
    _assert_identical(fused_chunked, unfused, "chunked")


# ---------------------------------------------------------------------------
# player shards: 8/2/1-way sharded runs auto-fall-back to the scan and
# must still match the unsharded FUSED engine exactly on counting stats
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fused_vs_sharded_8dev():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.continuum import (SimConfig, make_topology,
                                     run_sim_players, run_sim_stream)
        from repro.launch.mesh import make_continuum_mesh

        K, M, WARM = 16, 4, 10
        rtt = make_topology(jax.random.PRNGKey(0), K, M).lb_instance_rtt()
        key = jax.random.PRNGKey(7)
        COUNTS = {"succ_kc", "n_kc", "arrivals_m", "choice_counts",
                  "proc_hist", "steps_measured", "ev_succ", "ev_n"}
        fused = run_sim_stream(
            "qedgeproxy", rtt, SimConfig(horizon=4.0, fused_round=True),
            key, warmup_steps=WARM)
        for D in (8, 2, 1):
            mesh = make_continuum_mesh(players=D, devices=jax.devices()[:D])
            got = run_sim_players(
                "qedgeproxy", rtt, SimConfig(horizon=4.0, fused_round=True),
                key, warmup_steps=WARM, mesh=mesh)
            for name in fused.acc._fields:
                a = np.asarray(getattr(fused.acc, name))
                b = np.asarray(getattr(got.acc, name))
                if name in COUNTS:
                    np.testing.assert_array_equal(
                        b, a, err_msg=f"D{D} {name}")
                else:
                    np.testing.assert_allclose(
                        b, a, rtol=1e-5, atol=1e-5, err_msg=f"D{D} {name}")
            np.testing.assert_array_equal(
                np.asarray(got.series.succ), np.asarray(fused.series.succ),
                err_msg=f"D{D} series.succ")
            print("D", D, "ok")
        print("OK fused-vs-sharded")
    """)
    assert "OK fused-vs-sharded" in out


# ---------------------------------------------------------------------------
# randomized configs (hypothesis optional, per PR 1 convention)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=5)
    @given(st.integers(0, 2**16), st.integers(1, 6), st.sampled_from([2, 3, 5]),
           st.sampled_from([8, 16, 12]), st.floats(0.05, 0.12),
           st.booleans())
    def test_fused_matches_unfused_random_config(seed, max_clients, m, ring,
                                                 tau, controlled):
        k = 7
        rtt = make_topology(jax.random.PRNGKey(seed), k, m).lb_instance_rtt()
        key = jax.random.PRNGKey(seed ^ 0x5bd1)
        cfg_kw = dict(horizon=1.5, max_clients=max_clients, ring=ring,
                      reward_ring=32, tau=tau,
                      control=CTL if controlled else None)
        fused = run_sim_stream(
            "qedgeproxy", rtt, SimConfig(fused_round=True, **cfg_kw), key)
        unfused = run_sim_stream(
            "qedgeproxy", rtt, SimConfig(fused_round=False, **cfg_kw), key)
        _assert_identical(fused, unfused, f"random seed={seed}")
else:
    def test_fused_random_config_needs_hypothesis():
        pytest.importorskip("hypothesis")
