"""Elastic re-meshing + checkpoint-based elastic restore.

Multi-device cases run in subprocesses with 8 forced host devices
(conftest.run_sub — jax locks the device count at first init).
"""
import numpy as np
import pytest

from conftest import run_sub


def test_build_and_shrink_mesh_shapes():
    out = run_sub("""
        import jax, numpy as np
        from repro.fault import build_mesh, shrink_mesh, surviving_replicas
        mesh = build_mesh(jax.devices(), model_axis=2)
        assert dict(mesh.shape) == {'data': 4, 'model': 2}, mesh.shape
        small = shrink_mesh(mesh, 1)
        assert dict(small.shape) == {'data': 3, 'model': 2}
        alive = surviving_replicas(4, 3)
        assert alive.tolist() == [True, True, True, False]
        mesh3 = build_mesh(jax.devices(), model_axis=2, pod_axis=2)
        assert dict(mesh3.shape) == {'pod': 2, 'data': 2, 'model': 2}
        print('OK')
    """)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_restart_from_checkpoint():
    """Train on 4x2 mesh, checkpoint, 'lose' a data row, restore onto
    3x2, keep training: the full node-failure recovery path."""
    out = run_sub("""
        import dataclasses, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import Checkpointer
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.fault import build_mesh, shrink_mesh
        from repro.models import build_model
        from repro.sharding import tree_shardings
        from repro.training import adamw, make_train_step, synthetic_batch
        from repro.training.optimizer import AdamWState

        cfg = dataclasses.replace(get_config('qwen3-4b', reduced=True),
                                  dtype='float32')
        model = build_model(cfg)
        # batch divisible by both 4-row and 3-row data axes
        shape = ShapeConfig('t', 'train', 32, 12)
        opt = adamw(1e-3)
        step_fn = make_train_step(model, opt)
        ckdir = tempfile.mkdtemp()
        ck = Checkpointer(ckdir)

        from repro.sharding import set_rules
        set_rules({'embed_fsdp': ()})   # reduced model: no FSDP; 3-row
                                        # meshes must not shard d_model
        mesh = build_mesh(jax.devices(), model_axis=2)
        p_ax = model.param_axes()
        o_ax = AdamWState(step=(), m=p_ax, v=p_ax)
        with mesh:
            params = jax.jit(lambda k: model.init(k),
                             out_shardings=tree_shardings(p_ax, mesh))(
                jax.random.PRNGKey(0))
            state = jax.jit(opt.init, out_shardings=tree_shardings(
                o_ax, mesh))(params)
            fn = jax.jit(step_fn)
            for s in range(3):
                params, state, m = fn(params, state,
                                      synthetic_batch(cfg, shape, s, mesh))
            ck.save(3, (params, state))
            loss_before = float(m['loss'])

        # --- failure: one data row lost; restore onto the smaller mesh ---
        small = shrink_mesh(mesh, 1)
        with small:
            shardings = (tree_shardings(p_ax, small),
                         tree_shardings(o_ax, small))
            (params2, state2), start = ck.restore((params, state),
                                                  shardings=shardings)
            fn2 = jax.jit(step_fn)
            for s in range(start, start + 2):
                params2, state2, m2 = fn2(
                    params2, state2, synthetic_batch(cfg, shape, s, small))
            assert np.isfinite(float(m2['loss']))
        print('OK elastic', loss_before, float(m2['loss']))
    """)
    assert "OK elastic" in out
