"""XLA:CPU miscompile canaries: the two ORIGINAL shard_map patterns the
sharded engine ships workarounds for.

PR 5's player/grid sharding hit two wrong-answer (not crash) XLA:CPU
bugs on the pinned jax 0.4.37 with >= 4 host devices:

1. An in-loop ``groups[t % n_phases]`` gather of the sort-backed
   stagger table under ``shard_map``: XLA fuses the gather into the
   scan loop and some shards read another phase's row — sharded runs
   maintain the wrong players. Workaround: ``build_sim_fn`` gathers
   the (T, W) row table ONCE outside the loop and scans it in.
2. A traced lane-pad ``concatenate`` feeding the 2-axis (data,
   players) ``shard_map``: sharding propagation mis-distributes the
   concat's operands and lanes simulate with other lanes' data.
   Workaround: ``run_sim_grid`` pads eagerly on the host and
   ``build_sim_grid_fn`` refuses the traced pad.

These tests reconstruct the original patterns from the live engine
pieces (``build_sim_parts`` / ``build_sim_fn`` + the real sharding
specs) and compare against the unsharded/eager-padded reference. They
``xfail(strict=True)`` on 0.4.37 — the failure is the expected state,
and it is re-verified on every run so silent environment drift can't
hide it. The day a jax upgrade fixes either bug, the canary XPASSes
and fails the suite loudly: that is the signal that the corresponding
workaround (and this canary) can be retired. Each subprocess exits 0
either way and reports parity via stdout, so a genuine crash still
fails the test (and the xfail) with the captured traceback.
"""
import jax
import pytest

from conftest import run_sub

MISCOMPILES = jax.__version__ == "0.4.37"


@pytest.mark.slow
@pytest.mark.xfail(
    condition=MISCOMPILES, strict=True,
    reason="XLA:CPU on jax 0.4.37 mis-fuses the in-loop stagger-table "
           "gather under shard_map at >= 4 devices (see "
           "simulator.step_fn; workaround: pre-gathered rows via xs)")
def test_canary_inloop_stagger_gather():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from repro.continuum import (SimConfig, compile_scenario,
                                     get_library, make_topology,
                                     run_sim_stream)
        from repro.continuum import scenarios as qs
        from repro.continuum.metrics import StepSeries, StreamOutputs
        from repro.continuum.simulator import (PlayerSharding,
                                               build_sim_parts,
                                               _stream_specs)
        from repro.launch.mesh import make_continuum_mesh
        from repro.sharding import logical_to_spec

        K, M, WARM = 16, 4, 10
        cfg = SimConfig(horizon=4.0)
        T = cfg.num_steps
        rtt = make_topology(jax.random.PRNGKey(0), K, M).lb_instance_rtt()
        key = jax.random.PRNGKey(7)
        drv = compile_scenario(get_library(cfg.horizon, K, M)["surge"],
                               cfg, jax.random.PRNGKey(3))
        ref = run_sim_stream("qedgeproxy", rtt, cfg, key, drivers=drv,
                             warmup_steps=WARM)
        n_ph = max(cfg.maint_every, 1)
        ok = True
        for D in (8, 4):
            mesh = make_continuum_mesh(players=D,
                                       devices=jax.devices()[:D])
            init_fn, step_fn = build_sim_parts(
                "qedgeproxy", cfg, K, M, trace=False, warmup_steps=WARM,
                pshard=PlayerSharding("players", D))

            def run(rtt_, drivers, key_, pids):
                carry0, keys = init_fn(rtt_, drivers.active[0], key_,
                                       pids)
                xs = (jnp.arange(T),
                      *(getattr(drivers, f) for f in qs.STEP_FIELDS),
                      keys)

                def body(c, x):
                    # the ORIGINAL pattern: gather the due maintenance
                    # row from the carry-resident table INSIDE the loop
                    grow = c[4][x[0] % n_ph]
                    return step_fn(rtt_, drivers.marks, c, (*x, grow))

                carry, ys = jax.lax.scan(body, carry0, xs)
                acc = carry[3]

                def allsum(v):
                    return jax.lax.psum(v, "players")

                acc = acc._replace(arrivals_m=allsum(acc.arrivals_m),
                                   proc_hist=allsum(acc.proc_hist),
                                   ev_succ=allsum(acc.ev_succ),
                                   ev_n=allsum(acc.ev_n))
                return StreamOutputs(
                    acc=acc, series=StepSeries(*(allsum(y) for y in ys)),
                    ctrl=None)

            in_specs, out_specs = _stream_specs(mesh)
            inner = shard_map(
                run, mesh=mesh,
                in_specs=(*in_specs,
                          logical_to_spec(("players",), mesh)),
                out_specs=out_specs, check_rep=False)
            got = jax.jit(lambda r, d, k: inner(
                r, d, k, jnp.arange(K, dtype=jnp.int32)))(rtt, drv, key)
            for f in ("succ_kc", "n_kc", "choice_counts", "arrivals_m"):
                a = np.asarray(getattr(ref.acc, f))
                b = np.asarray(getattr(got.acc, f))
                if not np.array_equal(a, b):
                    ok = False
                    print(f"D={D} {f}: max|delta|="
                          f"{float(np.abs(a - b).max())}")
        print("CANARY OK" if ok else "CANARY MISCOMPILED")
    """)
    assert "CANARY OK" in out


@pytest.mark.slow
@pytest.mark.xfail(
    condition=MISCOMPILES, strict=True,
    reason="XLA:CPU on jax 0.4.37 mis-distributes a traced lane-pad "
           "concat feeding the 2-axis (data, players) shard_map (see "
           "build_sim_grid_fn; workaround: run_sim_grid pads eagerly)")
def test_canary_traced_lane_pad_concat():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from repro.continuum import (SimConfig, compile_scenario,
                                     get_library, make_topology,
                                     run_sim_grid, stack_drivers)
        from repro.continuum.simulator import (PlayerSharding,
                                               build_sim_fn,
                                               _stream_specs)
        from repro.launch.mesh import make_continuum_mesh
        from repro.sharding import logical_to_spec

        K, M, S, WARM = 16, 4, 3, 10
        cfg = SimConfig(horizon=3.0)
        rtts = jnp.stack([make_topology(jax.random.PRNGKey(s), K, M)
                          .lb_instance_rtt() for s in range(S)])
        keys = jnp.stack([jax.random.PRNGKey(100 + s)
                          for s in range(S)])
        lib = list(get_library(cfg.horizon, K, M).values())
        drivers = stack_drivers(
            [compile_scenario(lib[i % len(lib)], cfg,
                              jax.random.PRNGKey(i)) for i in range(S)])
        mesh = make_continuum_mesh(players=2, devices=jax.devices()[:4])
        Dd = 2
        run = build_sim_fn("qedgeproxy", cfg, K, M, trace=False,
                           warmup_steps=WARM,
                           pshard=PlayerSharding("players", 2))
        vrun = jax.vmap(lambda r, d, k, p: run(r, d, k, pids=p),
                        in_axes=(0, 0, 0, None))
        in_specs, out_specs = _stream_specs(mesh, lead=("grid",))
        inner = shard_map(
            vrun, mesh=mesh,
            in_specs=(*in_specs, logical_to_spec(("players",), mesh)),
            out_specs=out_specs, check_rep=False)

        def pad(x):
            return jnp.concatenate(
                [x, jnp.repeat(x[-1:], (-S) % Dd, 0)])

        def grid_traced_pad(rtts_, drv_, keys_):
            # the ORIGINAL pattern: pad S=3 lanes to the 2-way data
            # axis INSIDE the traced program
            out = inner(pad(rtts_), jax.tree.map(pad, drv_),
                        pad(keys_), jnp.arange(K, dtype=jnp.int32))
            return jax.tree.map(lambda x: x[:S], out)

        got = jax.jit(grid_traced_pad)(rtts, drivers, keys)
        ref = run_sim_grid("qedgeproxy", rtts, cfg, keys,
                           drivers=drivers, warmup_steps=WARM,
                           mesh=mesh)             # eager host-side pad
        ok = True
        for f in ("succ_kc", "n_kc", "choice_counts", "arrivals_m"):
            a = np.asarray(getattr(ref.acc, f))
            b = np.asarray(getattr(got.acc, f))
            if not np.array_equal(a, b):
                ok = False
                print(f"{f}: max|delta|={float(np.abs(a - b).max())}")
        print("CANARY OK" if ok else "CANARY MISCOMPILED")
    """)
    assert "CANARY OK" in out
