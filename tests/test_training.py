"""Training substrate: optimizer math, accumulation equivalence,
gradient compression, end-to-end convergence on learnable data."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.training import (adamw, clip_by_global_norm, cosine_schedule,
                            global_norm, int8_compress, make_train_step,
                            synthetic_batch)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1e-3)
    assert float(lr(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    assert float(global_norm(tree)) == pytest.approx(10.0)
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_adamw_reduces_quadratic():
    opt = adamw(1e-1, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_int8_compress_small_relative_error():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(0, 0.01, (256, 64)), jnp.float32)}
    gq = int8_compress(g)
    rel = float(jnp.abs(gq["w"] - g["w"]).max() /
                jnp.abs(g["w"]).max())
    assert rel < 1.0 / 127 + 1e-3


def _loss_after(steps, accum, compress=False, seed=0):
    cfg = dataclasses.replace(get_config("qwen3-4b", reduced=True),
                              dtype="float32")
    model = build_model(cfg)
    shape = ShapeConfig("t", "train", 64, 8)
    opt = adamw(cosine_schedule(3e-3, 5, steps), clip_norm=1.0)
    step_fn = jax.jit(make_train_step(model, opt, accum_steps=accum,
                                      compress_grads=compress))
    params = model.init(jax.random.PRNGKey(seed))
    state = opt.init(params)
    losses = []
    for s in range(steps):
        batch = synthetic_batch(cfg, shape, s)
        params, state, m = step_fn(params, state, batch)
        losses.append(float(m["loss"]))
    return losses


def test_training_converges_on_learnable_stream():
    losses = _loss_after(60, accum=1)
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_accumulation_matches_single_batch():
    l1 = _loss_after(10, accum=1)
    l2 = _loss_after(10, accum=2)
    # same data, same model: losses track closely (not exactly: grad of
    # mean-of-losses == mean-of-grads here, so they should be very close)
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)


def test_compressed_grads_still_converge():
    losses = _loss_after(60, accum=1, compress=True)
    assert losses[-1] < losses[0] - 0.5
