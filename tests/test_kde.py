"""KDE estimator unit + property tests (paper §V-A).

The property tests need ``hypothesis`` (see requirements-dev.txt); the
deterministic unit tests below run without it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kde

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:        # pragma: no cover - exercised in slim containers
    HAVE_HYPOTHESIS = False


def test_normal_cdf_matches_numpy():
    x = jnp.linspace(-5, 5, 101)
    from math import erf, sqrt
    want = np.array([0.5 * (1 + erf(v / sqrt(2))) for v in np.asarray(x)])
    np.testing.assert_allclose(kde.normal_cdf(x), want, atol=1e-6)


def test_kde_success_prob_basic():
    # all samples well below tau => prob ~ 1; well above => ~ 0
    lat = jnp.full((1, 16), 0.010)
    mask = jnp.ones((1, 16), bool)
    lo = kde.kde_success_prob(lat, mask, tau=0.080)
    hi = kde.kde_success_prob(lat * 20, mask, tau=0.080)
    assert float(lo[0]) > 0.99
    assert float(hi[0]) < 0.01


def test_kde_mask_respected():
    lat = jnp.asarray([[0.01] * 8 + [10.0] * 8])
    mask = jnp.asarray([[True] * 8 + [False] * 8])
    p = kde.kde_success_prob(lat, mask, tau=0.08)
    assert float(p[0]) > 0.99


def test_kde_empty_window_returns_zero():
    lat = jnp.zeros((3, 8))
    mask = jnp.zeros((3, 8), bool)
    p = kde.kde_success_prob(lat, mask, tau=0.08)
    np.testing.assert_array_equal(p, 0.0)


def test_empirical_matches_fraction():
    lat = jnp.asarray([[0.01, 0.02, 0.9, 0.95]])
    mask = jnp.ones((1, 4), bool)
    p = kde.empirical_success_prob(lat, mask, 0.08)
    assert float(p[0]) == pytest.approx(0.5)


def test_silverman_positive_and_scales():
    rng = np.random.default_rng(0)
    lat = jnp.asarray(rng.normal(0.05, 0.01, (4, 64)), jnp.float32)
    mask = jnp.ones((4, 64), bool)
    h = kde.silverman_bandwidth(lat, mask)
    assert (np.asarray(h) > 0).all()
    h2 = kde.silverman_bandwidth(lat * 10, mask)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h) * 10, rtol=1e-3)


def test_masked_quantile():
    x = jnp.asarray([[1.0, 5.0, 3.0, 2.0, 4.0, 99.0]])
    mask = jnp.asarray([[True, True, True, True, True, False]])
    assert float(kde.masked_quantile(x, mask, 0.0)[0]) == 1.0
    assert float(kde.masked_quantile(x, mask, 1.0)[0]) == 5.0
    assert float(kde.masked_quantile(x, mask, 0.5)[0]) == 3.0


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=30)
    @given(
        st.integers(2, 40),
        st.floats(0.01, 0.2),
        st.integers(0, 2**31 - 1),
    )
    def test_kde_prob_in_unit_interval_and_monotone_in_tau(n, tau, seed):
        rng = np.random.default_rng(seed)
        lat = jnp.asarray(rng.exponential(0.05, (1, n)), jnp.float32)
        mask = jnp.asarray(rng.random((1, n)) < 0.8)
        p1 = float(kde.kde_success_prob(lat, mask, tau)[0])
        p2 = float(kde.kde_success_prob(lat, mask, tau * 2)[0])
        assert 0.0 <= p1 <= 1.0
        assert p2 >= p1 - 1e-6      # CDF estimate is monotone in tau
else:
    def test_kde_prob_property_needs_hypothesis():
        pytest.importorskip("hypothesis")
