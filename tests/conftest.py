import os
import subprocess
import sys
import textwrap

# Tests run on the single real CPU device; ONLY the dry-run uses 512
# placeholder devices (set inside repro/launch/dryrun.py, never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str):
    """Run `code` in a subprocess with 8 forced host CPU devices.

    jax locks the device count at first init, so every real
    multi-device test runs out of process; the env is deliberately
    minimal (no inherited XLA_FLAGS) so results don't depend on the
    parent's configuration. Shared by test_sharding / test_elastic /
    test_sharded_grid.
    """
    src = textwrap.dedent(code)
    out = subprocess.run(
        [sys.executable, "-c", src], capture_output=True, text=True,
        env={"PYTHONPATH": "src",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu",
             "HOME": os.environ.get("HOME", "/root")},
        cwd=REPO_ROOT, timeout=560)
    assert out.returncode == 0, out.stdout + out.stderr
    return out.stdout
