import os
import subprocess
import sys
import textwrap

# Tests run on the single real CPU device; ONLY the dry-run uses 512
# placeholder devices (set inside repro/launch/dryrun.py, never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(params=["ref", "interpret"])
def kernel_mode(request):
    """Run the test once per kernel backend: the pure-jnp oracle and
    the Pallas kernel body in interpret mode, scoped via ops.mode() so
    no test can leak a forced backend. When REPRO_KERNEL_MODE pins a
    single mode (CI's interpret lane), the other param is skipped
    rather than silently overridden."""
    from repro.kernels import ops as kernel_ops

    pinned = os.environ.get("REPRO_KERNEL_MODE")
    if pinned in ("ref", "interpret") and pinned != request.param:
        pytest.skip(f"REPRO_KERNEL_MODE={pinned} pins the backend")
    with kernel_ops.mode(request.param):
        yield request.param


def run_sub(code: str):
    """Run `code` in a subprocess with 8 forced host CPU devices.

    jax locks the device count at first init, so every real
    multi-device test runs out of process; the env is deliberately
    minimal (no inherited XLA_FLAGS) so results don't depend on the
    parent's configuration. Shared by test_sharding / test_elastic /
    test_sharded_grid.
    """
    src = textwrap.dedent(code)
    out = subprocess.run(
        [sys.executable, "-c", src], capture_output=True, text=True,
        env={"PYTHONPATH": "src",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu",
             "HOME": os.environ.get("HOME", "/root")},
        cwd=REPO_ROOT, timeout=560)
    assert out.returncode == 0, out.stdout + out.stderr
    return out.stdout
