"""Repository hygiene locks.

Compiled-python artifacts were committed once in this repo's history;
the tracked set was since cleaned and ``.gitignore`` covers the
patterns, but nothing STOPPED a re-introduction — ``git add .`` happily
re-stages an already-tracked ``.pyc``. These tests make the invariant
durable: the index must never contain bytecode or packaging artifacts,
and ``.gitignore`` must keep covering the patterns that let them creep
in. Skipped gracefully outside a git checkout (e.g. an sdist).
"""
import fnmatch
import os
import subprocess

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# anything matching these must never be tracked
FORBIDDEN = ("*.pyc", "*.pyo", "*.pyd", "*/__pycache__/*", "__pycache__/*",
             "*.egg-info/*", "*/.pytest_cache/*", ".coverage", "*.prof")
# and .gitignore must keep covering the generators of the mess
REQUIRED_IGNORES = ("__pycache__/", "*.py[cod]", ".pytest_cache/")


def _tracked_files():
    try:
        out = subprocess.run(["git", "ls-files", "-z"], cwd=REPO_ROOT,
                             capture_output=True, text=True, timeout=60)
    except (OSError, subprocess.TimeoutExpired):
        pytest.skip("git unavailable")
    if out.returncode != 0:
        pytest.skip("not a git checkout")
    return [f for f in out.stdout.split("\0") if f]


def test_no_tracked_bytecode_or_build_artifacts():
    files = _tracked_files()
    assert files, "git ls-files returned nothing — broken checkout?"
    bad = sorted(f for f in files
                 if any(fnmatch.fnmatch(f, pat) for pat in FORBIDDEN))
    assert not bad, (
        f"{len(bad)} forbidden artifact(s) tracked in git: {bad[:10]} — "
        f"run `git rm --cached` on them; .gitignore already excludes "
        f"the patterns")


def test_gitignore_covers_bytecode():
    with open(os.path.join(REPO_ROOT, ".gitignore")) as f:
        lines = {ln.strip() for ln in f if ln.strip()
                 and not ln.startswith("#")}
    missing = [pat for pat in REQUIRED_IGNORES if pat not in lines]
    assert not missing, f".gitignore lost required patterns: {missing}"
