"""Scenario-compiler invariants + legacy bit-identity.

The compiler's post-conditions are load-bearing (the engine trusts
driver arrays blindly inside a scan), so every library entry is checked
for shape/dtype/bounds; determinism under a fixed key is what makes
scenario grids reproducible; and the two legacy figure events
(client surge, instance removal) must compile to EXACTLY the arrays
the pre-DSL harness hand-rolled — and produce bit-identical simulation
results through the drivers path.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.figures import SURGE_LBS, legacy_event_scenarios
from repro.continuum import (InstanceKill, LoadSurge, Scenario, SimConfig,
                             compile_scenario, get_library, make_topology,
                             run_sim_stream)
from repro.continuum.scenarios import MAX_MARKS, MIN_SERVICE_TIME

CFG = SimConfig(horizon=15.0)
K, M = 8, 4
T = CFG.num_steps


@pytest.fixture(scope="module")
def library():
    return get_library(CFG.horizon, K, M)


def test_library_has_ten_plus_entries(library):
    assert len(library) >= 10
    assert "baseline" in library


def test_compiled_invariants_every_library_entry(library):
    key = jax.random.PRNGKey(3)
    for name, scn in library.items():
        drv = compile_scenario(scn, CFG, key)
        assert drv.n_clients.shape == (T, K), name
        assert drv.n_clients.dtype == jnp.int32, name
        assert drv.active.shape == (T, M) and drv.active.dtype == bool, name
        assert drv.rtt_scale.shape == (T, M), name
        assert drv.rtt_cut_k.shape == (T, K), name
        assert drv.rtt_cut_m.shape == (T, M), name
        assert drv.s_m.shape == (T, M), name
        assert drv.marks.shape == (MAX_MARKS,), name
        nc = np.asarray(drv.n_clients)
        assert nc.min() >= 0 and nc.max() <= CFG.max_clients, name
        # the fleet is never fully dark
        assert np.asarray(drv.active).any(axis=1).all(), name
        assert float(drv.s_m.min()) >= MIN_SERVICE_TIME, name
        assert float(drv.rtt_scale.min()) > 0, name
        assert float(drv.rtt_cut_k.min()) >= 0, name
        marks = np.asarray(drv.marks)
        real = marks[marks >= 0]
        assert (real < T).all(), name


def test_compile_is_deterministic_under_key(library):
    for name in ("churn", "everything"):       # the stochastic entries
        a = compile_scenario(library[name], CFG, jax.random.PRNGKey(7))
        b = compile_scenario(library[name], CFG, jax.random.PRNGKey(7))
        for f, xa, xb in zip(a._fields, a, b):
            np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb),
                                          err_msg=f"{name}.{f}")


def test_churn_varies_with_key(library):
    a = compile_scenario(library["churn"], CFG, jax.random.PRNGKey(0))
    b = compile_scenario(library["churn"], CFG, jax.random.PRNGKey(1))
    assert not np.array_equal(np.asarray(a.n_clients),
                              np.asarray(b.n_clients))


def test_all_instances_dark_raises():
    scn = Scenario("dead", (InstanceKill(start=5.0, instances=tuple(range(M))),),
                   n_nodes=K, n_instances=M)
    with pytest.raises(ValueError, match="no instance alive"):
        compile_scenario(scn, CFG, jax.random.PRNGKey(0))


def test_n_clients_clipped_to_max():
    scn = Scenario("over", (LoadSurge(start=0.0, extra=100, fraction=1.0),),
                   n_nodes=K, n_instances=M)
    drv = compile_scenario(scn, CFG, jax.random.PRNGKey(0))
    assert int(drv.n_clients.max()) == CFG.max_clients


def test_surge_ramp_is_monotone():
    scn = Scenario("ramp", (LoadSurge(start=3.0, stop=math.inf, extra=4,
                                      fraction=1.0, ramp=4.0),),
                   n_nodes=K, n_instances=M, base_clients=2)
    drv = compile_scenario(scn, CFG, jax.random.PRNGKey(0))
    col = np.asarray(drv.n_clients)[:, 0]
    assert col[0] == 2
    assert (np.diff(col) >= 0).all()
    assert col[-1] == 6


# ---------------------------------------------------------------------------
# Legacy bit-identity: the DSL replaces the hand-rolled numpy event
# blocks of benchmarks/figures.py; drivers AND simulation results must
# match the old arrays exactly.
# ---------------------------------------------------------------------------

def _legacy_arrays(cfg, K_, M_):
    """Verbatim the pre-DSL blocks from benchmarks/figures.py."""
    T_ = cfg.num_steps
    surge_nc = np.full((T_, K_), 2, np.int32)
    surge_nc[T_ // 2:, [lb for lb in SURGE_LBS if lb < K_]] += 2
    removal_act = np.ones((T_, M_), bool)
    removal_act[T_ // 2:, M_ - 1] = False
    return surge_nc, removal_act


def test_legacy_events_compile_bit_identical():
    surge_nc, removal_act = _legacy_arrays(CFG, K, M)
    surge, removal = legacy_event_scenarios(CFG, K, M)
    key = jax.random.PRNGKey(0)
    drv_s = compile_scenario(surge, CFG, key)
    drv_r = compile_scenario(removal, CFG, key)
    np.testing.assert_array_equal(np.asarray(drv_s.n_clients), surge_nc)
    np.testing.assert_array_equal(np.asarray(drv_s.active),
                                  np.ones((T, M), bool))
    np.testing.assert_array_equal(np.asarray(drv_r.active), removal_act)
    np.testing.assert_array_equal(np.asarray(drv_r.n_clients),
                                  np.full((T, K), 4, np.int32))
    # neutral modulation everywhere: the engine computes the exact
    # pre-scenario floats on these lanes
    for drv in (drv_s, drv_r):
        assert (np.asarray(drv.rtt_scale) == 1.0).all()
        assert (np.asarray(drv.rtt_cut_k) == 0.0).all()
        assert (np.asarray(drv.s_m) == np.float32(CFG.service_time)).all()
    # both events mark mid-horizon
    assert int(drv_s.marks[0]) == T // 2
    assert int(drv_r.marks[0]) == T // 2


def test_legacy_events_run_bit_identical():
    """DSL drivers vs the legacy n_clients/active kwargs: same engine,
    same floats, every accumulator field and series."""
    surge_nc, removal_act = _legacy_arrays(CFG, K, M)
    surge, removal = legacy_event_scenarios(CFG, K, M)
    rtt = make_topology(jax.random.PRNGKey(2), K, M).lb_instance_rtt()
    cases = [
        (surge, dict(n_clients=jnp.asarray(surge_nc))),
        (removal, dict(active=jnp.asarray(removal_act))),
    ]
    for scn, legacy_kw in cases:
        drv = compile_scenario(scn, CFG, jax.random.PRNGKey(0))
        new = run_sim_stream("qedgeproxy", rtt, CFG, jax.random.PRNGKey(5),
                             drivers=drv, warmup_steps=50)
        # the kwargs path wraps into neutral drivers; the array the
        # legacy block did not vary takes its old default fill
        old = run_sim_stream("qedgeproxy", rtt, CFG, jax.random.PRNGKey(5),
                             warmup_steps=50, **legacy_kw)
        for f in new.acc._fields:
            if f in ("ev_succ", "ev_n"):
                continue        # marks exist only on the DSL side
            np.testing.assert_array_equal(
                np.asarray(getattr(new.acc, f)),
                np.asarray(getattr(old.acc, f)),
                err_msg=f"{scn.name} acc.{f}")
        for f in new.series._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(new.series, f)),
                np.asarray(getattr(old.series, f)),
                err_msg=f"{scn.name} series.{f}")


def test_event_vmap_lane_matches_single_run():
    """The batched event program must reproduce the single-lane run
    bit-for-bit, lane by lane. (The pre-DSL harness failed this: its
    vmapped removal lane drifted from the canonical single-lane
    trajectory through an XLA fusion artifact — which is why the
    committed fig11 artifact moved when the DSL landed.)"""
    import jax.numpy as jnp
    from repro.continuum import build_sim_fn, compile_scenario, stack_drivers
    cfg = SimConfig(horizon=12.0)
    rtt = make_topology(jax.random.PRNGKey(1), K, M).lb_instance_rtt()
    scns = legacy_event_scenarios(cfg, K, M)
    drivers = stack_drivers(
        [compile_scenario(s, cfg, jax.random.PRNGKey(0)) for s in scns])
    key = jax.random.PRNGKey(11)
    run = build_sim_fn("qedgeproxy", cfg, K, M, trace=False,
                       warmup_steps=40)
    vout = jax.jit(jax.vmap(run, in_axes=(None, 0, None)))(
        rtt, drivers, key)
    for i, scn in enumerate(scns):
        drv = compile_scenario(scn, cfg, jax.random.PRNGKey(0))
        single = run_sim_stream("qedgeproxy", rtt, cfg, key,
                                drivers=drv, warmup_steps=40)
        for f in single.acc._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(vout.acc, f)[i]),
                np.asarray(getattr(single.acc, f)),
                err_msg=f"{scn.name} acc.{f}")
        np.testing.assert_array_equal(
            np.asarray(vout.series.succ[i]),
            np.asarray(single.series.succ), err_msg=scn.name)


def test_event_recovery_never_recovered_reports_none():
    """A collapse that never climbs back inside the observed windows
    must not fake an instant recovery (argmax-on-all-False regression)."""
    from repro.continuum import event_recovery
    ev_n = np.zeros((2, 5)); ev_s = np.zeros((2, 5))
    ev_n[0] = 100.0
    ev_s[0] = [95.0, 90.0, 80.0, 50.0, 30.0]     # monotone collapse
    out = event_recovery((ev_s, ev_n), bucket_s=2.0)
    assert len(out) == 1
    assert not out[0]["recovered"] and out[0]["recovery_s"] is None
    assert out[0]["dip"] == pytest.approx(0.3)
    # and a genuine recovery still reads normally
    ev_s[0] = [95.0, 40.0, 85.0, 90.0, 91.0]
    out = event_recovery((ev_s, ev_n), bucket_s=2.0)
    # dip at post bucket 0; first bucket back over threshold is post
    # bucket 1, whose left edge is 1 * bucket_s
    assert out[0]["recovered"] and out[0]["recovery_s"] == pytest.approx(2.0)


def test_overlapping_partitions_warn():
    """The factored cut penalizes cross routes of temporally
    overlapping partitions with different sides — loudly, not
    silently."""
    from repro.continuum import Partition
    scn = Scenario("xpart",
                   (Partition(start=2.0, stop=8.0, lbs=(0,), instances=(0,)),
                    Partition(start=5.0, stop=10.0, lbs=(1,), instances=(1,))),
                   n_nodes=K, n_instances=M)
    with pytest.warns(UserWarning, match="cross routes"):
        compile_scenario(scn, CFG, jax.random.PRNGKey(0))
    # disjoint-in-time partitions stay silent
    import warnings as _w
    scn2 = Scenario("seqpart",
                    (Partition(start=2.0, stop=5.0, lbs=(0,), instances=(0,)),
                     Partition(start=6.0, stop=9.0, lbs=(1,), instances=(1,))),
                    n_nodes=K, n_instances=M)
    with _w.catch_warnings():
        _w.simplefilter("error")
        compile_scenario(scn2, CFG, jax.random.PRNGKey(0))


def test_mark_overflow_warns():
    from repro.continuum import InstanceKill
    events = tuple(InstanceKill(start=0.1 * i, stop=0.1 * i + 0.1,
                                instances=(0,)) for i in range(40))
    scn = Scenario("busy", events, n_nodes=K, n_instances=2)
    with pytest.warns(UserWarning, match="event marks exceed"):
        drv = compile_scenario(scn, CFG, jax.random.PRNGKey(0))
    assert int((np.asarray(drv.marks) >= 0).sum()) == MAX_MARKS


def test_surge_base_clients_note():
    """Guard the one asymmetry: the legacy surge lane ran base 2
    clients, the removal lane base 4 (matching the old hand-rolled
    arrays), encoded in the scenario specs."""
    surge, removal = legacy_event_scenarios(CFG, K, M)
    assert surge.base_clients == 2 and removal.base_clients == 4
