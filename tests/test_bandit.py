"""QEdgeProxy bandit invariants + behaviour (paper Algs 1-4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BanditParams, init_state, instance_added,
                        instance_removed, maintenance, record, select,
                        sync_active)

P = BanditParams()


def _drive(st, params, arm_latency, steps, rtt, maint_every=10, t0=0.0):
    """Feed deterministic latencies per arm for `steps` requests/LB."""
    rec = jax.jit(record, static_argnums=1)
    mnt = jax.jit(maintenance, static_argnums=1)
    sel = jax.jit(select)
    K = st.lat_buf.shape[0]
    for i in range(steps):
        t = jnp.float32(t0 + i * 0.1)
        choice, st, _ = sel(st)
        lat = jnp.asarray(arm_latency)[choice] + rtt[jnp.arange(K), choice]
        st = rec(st, params, choice, lat, t, jnp.ones((K,), bool))
        if i % maint_every == maint_every - 1:
            st = mnt(st, params, rtt, t)
    return st


@pytest.fixture
def rtt():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.uniform(0.002, 0.02, (3, 4)), jnp.float32)


def test_init_invariants(rtt):
    st = init_state(3, 4, P, ring=16)
    np.testing.assert_allclose(st.weights.sum(-1), 1.0, atol=1e-6)
    assert float(st.eps[0]) == pytest.approx(1 - P.rho)
    assert bool(st.active.all())


def test_weights_form_distribution_over_pool(rtt):
    st = init_state(3, 4, P, ring=32, key=jax.random.PRNGKey(0))
    st = _drive(st, P, [0.02, 0.03, 0.2, 0.02], 100, rtt)
    w = np.asarray(st.weights)
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)
    assert (w >= -1e-7).all()
    # weights outside the pool must be zero
    outside = ~np.asarray(st.in_pool)
    assert np.abs(w[outside]).max() <= 1e-6


def test_converges_to_qos_feasible_arms(rtt):
    # arm 2 always violates tau; the others never do
    st = init_state(3, 4, P, ring=32, key=jax.random.PRNGKey(1))
    st = _drive(st, P, [0.01, 0.01, 0.5, 0.01], 200, rtt)
    w = np.asarray(st.weights)
    assert w[:, 2].max() < 0.05
    mu = np.asarray(st.mu_hat)
    assert (mu[:, [0, 1, 3]] > 0.9).all()
    assert (mu[:, 2] < 0.1).all()


def test_eps_decays_when_stable(rtt):
    st = init_state(3, 4, P, ring=32, key=jax.random.PRNGKey(2))
    st = _drive(st, P, [0.01] * 4, 300, rtt)
    assert (np.asarray(st.eps) < 1 - P.rho).all()


def test_cooldown_trips_after_consecutive_errors(rtt):
    params = BanditParams(err_thresh=3, cooldown=5.0)
    st = init_state(1, 2, params, ring=16)
    rtt1 = jnp.zeros((1, 2), jnp.float32)
    rec = jax.jit(record, static_argnums=1)
    # force arm 0 selection by weights
    st = st._replace(weights=jnp.asarray([[1.0, 0.0]]))
    for i in range(3):
        st = rec(st, params, jnp.asarray([0]), jnp.asarray([1.0]),
                 jnp.float32(i * 0.1), jnp.ones((1,), bool))
    assert float(st.cooldown_until[0, 0]) > 0.2       # tripped
    assert not bool(st.in_pool[0, 0])
    # weights renormalized to the surviving arm
    np.testing.assert_allclose(np.asarray(st.weights)[0], [0.0, 1.0],
                               atol=1e-6)


def test_instance_removed_renormalizes(rtt):
    st = init_state(3, 4, P, ring=16)
    st2 = instance_removed(st, jnp.int32(1))
    w = np.asarray(st2.weights)
    assert np.abs(w[:, 1]).max() == 0.0
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)
    assert not bool(st2.active[1])


def test_instance_added_starts_at_zero_weight(rtt):
    st = init_state(3, 4, P, ring=16,
                    active=jnp.asarray([True, True, True, False]))
    st = _drive(st, P, [0.01, 0.01, 0.01, 0.01], 50, rtt)
    st2 = instance_added(st, P, jnp.int32(3), rtt, jnp.float32(5.0))
    assert bool(st2.active[3])
    assert np.abs(np.asarray(st2.weights)[:, 3]).max() == 0.0
    # optimistic mu puts it at the top of the exploration pool next maint
    st3 = maintenance(st2, P, rtt, jnp.float32(5.0))
    assert (np.asarray(st3.weights)[:, 3] > 0).all()


def test_sync_active_matches_individual_events(rtt):
    st = init_state(3, 4, P, ring=16, key=jax.random.PRNGKey(3))
    st = _drive(st, P, [0.01] * 4, 60, rtt)
    target = jnp.asarray([True, False, True, True])
    a = sync_active(st, P, target)
    b = instance_removed(st, jnp.int32(1))
    np.testing.assert_allclose(np.asarray(a.weights),
                               np.asarray(b.weights), atol=1e-6)


def test_eps_resets_on_qos_degradation(rtt):
    st = init_state(3, 4, P, ring=64, reward_ring=1024,
                    key=jax.random.PRNGKey(4))
    st = _drive(st, P, [0.01] * 4, 300, rtt)          # healthy: eps decays
    eps_before = np.asarray(st.eps).copy()
    assert (eps_before < 0.09).all()
    # now everything degrades: rolling QoS drops, eps resets to 1-rho
    st = _drive(st, P, [0.5] * 4, 300, rtt, t0=30.0)
    assert (np.asarray(st.eps) >= eps_before - 1e-6).all()
    assert (np.asarray(st.eps) > 0.05).any()


def test_lb_mask_freezes_other_players(rtt):
    st = init_state(3, 4, P, ring=32, key=jax.random.PRNGKey(5))
    st = _drive(st, P, [0.01, 0.02, 0.2, 0.01], 100, rtt)
    mask = jnp.asarray([True, False, False])
    st2 = maintenance(st, P, rtt, jnp.float32(20.0), lb_mask=mask)
    np.testing.assert_allclose(np.asarray(st2.weights)[1:],
                               np.asarray(st.weights)[1:], atol=0)
