"""Partitioning rules + multi-device SPMD behaviour.

In-process tests use the single CPU device; real multi-device sharding
(8 fake host devices) runs in subprocesses (conftest.run_sub) because
jax locks the device count at first init.
"""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import run_sub
from repro.sharding import (logical_to_spec, rule_overrides, set_rules,
                            DEFAULT_RULES)
from repro.sharding.partitioning import is_axes_leaf


def test_rules_resolution_no_mesh_drops_axes():
    import jax
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    spec = logical_to_spec(("batch", "heads", None), mesh)
    assert spec == P("data", "model", None)


def test_rule_overrides_scoped():
    import jax
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    with rule_overrides(batch=()):
        assert logical_to_spec(("batch",), mesh) == P(None)
    assert logical_to_spec(("batch",), mesh) == P("data")


def test_is_axes_leaf():
    assert is_axes_leaf(("a", None))
    assert is_axes_leaf(())
    assert not is_axes_leaf({"x": ("a",)})
    assert not is_axes_leaf((("a",), ("b",)))
    from repro.training.optimizer import AdamWState
    assert not is_axes_leaf(AdamWState(step=(), m={}, v={}))


def test_pod_axis_dropped_on_single_pod_mesh():
    import jax
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    # "batch" -> ("pod","data"); pod absent => P("data")
    assert logical_to_spec(("batch",), mesh) == P("data")


@pytest.mark.slow
def test_spmd_train_step_8dev_matches_1dev():
    """Same reduced model, 2x4 mesh vs single device: loss identical."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.models import build_model
        from repro.sharding import tree_shardings
        from repro.training import adamw, make_train_step, synthetic_batch
        from repro.training.optimizer import AdamWState

        cfg = dataclasses.replace(get_config('qwen3-4b', reduced=True),
                                  dtype='float32')
        model = build_model(cfg)
        shape = ShapeConfig('t', 'train', 32, 8)
        opt = adamw(1e-3, clip_norm=1.0)
        step = make_train_step(model, opt)

        def run(mesh):
            with mesh:
                p_ax = model.param_axes()
                ps = tree_shardings(p_ax, mesh)
                params = jax.jit(lambda k: model.init(k),
                                 out_shardings=ps)(jax.random.PRNGKey(0))
                state = jax.jit(opt.init, out_shardings=tree_shardings(
                    AdamWState(step=(), m=p_ax, v=p_ax), mesh))(params)
                fn = jax.jit(step)
                losses = []
                for s in range(3):
                    batch = synthetic_batch(cfg, shape, s, mesh)
                    params, state, m = fn(params, state, batch)
                    losses.append(float(m['loss']))
            return losses

        devs = np.asarray(jax.devices())
        mesh1 = Mesh(devs[:1].reshape(1, 1), ('data', 'model'))
        mesh8 = Mesh(devs.reshape(2, 4), ('data', 'model'))
        l1, l8 = run(mesh1), run(mesh8)
        np.testing.assert_allclose(l1, l8, rtol=1e-4, atol=1e-5)
        print('OK', l1, l8)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_multipod_mesh_lowering_8dev():
    """A (pod, data, model) mesh lowers + compiles a decode step."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import Mesh
        jax.devices()   # lock 8 host devices BEFORE importing dryrun
                        # (its import sets XLA_FLAGS to 512 by design)
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.models import build_model
        from repro.launch.dryrun import _sharded_sds, _rules_for
        from repro.sharding import rule_overrides

        cfg = get_config('qwen3-4b', reduced=True)
        model = build_model(cfg)
        shape = ShapeConfig('d', 'decode', 64, 8)
        devs = np.asarray(jax.devices())
        mesh = Mesh(devs.reshape(2, 2, 2), ('pod', 'data', 'model'))
        over = _rules_for(cfg, shape, mesh)
        with rule_overrides(**over), mesh:
            params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            p_sds = _sharded_sds(params, model.param_axes(), mesh)
            cache = jax.eval_shape(lambda: model.init_cache(8, 64))
            c_sds = _sharded_sds(cache, model.cache_axes(), mesh)
            b_specs, b_axes = model.input_specs(shape)
            b_sds = _sharded_sds(b_specs, b_axes, mesh)
            lowered = jax.jit(model.decode).lower(p_sds, c_sds, b_sds)
            compiled = lowered.compile()
            ca = compiled.cost_analysis()
            assert float(ca.get('flops', 0)) > 0
            print('OK multipod compile')
    """)
    assert "OK multipod compile" in out
