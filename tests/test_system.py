"""End-to-end behaviour tests for the paper's system (§VII claims).

A compact CC scenario (fewer nodes, shorter horizon than the
benchmarks) must reproduce the paper's qualitative results: QEdgeProxy
beats both baselines on per-client QoS, remains fair, and adapts to
load surges and instance removal.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.continuum import (SimConfig, client_qos_satisfaction,
                             jain_fairness, make_topology, rolling_qos,
                             run_sim)

CFG = SimConfig(horizon=120.0)
WARM = int(40 / CFG.dt)


@pytest.fixture(scope="module")
def topo():
    return make_topology(jax.random.PRNGKey(1), 30, 10)


@pytest.fixture(scope="module")
def results(topo):
    rtt = topo.lb_instance_rtt()
    out = {}
    for name, kw in [("qedgeproxy", {}),
                     ("proxy_mity", dict(alpha=1.0)),
                     ("dec_sarsa", {})]:
        out[name] = run_sim(name, rtt, CFG, jax.random.PRNGKey(7), **kw)
    return out


def test_qedgeproxy_meets_paper_band(results):
    sat = client_qos_satisfaction(results["qedgeproxy"], CFG.rho, WARM)
    assert sat >= 95.0, sat            # paper: 95-100%


def test_strategy_ordering_matches_paper(results):
    sat = {k: client_qos_satisfaction(v, CFG.rho, WARM)
           for k, v in results.items()}
    assert sat["qedgeproxy"] > sat["dec_sarsa"] > sat["proxy_mity"]


def test_fairness_ordering(results):
    f = {k: jain_fairness(v, warmup_steps=WARM) for k, v in results.items()}
    assert f["qedgeproxy"] >= 0.85     # paper: ~0.85-0.90
    assert f["dec_sarsa"] >= 0.80
    assert f["proxy_mity"] < f["qedgeproxy"]


def test_rolling_qos_converges(results):
    r = rolling_qos(results["qedgeproxy"], int(CFG.window / CFG.dt))
    # after convergence (~60s in the paper) rolling QoS stays high
    assert r[WARM:].mean() > 0.93


def test_adapts_to_client_surge(topo):
    """Paper Fig. 10: +50% clients mid-run, QoS recovers to ~0.9."""
    rtt = topo.lb_instance_rtt()
    T = CFG.num_steps
    n_clients = np.full((T, 30), 2, np.int32)
    rng = np.random.default_rng(0)
    surge_lbs = rng.choice(30, 15, replace=False)
    n_clients[T // 2:, surge_lbs] += 2
    outs = run_sim("qedgeproxy", rtt, CFG, jax.random.PRNGKey(9),
                   n_clients=jnp.asarray(n_clients))
    roll = rolling_qos(outs, int(CFG.window / CFG.dt))
    tail = roll[-int(20 / CFG.dt):]
    assert tail.mean() > 0.88, tail.mean()


def test_adapts_to_instance_removal(topo):
    """Paper Fig. 11: one instance removed mid-run, recovers ~0.9."""
    rtt = topo.lb_instance_rtt()
    T = CFG.num_steps
    active = np.ones((T, 10), bool)
    active[T // 2:, 9] = False
    outs = run_sim("qedgeproxy", rtt, CFG, jax.random.PRNGKey(9),
                   active=jnp.asarray(active))
    roll = rolling_qos(outs, int(CFG.window / CFG.dt))
    tail = roll[-int(20 / CFG.dt):]
    assert tail.mean() > 0.85, tail.mean()
    # removed instance receives zero traffic after the event (+1 window)
    arr = np.asarray(outs.arrivals)
    assert arr[T // 2 + int(2 / CFG.dt):, 9].sum() == 0


def test_regret_vanishes_in_stable_regime(topo):
    """Thm 1 consequence: R(T)/T -> 0. In the well-provisioned regime
    the learned weights track the oracle so closely that per-step
    regret stays ~0 for the whole horizon (proxy-mity's, by contrast,
    grows linearly — benchmarks/regret_curve)."""
    rtt = topo.lb_instance_rtt()
    outs = run_sim("qedgeproxy", rtt, CFG, jax.random.PRNGKey(3))
    reg = np.asarray(outs.regret).sum(1)          # (T,) system regret
    assert reg[-WARM:].mean() < 0.01 * 30         # << 1 per LB per step
    outs_pm = run_sim("proxy_mity", rtt, CFG, jax.random.PRNGKey(3),
                      alpha=1.0)
    reg_pm = np.asarray(outs_pm.regret).sum(1)
    assert reg[-WARM:].mean() < 0.2 * reg_pm[-WARM:].mean() + 1e-6
