"""Pallas kernel sweeps, routed through the ops dispatch layer.

Per instructions: sweep shapes/dtypes per kernel, assert_allclose
against ref.py; hypothesis (requirements-dev.txt, optional) drives the
KDE kernel's input space. Every call goes through ``repro.kernels.ops``
under the ``kernel_mode`` fixture, so each case runs twice: once with
the dispatcher forced to the pure-jnp oracle (locks the ``ref`` routing
and any XLA-side impl it picks) and once with the Pallas kernel body in
interpret mode — the same code path CI's interpret lane exercises.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:        # pragma: no cover - exercised in slim containers
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref

pytestmark = pytest.mark.kernels

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (2, 4, 2, 256, 64),
    (1, 8, 2, 192, 32),     # ragged: S not a block multiple
    (2, 4, 1, 256, 64),     # MQA
    (1, 2, 2, 128, 128),    # MHA, wide head
    (1, 4, 4, 64, 256),     # gemma3-style head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Hq, Hkv, S, D, dtype, kernel_mode):
    q = jnp.asarray(RNG.normal(0, 1, (B, Hq, S, D)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (B, Hkv, S, D)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (B, Hkv, S, D)), dtype)
    got = ops.attention(q, k, v, causal=True)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [16, 96, 1024])
def test_flash_attention_sliding_window(window, kernel_mode):
    B, Hq, Hkv, S, D = 1, 4, 2, 256, 32
    q = jnp.asarray(RNG.normal(0, 1, (B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (B, Hkv, S, D)), jnp.float32)
    got = ops.attention(q, k, v, causal=True, window=window)
    want = ref.attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_noncausal(kernel_mode):
    B, Hq, Hkv, S, D = 1, 2, 2, 128, 32
    q = jnp.asarray(RNG.normal(0, 1, (B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (B, Hkv, S, D)), jnp.float32)
    got = ops.attention(q, k, v, causal=False)
    want = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.fixture
def naive_decode(monkeypatch):
    # ref-mode dispatch defaults to the lowcast (bf16-operand) XLA
    # impl, which is intentionally looser than the f32 tolerance here.
    monkeypatch.setenv("REPRO_DECODE_IMPL", "naive")


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (2, 8, 2, 300, 64),
    (1, 4, 4, 128, 32),
    (3, 4, 1, 512, 128),
    (1, 25, 5, 96, 64),     # hymba head counts
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, Hq, Hkv, S, D, dtype, kernel_mode,
                                naive_decode):
    q = jnp.asarray(RNG.normal(0, 1, (B, Hq, D)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (B, Hkv, S, D)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (B, Hkv, S, D)), dtype)
    ln = jnp.asarray(RNG.integers(1, S + 1, (B,)), jnp.int32)
    got = ops.decode_attention(q, k, v, ln)
    want = ref.decode_attention(q, k, v, ln)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attention_length_masks_tail(kernel_mode, naive_decode):
    B, Hq, Hkv, S, D = 1, 2, 1, 64, 16
    q = jnp.asarray(RNG.normal(0, 1, (B, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (B, Hkv, S, D)), jnp.float32)
    ln = jnp.asarray([10], jnp.int32)
    got = ops.decode_attention(q, k, v, ln)
    # poison the tail: result must not change
    k2 = k.at[:, :, 10:].set(99.0)
    v2 = v.at[:, :, 10:].set(-99.0)
    got2 = ops.decode_attention(q, k2, v2, ln)
    np.testing.assert_allclose(got, got2, atol=1e-6)


# ---------------------------------------------------------------------------
# SSD (Mamba-2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,c", [
    (2, 96, 2, 16, 8, 32),
    (1, 64, 4, 32, 16, 64),
    (2, 130, 2, 16, 8, 32),     # S not a chunk multiple
    (1, 256, 2, 64, 128, 128),  # mamba2-1.3b-like dims
])
def test_ssd_sweep(B, S, H, P, N, c, kernel_mode):
    x = jnp.asarray(RNG.normal(0, 1, (B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, (H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(0, 1, (B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(0, 1, (B, S, N)), jnp.float32)
    got = ops.ssd(x, dt, A, Bm, Cm, chunk=c)
    want = ref.ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_ssd_decode_step_consistent_with_scan():
    B, S, H, P, N = 1, 32, 2, 8, 4
    x = jnp.asarray(RNG.normal(0, 1, (B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.1, (B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, (H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(0, 1, (B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(0, 1, (B, S, N)), jnp.float32)
    want = ref.ssd(x, dt, A, Bm, Cm)
    h = jnp.zeros((B, H, N, P), jnp.float32)
    for t in range(S):
        h, y = ops.ssd_decode_step(h, x[:, t], dt[:, t], A, Bm[:, t],
                                   Cm[:, t])
        np.testing.assert_allclose(y, want[:, t], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# KDE kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,R", [(8, 16), (300, 64), (1024, 128)])
def test_kde_kernel_sweep(rows, R, kernel_mode):
    lat = jnp.asarray(RNG.exponential(0.03, (rows, R)), jnp.float32)
    mask = jnp.asarray(RNG.random((rows, R)) < 0.7)
    bw = jnp.asarray(RNG.uniform(1e-3, 1e-2, rows), jnp.float32)
    got = ops.kde_success_prob(lat, mask, 0.08, bw)
    want = ref.kde_success_prob(lat, mask, 0.08, bw)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("rows,R", [(17, 8), (300, 64), (1000, 512)])
def test_maintenance_stats_sweep(rows, R, kernel_mode):
    lat = jnp.asarray(RNG.exponential(0.03, (rows, R)), jnp.float32)
    mask = jnp.asarray(RNG.random((rows, R)) < 0.7)
    rtt = jnp.asarray(RNG.uniform(0.001, 0.05, rows), jnp.float32)
    got = ops.bandit_maintenance_stats(lat, mask, rtt, 0.08, 0.9)
    want = ref.bandit_maintenance_stats(lat, mask, rtt, 0.08, 0.9)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-6)


def test_bitonic_sort_matches_sort():
    """The branchless bitonic network that replaces XLA:CPU's scalar
    jnp.sort in the maintenance quantile must be bitwise-identical to
    np.sort for the values it sees (finite, non-negative, duplicates)."""
    for rows, R in ((5000, 64), (17, 8), (3, 16), (100, 512)):
        x = RNG.exponential(1.0, (rows, R)).astype(np.float32)
        x[:, :: max(R // 4, 1)] = 0.0          # duplicated exact values
        x[0, :2] = np.finfo(np.float32).max    # sentinel-sized entries
        got = np.asarray(ref._bitonic_sort_rows(jnp.asarray(x)))
        np.testing.assert_array_equal(got, np.sort(x, axis=-1))


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=25)
    @given(st.integers(1, 64), st.integers(4, 64),
           st.floats(0.01, 0.5), st.integers(0, 2**31 - 1))
    def test_kde_kernel_property(rows, R, tau, seed):
        rng = np.random.default_rng(seed)
        lat = jnp.asarray(rng.exponential(0.05, (rows, R)), jnp.float32)
        mask = jnp.asarray(rng.random((rows, R)) < 0.5)
        bw = jnp.asarray(rng.uniform(1e-4, 1e-1, rows), jnp.float32)
        with ops.mode("interpret"):
            got = ops.kde_success_prob(lat, mask, tau, bw)
        want = ref.kde_success_prob(lat, mask, tau, bw)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
        assert ((np.asarray(got) >= 0) & (np.asarray(got) <= 1)).all()
else:
    def test_kde_kernel_property_needs_hypothesis():
        pytest.importorskip("hypothesis")
