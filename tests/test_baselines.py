"""proxy-mity + Dec-SARSA baselines (paper §VII-A5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DecSarsaParams, decsarsa_init, decsarsa_select,
                        decsarsa_update, proxy_mity_weights)


def test_proxy_mity_alpha_one_routes_nearest():
    rtt = jnp.asarray([[0.01, 0.002, 0.05], [0.03, 0.04, 0.001]])
    w = proxy_mity_weights(rtt, alpha=1.0)
    np.testing.assert_allclose(np.asarray(w),
                               [[0, 1, 0], [0, 0, 1]], atol=1e-6)


def test_proxy_mity_alpha09_spreads_ten_percent():
    rtt = jnp.asarray([[0.01, 0.002, 0.05]])
    w = np.asarray(proxy_mity_weights(rtt, alpha=0.9))
    assert w[0, 1] == pytest.approx(0.9 + 0.1 / 3, abs=1e-5)
    assert w[0, 0] == pytest.approx(0.1 / 3, abs=1e-5)
    assert w.sum() == pytest.approx(1.0)


def test_proxy_mity_respects_active():
    rtt = jnp.asarray([[0.001, 0.01, 0.02]])
    act = jnp.asarray([False, True, True])
    w = np.asarray(proxy_mity_weights(rtt, 1.0, act))
    assert w[0, 0] == 0.0 and w[0, 1] == pytest.approx(1.0)


def test_decsarsa_learns_to_avoid_failures():
    K, M = 2, 3
    rtt = jnp.asarray([[0.01, 0.01, 0.01]] * K)
    p = DecSarsaParams(tau=0.08, eps=0.2)
    st = decsarsa_init(K, M, rtt, p)
    key = jax.random.PRNGKey(0)
    # arm 2 always violates the deadline, others always meet it
    for i in range(400):
        key, sub = jax.random.split(key)
        a, s = decsarsa_select(st, p, jnp.ones((M,), bool), sub)
        lat = jnp.where(a == 2, 0.5, 0.01)
        r = (lat <= p.tau).astype(jnp.float32)
        st = decsarsa_update(st, p, s, a, r, lat, jnp.ones((K,), bool))
    q = np.asarray(st.q)
    # greedy action should not be arm 2 in any state bucket visited
    greedy = q.argmax(-1)
    assert (greedy != 2).all()


def test_decsarsa_average_reward_tracks():
    K, M = 1, 2
    rtt = jnp.zeros((K, M))
    p = DecSarsaParams()
    st = decsarsa_init(K, M, rtt, p)
    for i in range(300):
        st = decsarsa_update(st, p, jnp.zeros((K,), jnp.int32),
                             jnp.zeros((K,), jnp.int32),
                             jnp.ones((K,)), jnp.full((K,), 0.01),
                             jnp.ones((K,), bool))
    assert float(st.rbar[0]) > 0.9
