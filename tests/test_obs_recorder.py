"""Flight recorder invariants: the observability layer must be free
when off and exact when on.

1. **Disabled = absent** — ``recorder=None`` and a zero-capacity
   ``RecorderConfig`` lower to byte-identical HLO for every strategy
   (the gate is Python-level static config), and the disabled program
   reproduces the committed HEAD golden
   (``tests/data/neutral_stream_ref.npz``) bit-for-bit, plain and
   chunked, plus (subprocess) on the 2x2 (data, players) sharded grid.
2. **Ring semantics** — wraparound keeps exactly the last ``capacity``
   events in order, the append/drop counters stay exact across
   overflow, and intra-batch overflow never reorders lanes.
3. **Engine composition** — recorder state streams through chunking
   and checkpoint/resume bit-exactly, and player-sharded runs record
   the SAME event set as the unsharded run (subprocess, 8/2/1-way)
   while adding zero in-loop collectives to the lowered program.
4. **NaN-explicit recovery windows** — regression for the
   ``event_recovery`` degenerate cases (no post data, all-shed tail,
   empty pre-window).
"""
import dataclasses
import math
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_sub
from repro.continuum import (SimConfig, compile_scenario, event_recovery,
                             get_library, make_topology, run_sim,
                             run_sim_stream)
from repro.continuum.simulator import build_sim_fn
from repro.obs import (KIND_MARK, KIND_QOS_SPIKE, RecorderConfig,
                       events_appended, events_dropped, recorder_enabled,
                       recorder_events, recorder_init)
from repro.obs import recorder as obr

K, M = 10, 4
CFG = SimConfig(horizon=12.0)
WARM = 30
STRATEGIES = (("qedgeproxy", {}), ("proxy_mity", dict(alpha=0.9)),
              ("dec_sarsa", {}))
REF = os.path.join(os.path.dirname(__file__), "data",
                   "neutral_stream_ref.npz")


def _inputs():
    rtt = make_topology(jax.random.PRNGKey(2), K, M).lb_instance_rtt()
    return rtt, jax.random.PRNGKey(5)


def _storm_cfg(capacity=4096):
    # bounded lifecycle + relaxed tau so retry_storm actually trips
    # breakers/retries; the recorder has real events to catch
    return dataclasses.replace(
        CFG, tau=0.150, attempt_timeout=0.090, max_retries=2,
        retry_backoff=0.002, breaker_threshold=5, breaker_cooldown=1.0,
        recorder=RecorderConfig(capacity=capacity))


def _storm_drivers(cfg):
    lib = get_library(cfg.horizon, K, M)
    return compile_scenario(lib["retry_storm"], cfg, jax.random.PRNGKey(7))


# -- invariant 1: disabled recorder is absent, bit for bit -------------

def test_recorder_config_gate():
    assert not recorder_enabled(SimConfig())
    assert not recorder_enabled(
        dataclasses.replace(CFG, recorder=RecorderConfig(capacity=0)))
    assert recorder_enabled(
        dataclasses.replace(CFG, recorder=RecorderConfig(capacity=8)))
    assert not SimConfig().recorder_on


@pytest.mark.parametrize("strat,kw", STRATEGIES,
                         ids=[s for s, _ in STRATEGIES])
def test_disabled_hlo_byte_identity(strat, kw):
    """``recorder=None`` and a zero-capacity config lower to the SAME
    program text — observability off is structurally absent."""
    rtt, key = _inputs()
    texts = []
    for rec in (None, RecorderConfig(capacity=0)):
        cfg = dataclasses.replace(CFG, recorder=rec)
        run = build_sim_fn(strat, cfg, K, M, trace=False,
                           warmup_steps=WARM, **kw)
        texts.append(jax.jit(run)
                     .lower(rtt, _neutral(cfg), key).as_text())
    assert texts[0] == texts[1]


def _neutral(cfg):
    from repro.continuum import neutral_drivers
    return neutral_drivers(cfg, K, M)


@pytest.mark.parametrize("strat,kw", STRATEGIES,
                         ids=[s for s, _ in STRATEGIES])
def test_disabled_bit_identity_vs_head(strat, kw):
    """Zero-capacity recorder reproduces the committed HEAD golden
    bit-for-bit, plain and chunked, and carries no recorder state
    out."""
    rtt, key = _inputs()
    ref = np.load(REF)
    cfg = dataclasses.replace(CFG, recorder=RecorderConfig(capacity=0))
    for chunk in (None, 25):
        out = run_sim_stream(strat, rtt, cfg, key, warmup_steps=WARM,
                             chunk_steps=chunk, **kw)
        assert out.rec is None
        for f in out.acc._fields:
            if f"{strat}.acc.{f}" in ref.files:
                np.testing.assert_array_equal(
                    np.asarray(getattr(out.acc, f)),
                    ref[f"{strat}.acc.{f}"],
                    err_msg=f"{strat} chunk={chunk} acc.{f}")
        for f in out.series._fields:
            if f"{strat}.series.{f}" in ref.files:
                np.testing.assert_array_equal(
                    np.asarray(getattr(out.series, f)),
                    ref[f"{strat}.series.{f}"],
                    err_msg=f"{strat} chunk={chunk} series.{f}")


def test_recorder_is_streaming_only():
    rtt, key = _inputs()
    with pytest.raises(ValueError, match="streaming"):
        run_sim("qedgeproxy", rtt,
                dataclasses.replace(CFG,
                                    recorder=RecorderConfig(capacity=8)),
                key)


@pytest.mark.slow
def test_disabled_parity_sharded_2x2_8dev():
    """On a 2x2 (data, players) mesh the zero-capacity grid program
    lowers byte-identically to recorder=None and produces bit-identical
    outputs."""
    out = run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.continuum import (SimConfig, compile_scenario,
                                     get_library, make_topology,
                                     run_sim_grid, stack_drivers)
        from repro.continuum.simulator import build_sim_grid_fn
        from repro.launch.mesh import make_continuum_mesh
        from repro.obs import RecorderConfig

        K, M, S, WARM = 16, 4, 2, 10
        cfg0 = SimConfig(horizon=3.0)
        rtts = jnp.stack([make_topology(jax.random.PRNGKey(s), K, M)
                          .lb_instance_rtt() for s in range(S)])
        keys = jnp.stack([jax.random.PRNGKey(100 + s) for s in range(S)])
        lib = get_library(cfg0.horizon, K, M)
        drivers = stack_drivers(
            [compile_scenario(lib[n], cfg0, jax.random.PRNGKey(i))
             for i, n in enumerate(("surge", "rolling_restart"))])
        mesh = make_continuum_mesh(players=2, devices=jax.devices()[:4])
        outs, texts = [], []
        for rec in (None, RecorderConfig(capacity=0)):
            cfg = dataclasses.replace(cfg0, recorder=rec)
            run, _ = build_sim_grid_fn("qedgeproxy", cfg, K, M,
                                       warmup_steps=WARM, mesh=mesh)
            texts.append(jax.jit(run).lower(rtts, drivers, keys).as_text())
            outs.append(run_sim_grid("qedgeproxy", rtts, cfg, keys,
                                     drivers=drivers, warmup_steps=WARM,
                                     mesh=mesh))
        assert texts[0] == texts[1], "sharded HLO differs"
        ref, got = outs
        for f in ref.acc._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(got.acc, f)),
                np.asarray(getattr(ref.acc, f)), err_msg=f"acc.{f}")
        for f in ref.series._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(got.series, f)),
                np.asarray(getattr(ref.series, f)),
                err_msg=f"series.{f}")
        assert got.rec is None
        print("OK sharded disabled parity")
    """)
    assert "OK sharded disabled parity" in out


# -- invariant 2: ring semantics ---------------------------------------

def _spike_step(rcfg, rec, t, k_spiking):
    """Drive record_step with exactly ``k_spiking`` players missing
    100% of their issued requests at step ``t``."""
    miss = jnp.where(jnp.arange(K) < k_spiking, 3.0, 0.0)
    return obr.record_step(
        rcfg, rec, t_idx=jnp.int32(t), pids=jnp.arange(K),
        marks=jnp.full((2,), -1, jnp.int32), miss_k=miss, iss_k=miss)


def test_ring_wraparound_keeps_last_capacity_in_order():
    rcfg = RecorderConfig(capacity=8)
    rec = recorder_init(rcfg, K, M, track_breakers=False)
    step = jax.jit(_spike_step, static_argnums=(0, 3))
    for t in range(6):          # 6 steps x 3 spiking players = 18
        rec = step(rcfg, rec, t, 3)
    assert int(events_appended(rec)) == 18
    assert int(events_dropped(rec)) == 10
    evs = recorder_events(rec)
    assert len(evs) == 8        # exactly the last `capacity`
    # the newest 8 events, in (step, seq) order
    assert [(e.step, e.entity) for e in evs] == [
        (3, 2), (4, 0), (4, 1), (4, 2), (5, 0), (5, 1), (5, 2)][-8:] or \
        [(e.step, e.entity) for e in evs] == [
        (3, 1), (3, 2), (4, 0), (4, 1), (4, 2), (5, 0), (5, 1), (5, 2)]
    assert all(e.kind == KIND_QOS_SPIKE for e in evs)
    steps = [e.step for e in evs]
    assert steps == sorted(steps)


def test_intra_batch_overflow_keeps_newest_lanes():
    """One batch larger than the whole ring: only the LAST `cap`
    candidates of the batch survive — earlier lanes must not clobber
    later ones regardless of scatter order."""
    rcfg = RecorderConfig(capacity=4)
    rec = recorder_init(rcfg, K, M, track_breakers=False)
    rec = jax.jit(_spike_step, static_argnums=(0, 3))(rcfg, rec, 0, 7)
    assert int(events_appended(rec)) == 7
    assert int(events_dropped(rec)) == 3
    evs = recorder_events(rec)
    assert [e.entity for e in evs] == [3, 4, 5, 6]


def test_mark_events_fire_once_on_owner_shard():
    rcfg = RecorderConfig(capacity=16)
    rec = recorder_init(rcfg, K, M, track_breakers=False)
    marks = jnp.asarray([2, 5, -1], jnp.int32)

    def step(rec, t):
        return obr.record_step(
            rcfg, rec, t_idx=jnp.int32(t), pids=jnp.arange(K),
            marks=marks, miss_k=jnp.zeros((K,)), iss_k=jnp.ones((K,)))

    for t in range(8):
        rec = step(rec, t)
    evs = recorder_events(rec)
    # fleet lane: entity is the MARK INDEX, once each, on the owner
    assert [(e.step, e.kind, e.entity) for e in evs] == [
        (2, KIND_MARK, 0), (5, KIND_MARK, 1)]
    # a non-owner shard (pids not containing 0) records no fleet events
    rec2 = recorder_init(rcfg, K, M, track_breakers=False)
    for t in range(8):
        rec2 = obr.record_step(
            rcfg, rec2, t_idx=jnp.int32(t), pids=jnp.arange(K) + K,
            marks=marks, miss_k=jnp.zeros((K,)), iss_k=jnp.ones((K,)))
    assert recorder_events(rec2) == []


# -- invariant 3: engine composition -----------------------------------

def _rec_fields_equal(a, b, msg):
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(b, f)), np.asarray(getattr(a, f)),
            err_msg=f"{msg} rec.{f}")


def test_recorder_chunked_matches_unchunked():
    rtt, key = _inputs()
    cfg = _storm_cfg()
    drv = _storm_drivers(cfg)
    full = run_sim_stream("qedgeproxy", rtt, cfg, key, drivers=drv,
                          warmup_steps=WARM)
    assert int(events_appended(full.rec)) > 0, "storm must record"
    chun = run_sim_stream("qedgeproxy", rtt, cfg, key, drivers=drv,
                          warmup_steps=WARM, chunk_steps=25)
    for f in full.acc._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(chun.acc, f)),
            np.asarray(getattr(full.acc, f)), err_msg=f"acc.{f}")
    _rec_fields_equal(full.rec, chun.rec, "chunked")


def test_recorder_checkpoint_resume_exact(tmp_path):
    """Killed-and-resumed == uninterrupted with the recorder ring in
    the carry — including under a different resumed chunk length."""
    rtt, key = _inputs()
    cfg = _storm_cfg()
    drv = _storm_drivers(cfg)
    d = str(tmp_path / "ck")
    full = run_sim_stream("qedgeproxy", rtt, cfg, key, drivers=drv,
                          warmup_steps=WARM, chunk_steps=40)
    run_sim_stream("qedgeproxy", rtt, cfg, key, drivers=drv,
                   warmup_steps=WARM, chunk_steps=40,
                   checkpoint_dir=d, stop_at_step=80)
    res = run_sim_stream("qedgeproxy", rtt, cfg, key, drivers=drv,
                         warmup_steps=WARM, chunk_steps=25,
                         checkpoint_dir=d, resume=True)
    for f in full.acc._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res.acc, f)),
            np.asarray(getattr(full.acc, f)), err_msg=f"acc.{f}")
    _rec_fields_equal(full.rec, res.rec, "resumed")
    assert recorder_events(full.rec) == recorder_events(res.rec)
    shutil.rmtree(d)


@pytest.mark.slow
def test_recorder_sharded_matches_unsharded_8dev():
    """Player-sharded runs record the same event SET as the unsharded
    run (ring order is shard-local; capacity is large enough that
    nothing drops), and the recorder adds ZERO in-loop collectives to
    the sharded program."""
    out = run_sub("""
        import dataclasses, re
        import jax, jax.numpy as jnp, numpy as np
        from repro.continuum import (SimConfig, compile_scenario,
                                     get_library, make_topology,
                                     run_sim_players, run_sim_stream)
        from repro.continuum.simulator import build_sim_players_fn
        from repro.launch.mesh import make_continuum_mesh
        from repro.obs import RecorderConfig, recorder_events

        K, M, WARM = 16, 6, 10
        base = SimConfig(horizon=4.0, tau=0.150, service_time=0.0275,
                         attempt_timeout=0.055, max_retries=2,
                         retry_backoff=0.002, breaker_threshold=4,
                         breaker_cooldown=1.0)
        cfg = dataclasses.replace(
            base, recorder=RecorderConfig(capacity=65536))
        rtt = make_topology(jax.random.PRNGKey(0), K, M).lb_instance_rtt()
        key = jax.random.PRNGKey(7)
        lib = get_library(cfg.horizon, K, M)
        drv = compile_scenario(lib["retry_storm"], cfg,
                               jax.random.PRNGKey(3))

        def evset(rec):
            return sorted((e.step, e.kind, e.entity, round(e.value, 4))
                          for e in recorder_events(rec))

        ref = run_sim_stream("qedgeproxy", rtt, cfg, key, drivers=drv,
                             warmup_steps=WARM)
        ref_set = evset(ref.rec)
        assert len(ref_set) > 10, "storm must record enough to bite"
        for D in (8, 2, 1):
            mesh = make_continuum_mesh(players=D,
                                       devices=jax.devices()[:D])
            got = run_sim_players("qedgeproxy", rtt, cfg, key,
                                  drivers=drv, warmup_steps=WARM,
                                  mesh=mesh)
            assert evset(got.rec) == ref_set, f"D={D} event set differs"
        # no new in-loop collectives: the enabled sharded program has
        # exactly as many all-reduces as the disabled one
        mesh = make_continuum_mesh(players=8, devices=jax.devices()[:8])
        n_ar = {}
        for label, rc in (("off", None),
                          ("on", RecorderConfig(capacity=65536))):
            c = dataclasses.replace(base, recorder=rc)
            run, _ = build_sim_players_fn("qedgeproxy", c, K, M,
                                          warmup_steps=WARM, mesh=mesh)
            text = jax.jit(run).lower(rtt, drv, key).as_text()
            n_ar[label] = len(re.findall(r"all-reduce", text))
        assert n_ar["on"] == n_ar["off"], n_ar
        print("OK sharded recorder", len(ref_set), n_ar)
    """)
    assert "OK sharded recorder" in out


# -- invariant 4: NaN-explicit recovery windows ------------------------

def test_event_recovery_nan_edges():
    b = 1.0
    # row 0: sentinel (no data at all) -> skipped
    # row 1: pre data, zero post data -> NaN dip/steady, not recovered
    # row 2: NO pre data, some post data -> pre is NaN, dip is real
    # row 3: all-shed tail (post buckets all miss) -> steady 0,
    #        recovery_s None instead of instant recovery
    # row 4: healthy dip-and-recover
    ev_n = np.array([[0, 0, 0, 0],
                     [8, 0, 0, 0],
                     [0, 4, 4, 4],
                     [8, 4, 4, 4],
                     [8, 4, 4, 4]], np.float64)
    ev_s = np.array([[0, 0, 0, 0],
                     [8, 0, 0, 0],
                     [0, 2, 3, 4],
                     [8, 0, 0, 0],
                     [8, 1, 4, 4]], np.float64)
    recs = event_recovery((ev_s, ev_n), b)
    assert len(recs) == 4
    no_post, no_pre, shed, healthy = recs
    assert no_post["pre"] == 1.0
    assert math.isnan(no_post["dip"]) and math.isnan(no_post["steady"])
    assert no_post["recovered"] is False and no_post["recovery_s"] is None
    assert math.isnan(no_pre["pre"])
    assert no_pre["dip"] == 0.5
    assert shed["steady"] == 0.0
    assert shed["recovered"] is False and shed["recovery_s"] is None
    assert healthy["recovered"] is True
    assert healthy["dip"] == 0.25 and healthy["recovery_s"] == 1.0


def test_event_recovery_all_shed_run_end_to_end():
    """A scenario whose post-event traffic is fully shed must yield a
    NaN-dip record through the real engine path, not crash or report a
    recovery."""
    recs = event_recovery(
        (np.array([[5.0, 0.0]]), np.array([[5.0, 0.0]])), 2.0)
    assert len(recs) == 1
    assert math.isnan(recs[0]["dip"]) and recs[0]["recovery_s"] is None
