"""Roofline package arithmetic on hand-computed specs, plus a smoke
test of the measured ``roofline_round`` benchmark row."""
import numpy as np
import pytest

from repro import roofline
from repro.roofline import hw


def test_roofline_terms_hand_computed():
    # exactly one second on each roof, by construction
    t = roofline.roofline_terms(flops=hw.PEAK_FLOPS_BF16,
                                hbm_bytes=hw.HBM_BW,
                                coll_bytes=hw.ICI_BW_PER_LINK * 4,
                                ici_links=4)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
    assert t["bound_s"] == pytest.approx(1.0)


def test_roofline_terms_dominant():
    t = roofline.roofline_terms(flops=2 * hw.PEAK_FLOPS_BF16,
                                hbm_bytes=hw.HBM_BW,
                                coll_bytes=0.0)
    assert t["dominant"] == "compute"
    assert t["bound_s"] == pytest.approx(2.0)
    t = roofline.roofline_terms(flops=0.0, hbm_bytes=3 * hw.HBM_BW,
                                coll_bytes=hw.ICI_BW_PER_LINK)
    assert t["dominant"] == "memory"
    assert t["bound_s"] == pytest.approx(3.0)
    # halving the links doubles the collective term
    a = roofline.roofline_terms(1.0, 1.0, 1e9, ici_links=4)
    b = roofline.roofline_terms(1.0, 1.0, 1e9, ici_links=2)
    assert b["collective_s"] == pytest.approx(2 * a["collective_s"])


def test_collective_bytes_hand_computed():
    hlo = """
  %ar = f32[1024,256] all-reduce(f32[1024,256] %x), to_apply=%sum
  %ag = bf16[64,128] all-gather(bf16[32,128] %y), dimensions={0}
  %cp = f32[16] collective-permute(f32[16] %z)
  %add = f32[8,8] add(f32[8,8] %a, f32[8,8] %b)
"""
    out = roofline.collective_bytes(hlo)
    # all-reduce moves ~2x its payload per chip in a ring
    assert out["all-reduce"] == 1024 * 256 * 4 * 2.0
    assert out["all-gather"] == 64 * 128 * 2 * 1.0
    assert out["collective-permute"] == 16 * 4 * 1.0
    assert out["all-to-all"] == 0.0
    assert out["_counts"]["all-reduce"] == 1


def test_collective_bytes_async_pairs_counted_once():
    hlo = """
  %s = f32[100] all-reduce-start(f32[100] %x), to_apply=%sum
  %d = f32[100] all-reduce-done(f32[100] %s)
"""
    out = roofline.collective_bytes(hlo)
    assert out["all-reduce"] == 100 * 4 * 2.0
    assert out["_counts"]["all-reduce"] == 1


def test_model_flops():
    assert roofline.model_flops(1e9, 1e9, 1e12, "train") == 6e21
    # inference counts active params only (MoE)
    assert roofline.model_flops(1e9, 2e8, 1e12, "inference") == 2.0 * 2e8 * 1e12


def test_hw_bytes_table():
    assert hw.BYTES["f32"] == 4
    assert hw.BYTES["bf16"] == 2
    assert hw.BYTES["pred"] == 1


def test_roofline_round_smoke(monkeypatch, tmp_path):
    """The measured benchmark row on a tiny cell: per-step FLOPs/bytes
    finite and nonzero, intensity nonzero, every model term positive."""
    from benchmarks import common
    from benchmarks.roofline_round import roofline_round

    monkeypatch.setattr(common, "SMOKE", True)
    # emit() writes the JSON artifact — keep the smoke payload out of
    # the committed full-cell results/benchmarks/roofline_round.json
    monkeypatch.setattr(common, "RESULTS_DIR", str(tmp_path))
    payload = roofline_round()
    ps = payload["per_step"]
    assert np.isfinite(ps["flops"]) and ps["flops"] > 0
    assert np.isfinite(ps["hbm_bytes"]) and ps["hbm_bytes"] > 0
    assert np.isfinite(ps["intensity_flops_per_byte"])
    assert ps["intensity_flops_per_byte"] > 0
    assert payload["roofline"]["bound_s"] > 0
    assert payload["roofline"]["dominant"] in ("compute", "memory",
                                               "collective")
    assert payload["measured"]["steps_per_s"] > 0
