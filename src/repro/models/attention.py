"""Attention block: GQA with RoPE, optional qk-norm / QKV bias / sliding
window; full-sequence (train/prefill) and single-token (decode) paths.

The heavy math dispatches through ``repro.kernels.ops`` (Pallas on TPU,
jnp reference elsewhere).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers as L
from repro.sharding import constrain


def init_attn(key, cfg: ModelConfig):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": L._dense_init(ks[0], (d, qd)),
        "wk": L._dense_init(ks[1], (d, kvd)),
        "wv": L._dense_init(ks[2], (d, kvd)),
        "wo": L._dense_init(ks[3], (qd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), jnp.float32)
        p["bk"] = jnp.zeros((kvd,), jnp.float32)
        p["bv"] = jnp.zeros((kvd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), jnp.float32)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), jnp.float32)
    return p


def axes_attn(cfg: ModelConfig):
    a = {
        "wq": ("embed_fsdp", "heads"),
        "wk": ("embed_fsdp", "kv_heads"),
        "wv": ("embed_fsdp", "kv_heads"),
        "wo": ("heads", "embed_fsdp"),
    }
    if cfg.qkv_bias:
        a["bq"] = ("heads",)
        a["bk"] = ("kv_heads",)
        a["bv"] = ("kv_heads",)
    if cfg.qk_norm:
        a["q_norm"] = (None,)
        a["k_norm"] = (None,)
    return a


def _project_qkv(p, cfg: ModelConfig, x, positions, dtype, use_rope=True):
    B = x.shape[0]
    S = x.shape[1]
    Hq, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    q = q.reshape(B, S, Hq, Dh).transpose(0, 2, 1, 3)     # (B,Hq,S,D)
    k = k.reshape(B, S, Hkv, Dh).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, Hkv, Dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.rms_eps)
    if use_rope:
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "heads", None, None)
    k = constrain(k, "batch", "kv_heads", None, None)
    v = constrain(v, "batch", "kv_heads", None, None)
    return q, k, v


def attn_full(
    p, cfg: ModelConfig, x: jax.Array, positions: jax.Array, dtype,
    window: int | None = None, causal: bool = True, use_rope: bool = True,
):
    """Full-sequence attention. Returns (out (B,S,d), (k, v) for caching)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions, dtype, use_rope)
    o = ops.attention(q, k, v, causal=causal, window=window)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.q_dim)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(dtype))
    return constrain(out, "batch", None, None), (k, v)


def attn_decode(
    p, cfg: ModelConfig, x: jax.Array, pos: jax.Array,
    k_cache: jax.Array, v_cache: jax.Array, length: jax.Array,
    write_idx: jax.Array, dtype, use_rope: bool = True,
):
    """One-token attention against a (possibly ring) KV cache.

    x: (B, 1, d); pos: scalar absolute position (for RoPE); write_idx:
    scalar slot to write (== pos for full caches, pos % W for rings);
    length: valid cache entries *after* this token is appended.
    Returns (out (B, 1, d), k_cache', v_cache').
    """
    B = x.shape[0]
    Hq, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    positions = jnp.reshape(pos, (1,))
    q, k, v = _project_qkv(p, cfg, x, positions, dtype, use_rope)  # (B,H,1,D)
    # move the per-token q/k/v (MBs) into the CACHE's layout instead of
    # letting XLA move the multi-GB cache into the activations' layout:
    # "kv_batch" re-points at the TP axis in the hybrid decode layout.
    q = constrain(q, "kv_batch", None, None, None)
    k = constrain(k, "kv_batch", None, None, None)
    v = constrain(v, "kv_batch", None, None, None)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, 0, write_idx, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, 0, write_idx, 0))
    lengths = jnp.full((B,), length, jnp.int32)
    o = ops.decode_attention(q[:, :, 0], k_cache.astype(dtype),
                             v_cache.astype(dtype), lengths)
    o = constrain(o.reshape(B, cfg.q_dim), "batch", "heads")
    out = jnp.einsum("bh,hd->bd", o, p["wo"].astype(dtype))
    return out[:, None, :], k_cache, v_cache
