"""Mamba-2 (SSD) mixer block [arXiv:2405.21060], ngroups=1.

Full path uses the chunked SSD kernel (``kernels.ops.ssd``); decode is
the O(1)-state recurrence. The block also exposes its final SSM + conv
states so serving can hand off prefill -> decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers as L
from repro.sharding import constrain


def _dims(cfg: ModelConfig):
    inner = cfg.ssm_inner
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = inner + 2 * N
    return inner, H, P, N, conv_dim


def init_ssm(key, cfg: ModelConfig):
    d = cfg.d_model
    inner, H, P, N, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": L._dense_init(ks[0], (d, 2 * inner + 2 * N + H)),
        "conv_w": L._dense_init(ks[1], (cfg.ssm_conv, conv_dim), scale=0.3),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "norm": jnp.zeros((inner,), jnp.float32),
        "out_proj": L._dense_init(ks[4], (inner, d)),
    }


def axes_ssm():
    return {
        "in_proj": ("embed_fsdp", "heads"),
        "conv_w": ("conv", "heads"),
        "conv_b": ("heads",),
        "A_log": (None,),
        "dt_bias": (None,),
        "norm": ("heads",),
        "out_proj": ("heads", "embed_fsdp"),
    }


def _split_proj(cfg, proj):
    inner, H, P, N, _ = _dims(cfg)
    z = proj[..., :inner]
    xin = proj[..., inner:2 * inner]
    Bc = proj[..., 2 * inner:2 * inner + N]
    Cc = proj[..., 2 * inner + N:2 * inner + 2 * N]
    dt = proj[..., 2 * inner + 2 * N:]
    return z, xin, Bc, Cc, dt


def ssm_full(p, cfg: ModelConfig, x: jax.Array, dtype,
             return_state: bool = False):
    """x: (B, S, d) -> out (B, S, d) [, (conv_state, h_state)]."""
    B, S, d = x.shape
    inner, H, P, N, conv_dim = _dims(cfg)
    proj = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(dtype))
    z, xin, Bc, Cc, dt_raw = _split_proj(cfg, proj)

    # causal depthwise conv over (x, B, C)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)        # (B,S,conv_dim)
    ck = cfg.ssm_conv
    padded = jnp.pad(conv_in, ((0, 0), (ck - 1, 0), (0, 0)))
    conv = sum(
        padded[:, i:i + S] * p["conv_w"][i].astype(dtype)
        for i in range(ck)) + p["conv_b"].astype(dtype)
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(dtype)
    xin = conv[..., :inner]
    Bc = conv[..., inner:inner + N]
    Cc = conv[..., inner + N:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,)
    xh = xin.reshape(B, S, H, P)
    xh = constrain(xh, "batch", None, "heads", None)
    y = ops.ssd(xh, dt, A, Bc.astype(jnp.float32), Cc.astype(jnp.float32),
                chunk=cfg.ssm_chunk)
    y = y.reshape(B, S, inner)

    # gated RMSNorm then output projection
    gate = jax.nn.silu(z.astype(jnp.float32)).astype(dtype)
    y = L.rms_norm(y * gate, p["norm"], cfg.rms_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(dtype))
    out = constrain(out, "batch", None, None)
    if not return_state:
        return out

    # final states for prefill -> decode handoff
    dtf = dt
    a = A[None, None, :] * dtf                                # (B,S,H)
    cum = jnp.cumsum(a, axis=1)
    w = jnp.exp(cum[:, -1:, :] - cum) * dtf                   # (B,S,H)
    h = jnp.einsum("bsh,bsn,bshp->bhnp", w, Bc.astype(jnp.float32),
                   xh.astype(jnp.float32))                    # (B,H,N,P)
    conv_state = jnp.concatenate(
        [jnp.zeros((B, ck - 1, conv_dim), dtype), conv_in], axis=1
    )[:, -(ck - 1):]
    return out, (conv_state, h)


def ssm_decode(p, cfg: ModelConfig, x: jax.Array, conv_state, h_state, dtype):
    """x: (B, 1, d). Returns (out (B,1,d), conv_state', h_state')."""
    B = x.shape[0]
    inner, H, P, N, conv_dim = _dims(cfg)
    proj = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(dtype))[:, 0]
    z, xin, Bc, Cc, dt_raw = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)         # (B, conv_dim)
    ck = cfg.ssm_conv
    window = jnp.concatenate([conv_state, conv_in[:, None]], axis=1)  # (B,ck,C)
    conv = jnp.einsum("bkc,kc->bc", window.astype(dtype),
                      p["conv_w"].astype(dtype)) + p["conv_b"].astype(dtype)
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(dtype)
    xin = conv[..., :inner]
    Bc = conv[..., inner:inner + N]
    Cc = conv[..., inner + N:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    h_state, y = ops.ssd_decode_step(
        h_state, xin.reshape(B, H, P).astype(jnp.float32), dt, A,
        Bc.astype(jnp.float32), Cc.astype(jnp.float32))
    y = y.reshape(B, inner).astype(dtype)

    gate = jax.nn.silu(z.astype(jnp.float32)).astype(dtype)
    y = L.rms_norm(y * gate, p["norm"], cfg.rms_eps)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"].astype(dtype))
    return out[:, None], window[:, 1:], h_state
