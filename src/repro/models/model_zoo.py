"""Unified model API: ``build_model(cfg)`` -> ``Model`` with init / loss /
prefill / decode / cache constructors / dry-run input specs.

Batch layouts per family:
  LM (dense/moe/ssm/hybrid): {"tokens", "targets"} ints (B, S)
  VLM: + {"patches"} (B, num_patches, d) stub embeddings; text len = S - P
  audio (whisper): {"frames"} (B, S//2, d) stub embeddings + token pair

Decode batches: {"token" (B, 1), "pos" scalar} + cache pytree.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import (AUDIO, DENSE, HYBRID, MOE, SSM, VLM,
                                ModelConfig, ShapeConfig)
from repro.models import layers as L
from repro.models import transformer as T
from repro.models import whisper as W

MOE_AUX_WEIGHT = 0.01


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable
    param_axes: Callable
    loss: Callable            # (params, batch) -> scalar
    forward: Callable         # (params, batch) -> logits
    prefill: Callable         # (params, batch) -> (last logits, cache)
    decode: Callable          # (params, cache, batch) -> (logits, cache)
    init_cache: Callable      # (batch_size, cache_len) -> zeros pytree
    cache_axes: Callable      # (cache_len,) -> logical-axis pytree
    input_specs: Callable     # (shape) -> (batch specs, batch axes)


def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def _cache_len(cfg: ModelConfig, S: int) -> int:
    if cfg.sliding_window is not None and cfg.local_global_pattern is None:
        return min(S, cfg.sliding_window)
    return S


# ---------------------------------------------------------------------------
# Cache constructors (zeros) + logical axes, per family
# ---------------------------------------------------------------------------

def _kv_zeros(cfg, n_stack, B, S, dtype):
    shape = tuple(n_stack) + (B, cfg.num_kv_heads, S, cfg.head_dim)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def _kv_axes(n_stack_axes):
    ax = tuple(n_stack_axes) + ("kv_batch", "kv_heads", "ctx", None)
    return (ax, ax)


def _ssm_zeros(cfg, n_stack, B, dtype):
    conv_dim = cfg.ssm_inner + 2 * cfg.ssm_state
    conv = jnp.zeros(tuple(n_stack) + (B, cfg.ssm_conv - 1, conv_dim), dtype)
    h = jnp.zeros(tuple(n_stack) + (B, cfg.ssm_heads, cfg.ssm_state,
                                    cfg.ssm_head_dim), jnp.float32)
    return (conv, h)


def _ssm_axes(n_stack_axes):
    conv_ax = tuple(n_stack_axes) + ("batch", None, "heads")
    h_ax = tuple(n_stack_axes) + ("batch", "heads", None, None)
    return (conv_ax, h_ax)


def make_init_cache(cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)

    def init_cache(B: int, S: int):
        if cfg.family == AUDIO:
            L_ = cfg.num_layers
            sd = min(S, cfg.max_decode_len)
            k_self, v_self = _kv_zeros(cfg, (L_,), B, sd, dtype)
            k_x, v_x = _kv_zeros(cfg, (L_,), B, cfg.cross_kv_len, dtype)
            return (k_self, v_self, k_x, v_x)
        if cfg.local_global_pattern is not None:
            nl, ng = cfg.local_global_pattern
            period = nl + ng
            G = cfg.num_layers // period
            tail = cfg.num_layers - G * period
            Wd = min(S, cfg.sliding_window)
            c = {
                "group_local": _kv_zeros(cfg, (G, nl), B, Wd, dtype),
                "group_global": _kv_zeros(cfg, (G,), B, S, dtype),
            }
            if tail:
                c["tail_local"] = _kv_zeros(cfg, (tail,), B, Wd, dtype)
            return c
        L_ = cfg.num_layers
        Sc = _cache_len(cfg, S)
        if cfg.family == SSM:
            return {"layers": _ssm_zeros(cfg, (L_,), B, dtype)}
        if cfg.family == HYBRID:
            kv = _kv_zeros(cfg, (L_,), B, Sc, dtype)
            ssm = _ssm_zeros(cfg, (L_,), B, dtype)
            return {"layers": kv + ssm}
        return {"layers": _kv_zeros(cfg, (L_,), B, Sc, dtype)}

    return init_cache


def make_cache_axes(cfg: ModelConfig):
    def cache_axes():
        if cfg.family == AUDIO:
            ka = _kv_axes(("layers",))
            return ka + ka
        if cfg.local_global_pattern is not None:
            c = {
                "group_local": _kv_axes(("groups", "layers")),
                "group_global": _kv_axes(("groups",)),
            }
            nl, ng = cfg.local_global_pattern
            if cfg.num_layers % (nl + ng):
                c["tail_local"] = _kv_axes(("layers",))
            return c
        if cfg.family == SSM:
            return {"layers": _ssm_axes(("layers",))}
        if cfg.family == HYBRID:
            return {"layers": _kv_axes(("layers",)) + _ssm_axes(("layers",))}
        return {"layers": _kv_axes(("layers",))}

    return cache_axes


# ---------------------------------------------------------------------------
# Prefill cache post-processing: full K/V -> ring layout for window layers
# ---------------------------------------------------------------------------

def _to_ring(kv, W):
    """(..., S, D) full cache -> (..., W, D) ring with slot = t % W."""
    k, v = kv
    S = k.shape[-2]
    if S <= W:
        pad = [(0, 0)] * k.ndim
        pad[-2] = (0, W - S)
        return (jnp.pad(k, pad), jnp.pad(v, pad))
    sl = [slice(None)] * k.ndim
    sl[-2] = slice(S - W, S)
    k, v = k[tuple(sl)], v[tuple(sl)]
    slots = jnp.arange(S - W, S) % W
    order = jnp.argsort(slots)
    return (jnp.take(k, order, axis=-2), jnp.take(v, order, axis=-2))


def _pad_seq(kv, max_len):
    """Grow a full (non-ring) KV cache's seq axis to max_len slots."""
    k, v = kv
    S = k.shape[-2]
    if max_len is None or max_len <= S:
        return kv
    pad = [(0, 0)] * k.ndim
    pad[-2] = (0, max_len - S)
    return (jnp.pad(k, pad), jnp.pad(v, pad))


# ---------------------------------------------------------------------------
# build_model
# ---------------------------------------------------------------------------

def build_model(cfg: ModelConfig) -> Model:
    dtype = jnp.dtype(cfg.dtype)

    if cfg.family == AUDIO:
        return _build_whisper(cfg)

    def init(key):
        return T.init_params(key, cfg)

    def param_axes():
        return T.param_axes(cfg)

    def _embed_inputs(params, batch):
        x = L.embed_tokens(params["embed"], batch["tokens"], dtype)
        if cfg.family == VLM:
            x = jnp.concatenate([batch["patches"].astype(dtype), x], axis=1)
        return x

    def forward(params, batch, remat=False):
        x = _embed_inputs(params, batch)
        h, aux, _ = T.forward(params, cfg, x, collect_cache=False,
                              remat=remat)
        return T.logits_from_hidden(params, cfg, h), aux

    def loss(params, batch, remat=True):
        logits, aux = forward(params, batch, remat=remat)
        if cfg.family == VLM:   # only text positions carry labels
            logits = logits[:, cfg.num_patches:]
        l = _xent(logits, batch["targets"])
        if cfg.is_moe:
            l = l + MOE_AUX_WEIGHT * aux
        return l

    def prefill(params, batch, max_len=None):
        """max_len reserves decode headroom in the full-attention caches
        (ring caches are fixed at the window size)."""
        x = _embed_inputs(params, batch)
        h, _, caches = T.forward(params, cfg, x, collect_cache=True)
        logits = T.logits_from_hidden(params, cfg, h[:, -1:])
        if cfg.local_global_pattern is not None:
            Wd = cfg.sliding_window
            caches = {
                "group_local": _to_ring(caches["group_local"], Wd),
                "group_global": _pad_seq(caches["group_global"], max_len),
                **({"tail_local": _to_ring(caches["tail_local"], Wd)}
                   if "tail_local" in caches else {}),
            }
        elif cfg.family == SSM:
            pass                      # states are O(1); nothing to pad
        elif cfg.sliding_window is not None:
            c = caches["layers"]
            kv = _to_ring(c[:2], cfg.sliding_window)
            caches = {"layers": kv + tuple(c[2:])}
        else:
            c = caches["layers"]
            caches = {"layers": _pad_seq(c[:2], max_len) + tuple(c[2:])}
        return logits, caches

    def decode(params, cache, batch):
        return T.decode_step(params, cfg, cache, batch["token"], batch["pos"])

    init_cache = make_init_cache(cfg)
    cache_axes = make_cache_axes(cfg)

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        f32 = jnp.float32
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            if cfg.family == VLM:
                St = S - cfg.num_patches
                specs = {
                    "patches": jax.ShapeDtypeStruct(
                        (B, cfg.num_patches, cfg.d_model), jnp.bfloat16),
                    "tokens": jax.ShapeDtypeStruct((B, St), i32),
                }
                axes = {"patches": ("batch", None, None),
                        "tokens": ("batch", None)}
                if shape.kind == "train":
                    specs["targets"] = jax.ShapeDtypeStruct((B, St), i32)
                    axes["targets"] = ("batch", None)
            else:
                specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
                axes = {"tokens": ("batch", None)}
                if shape.kind == "train":
                    specs["targets"] = jax.ShapeDtypeStruct((B, S), i32)
                    axes["targets"] = ("batch", None)
            return specs, axes
        # decode
        specs = {"token": jax.ShapeDtypeStruct((B, 1), i32),
                 "pos": jax.ShapeDtypeStruct((), i32)}
        axes = {"token": ("batch", None), "pos": ()}
        return specs, axes

    return Model(cfg, init, param_axes, loss, forward, prefill, decode,
                 init_cache, cache_axes, input_specs)


# ---------------------------------------------------------------------------
# Whisper wiring
# ---------------------------------------------------------------------------

def _build_whisper(cfg: ModelConfig) -> Model:
    dtype = jnp.dtype(cfg.dtype)

    def init(key):
        return W.init_params(key, cfg)

    def param_axes():
        return W.param_axes(cfg)

    def forward(params, batch, remat=False):
        enc = W.encode(params, cfg, batch["frames"])
        logits, _ = W.decode_full(params, cfg, batch["tokens"], enc)
        return logits, jnp.zeros((), jnp.float32)

    def loss(params, batch, remat=True):
        logits, _ = forward(params, batch)
        return _xent(logits, batch["targets"])

    def prefill(params, batch, max_len=None):
        enc = W.encode(params, cfg, batch["frames"])
        logits, caches = W.decode_full(params, cfg, batch["tokens"], enc,
                                       collect_cache=True)
        # self-KV -> ring of max_decode_len
        k_self, v_self, k_x, v_x = caches
        k_self, v_self = _to_ring((k_self, v_self), cfg.max_decode_len)
        return logits[:, -1:], (k_self, v_self, k_x, v_x)

    def decode(params, cache, batch):
        return W.decode_step(params, cfg, cache, batch["token"], batch["pos"])

    init_cache = make_init_cache(cfg)
    cache_axes = make_cache_axes(cfg)

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        Se = S // 2                       # post-conv frame rate (stub)
        Sd = min(cfg.max_decode_len, S)
        if shape.kind in ("train", "prefill"):
            specs = {
                "frames": jax.ShapeDtypeStruct((B, Se, cfg.d_model),
                                               jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, Sd), jnp.int32),
            }
            axes = {"frames": ("batch", None, None),
                    "tokens": ("batch", None)}
            if shape.kind == "train":
                specs["targets"] = jax.ShapeDtypeStruct((B, Sd), jnp.int32)
                axes["targets"] = ("batch", None)
            return specs, axes
        specs = {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                 "pos": jax.ShapeDtypeStruct((), jnp.int32)}
        axes = {"token": ("batch", None), "pos": ()}
        return specs, axes

    return Model(cfg, init, param_axes, loss, forward, prefill, decode,
                 init_cache, cache_axes, input_specs)
