"""Whisper-style encoder-decoder backbone (conv frontend is a STUB:
``input_specs`` provides precomputed frame embeddings at the post-conv
rate). Sinusoidal positions, bidirectional encoder, causal decoder with
cross-attention; no RoPE.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import attention as A
from repro.models import layers as L
from repro.models.transformer import _stack_axes, _unroll
from repro.sharding import constrain


def init_cross_attn(key, cfg: ModelConfig):
    return A.init_attn(key, cfg)          # same shapes; bias/qknorm off


def _enc_layer_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": A.init_attn(ks[0], cfg),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff),
    }


def _dec_layer_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "self_attn": A.init_attn(ks[0], cfg),
        "ln_x": jnp.zeros((cfg.d_model,), jnp.float32),
        "cross_attn": init_cross_attn(ks[1], cfg),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff),
    }


def init_params(key, cfg: ModelConfig):
    k_e, k_enc, k_dec = jax.random.split(key, 3)
    enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    return {
        "embed": L.init_embed(k_e, cfg.vocab_size, cfg.d_model, tie=True),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "enc_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def param_axes(cfg: ModelConfig):
    attn_ax = A.axes_attn(cfg)
    enc = {"ln1": (None,), "attn": attn_ax, "ln2": (None,),
           "mlp": L.axes_mlp()}
    dec = {"ln1": (None,), "self_attn": attn_ax, "ln_x": (None,),
           "cross_attn": attn_ax, "ln2": (None,), "mlp": L.axes_mlp()}
    return {
        "embed": L.axes_embed(tie=True),
        "enc_layers": _stack_axes(enc),
        "enc_norm": (None,),
        "dec_layers": _stack_axes(dec),
        "final_norm": (None,),
    }


def _cross_attn_full(p, cfg, x, enc_out, dtype):
    """Queries from x (B,Sd,d), keys/values from enc_out (B,Se,d)."""
    B, Sd, _ = x.shape
    Se = enc_out.shape[1]
    Hq, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"].astype(dtype))
    q = q.reshape(B, Sd, Hq, Dh).transpose(0, 2, 1, 3)
    k = k.reshape(B, Se, Hkv, Dh).transpose(0, 2, 1, 3)
    v = v.reshape(B, Se, Hkv, Dh).transpose(0, 2, 1, 3)
    if Sd == Se:
        o = ops.attention(q, k, v, causal=False)
    else:  # ragged cross shape: grouped-GQA reference path
        G = Hq // Hkv
        scale = Dh ** -0.5
        qg = (q.astype(jnp.float32) * scale).reshape(B, Hkv, G, Sd, Dh)
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
        o = jnp.einsum("bhgqk,bhkd->bhgqd", jax.nn.softmax(logits, -1),
                       v.astype(jnp.float32))
        o = o.reshape(B, Hq, Sd, Dh).astype(dtype)
    o = o.transpose(0, 2, 1, 3).reshape(B, Sd, cfg.q_dim)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(dtype))
    return out, (k, v)


def _cross_attn_decode(p, cfg, x, k_cache, v_cache, dtype):
    B = x.shape[0]
    Hq, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dtype))
    q = q.reshape(B, 1, Hq, Dh).transpose(0, 2, 1, 3)[:, :, 0]
    Se = k_cache.shape[2]
    lengths = jnp.full((B,), Se, jnp.int32)
    o = ops.decode_attention(q, k_cache.astype(dtype),
                             v_cache.astype(dtype), lengths)
    out = jnp.einsum("bh,hd->bd", o.reshape(B, cfg.q_dim),
                     p["wo"].astype(dtype))
    return out[:, None]


def encode(params, cfg: ModelConfig, frames: jax.Array):
    """frames: (B, Se, d) stub embeddings -> (B, Se, d)."""
    dtype = jnp.dtype(cfg.dtype)
    Se = frames.shape[1]
    x = frames.astype(dtype) + L.sinusoidal_positions(
        Se, cfg.d_model).astype(dtype)[None]
    x = constrain(x, "batch", None, None)
    positions = jnp.arange(Se)

    def step(x, lp):
        h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
        a, _ = A.attn_full(lp["attn"], cfg, h, positions, dtype,
                           causal=False, use_rope=False)
        x = x + a
        h = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + L.mlp(lp["mlp"], h, dtype)
        return x, ()

    x, _ = jax.lax.scan(step, x, params["enc_layers"], unroll=_unroll())
    return L.rms_norm(x, params["enc_norm"], cfg.rms_eps)


def decode_full(params, cfg: ModelConfig, tokens: jax.Array,
                enc_out: jax.Array, collect_cache: bool = False):
    """Teacher-forced decoder pass. Returns (logits, caches or None)."""
    dtype = jnp.dtype(cfg.dtype)
    B, Sd = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, dtype)
    x = x + L.sinusoidal_positions(Sd, cfg.d_model).astype(dtype)[None]
    positions = jnp.arange(Sd)

    def step(x, lp):
        h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
        a, self_kv = A.attn_full(lp["self_attn"], cfg, h, positions, dtype,
                                 causal=True, use_rope=False)
        x = x + a
        h = L.rms_norm(x, lp["ln_x"], cfg.rms_eps)
        c, cross_kv = _cross_attn_full(lp["cross_attn"], cfg, h, enc_out,
                                       dtype)
        x = x + c
        h = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + L.mlp(lp["mlp"], h, dtype)
        ys = (self_kv + cross_kv) if collect_cache else ()
        return x, ys

    x, caches = jax.lax.scan(step, x, params["dec_layers"],
                             unroll=_unroll())
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = L.unembed(params["embed"], x, dtype)
    return logits, (caches if collect_cache else None)


def decode_step(params, cfg: ModelConfig, caches, token: jax.Array,
                pos: jax.Array):
    """One decoder token vs (self ring + fixed cross) caches."""
    dtype = jnp.dtype(cfg.dtype)
    B = token.shape[0]
    x = L.embed_tokens(params["embed"], token, dtype)
    pos_emb = jax.lax.dynamic_slice_in_dim(
        L.sinusoidal_positions(cfg.max_decode_len, cfg.d_model).astype(dtype),
        pos, 1, axis=0)
    x = x + pos_emb[None]

    def step(x, inp):
        lp, cache = inp
        k_self, v_self, k_cross, v_cross = cache
        W = k_self.shape[2]
        length = jnp.minimum(pos + 1, W)
        h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
        a, k_self, v_self = A.attn_decode(
            lp["self_attn"], cfg, h, pos, k_self, v_self, length,
            pos % W, dtype, use_rope=False)
        x = x + a
        h = L.rms_norm(x, lp["ln_x"], cfg.rms_eps)
        x = x + _cross_attn_decode(lp["cross_attn"], cfg, h, k_cross,
                                   v_cross, dtype)
        h = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + L.mlp(lp["mlp"], h, dtype)
        return x, (k_self, v_self, k_cross, v_cross)

    x, new_caches = jax.lax.scan(step, x, (params["dec_layers"], caches),
                                 unroll=_unroll())
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = L.unembed(params["embed"], x, dtype)
    return logits, new_caches
