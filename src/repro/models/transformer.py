"""Decoder-only LM assembly for every uniform-stack family
(dense / moe / ssm / hybrid / vlm-backbone), plus the gemma3 grouped
local:global stack. Layers are stacked (leading L axis) and executed
with ``lax.scan`` — one layer's HLO regardless of depth, which keeps
512-device SPMD compiles tractable and is what a production framework
does anyway.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import DENSE, HYBRID, MOE, SSM, VLM, ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.sharding import constrain


def _unroll() -> bool:
    # full unroll for the dry-run: XLA cost_analysis counts a while
    # body ONCE, so roofline FLOPs/bytes/collectives need the layer
    # loop expanded. Runtime code keeps unroll=1 (small HLO).
    return os.environ.get("REPRO_SCAN_UNROLL", "0") == "1"


# ---------------------------------------------------------------------------
# Per-layer params
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": jnp.zeros((d,), jnp.float32)}
    fam = cfg.family
    if fam in (DENSE, MOE, HYBRID, VLM):
        p["attn"] = A.init_attn(ks[0], cfg)
    if fam in (SSM, HYBRID):
        p["ssm"] = S.init_ssm(ks[1], cfg)
    if fam == HYBRID:
        p["attn_norm"] = jnp.zeros((d,), jnp.float32)
        p["ssm_norm"] = jnp.zeros((d,), jnp.float32)
    if fam == MOE:
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        p["moe"] = M.init_moe(ks[2], cfg)
    elif cfg.d_ff > 0:
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff)
    return p


def axes_layer(cfg: ModelConfig):
    a: Dict[str, Any] = {"ln1": (None,)}
    fam = cfg.family
    if fam in (DENSE, MOE, HYBRID, VLM):
        a["attn"] = A.axes_attn(cfg)
    if fam in (SSM, HYBRID):
        a["ssm"] = S.axes_ssm()
    if fam == HYBRID:
        a["attn_norm"] = (None,)
        a["ssm_norm"] = (None,)
    if fam == MOE:
        a["ln2"] = (None,)
        a["moe"] = M.axes_moe()
    elif cfg.d_ff > 0:
        a["ln2"] = (None,)
        a["mlp"] = L.axes_mlp()
    return a


def _stack_axes(tree, extra=("layers",)):
    """Prepend stacking logical axes to every leaf's axis tuple."""
    return jax.tree.map(lambda ax: tuple(extra) + tuple(ax), tree,
                        is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def layer_full(lp, cfg: ModelConfig, x, positions, dtype,
               window: Optional[int], collect_cache: bool):
    """One layer, full-sequence. Returns (x, (cache_k, cache_v, extras), aux)."""
    fam = cfg.family
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
    cache = ()
    if fam in (DENSE, MOE, VLM):
        a_out, kv = A.attn_full(lp["attn"], cfg, h, positions, dtype,
                                window=window)
        x = x + a_out
        if collect_cache:
            cache = kv
    elif fam == SSM:
        if collect_cache:
            s_out, (conv_st, h_st) = S.ssm_full(lp["ssm"], cfg, h, dtype,
                                                return_state=True)
            cache = (conv_st, h_st)
        else:
            s_out = S.ssm_full(lp["ssm"], cfg, h, dtype)
        x = x + s_out
    elif fam == HYBRID:
        a_out, kv = A.attn_full(lp["attn"], cfg, h, positions, dtype,
                                window=window)
        if collect_cache:
            s_out, (conv_st, h_st) = S.ssm_full(lp["ssm"], cfg, h, dtype,
                                                return_state=True)
            cache = kv + (conv_st, h_st)
        else:
            s_out = S.ssm_full(lp["ssm"], cfg, h, dtype)
        a_out = L.rms_norm(a_out, lp["attn_norm"], cfg.rms_eps)
        s_out = L.rms_norm(s_out, lp["ssm_norm"], cfg.rms_eps)
        x = x + 0.5 * (a_out + s_out)
    if fam == MOE:
        h2 = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
        m_out, aux = M.moe(lp["moe"], cfg, h2, dtype)
        x = x + m_out
    elif cfg.d_ff > 0:
        h2 = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + L.mlp(lp["mlp"], h2, dtype)
    return x, cache, aux


def layer_decode(lp, cfg: ModelConfig, x, pos, cache, dtype,
                 window: Optional[int]):
    """One layer, one token. cache is this layer's slice; returns updated."""
    fam = cfg.family
    h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
    if fam in (DENSE, MOE, VLM, HYBRID):
        k_cache, v_cache = cache[0], cache[1]
        W = k_cache.shape[2]
        write_idx = pos % W if window is not None else pos
        length = jnp.minimum(pos + 1, W)
        a_out, k_cache, v_cache = A.attn_decode(
            lp["attn"], cfg, h, pos, k_cache, v_cache, length, write_idx,
            dtype)
    if fam in (SSM, HYBRID):
        conv_st, h_st = (cache[-2], cache[-1])
        s_out, conv_st, h_st = S.ssm_decode(lp["ssm"], cfg, h, conv_st,
                                            h_st, dtype)
    if fam in (DENSE, MOE, VLM):
        x = x + a_out
        new_cache = (k_cache, v_cache)
    elif fam == SSM:
        x = x + s_out
        new_cache = (conv_st, h_st)
    else:  # hybrid
        a_out = L.rms_norm(a_out, lp["attn_norm"], cfg.rms_eps)
        s_out = L.rms_norm(s_out, lp["ssm_norm"], cfg.rms_eps)
        x = x + 0.5 * (a_out + s_out)
        new_cache = (k_cache, v_cache, conv_st, h_st)
    if fam == MOE:
        h2 = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
        m_out, _ = M.moe(lp["moe"], cfg, h2, dtype)
        x = x + m_out
    elif cfg.d_ff > 0:
        h2 = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + L.mlp(lp["mlp"], h2, dtype)
    return x, new_cache


# ---------------------------------------------------------------------------
# Whole-stack init (uniform and gemma3-grouped)
# ---------------------------------------------------------------------------

def _vmap_init(key, cfg, n):
    return jax.vmap(lambda k: init_layer(k, cfg))(jax.random.split(key, n))


def init_params(key, cfg: ModelConfig):
    k_embed, k_layers, k_tail, k_glob = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": L.init_embed(k_embed, cfg.vocab_size, cfg.d_model,
                              cfg.tie_embeddings),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if cfg.local_global_pattern is None:
        params["layers"] = _vmap_init(k_layers, cfg, cfg.num_layers)
    else:
        n_local, n_global = cfg.local_global_pattern
        period = n_local + n_global
        n_groups = cfg.num_layers // period
        n_tail = cfg.num_layers - n_groups * period  # trailing local layers
        params["group_local"] = jax.vmap(
            lambda k: _vmap_init(k, cfg, n_local))(
                jax.random.split(k_layers, n_groups))
        params["group_global"] = _vmap_init(k_glob, cfg, n_groups)
        if n_tail:
            params["tail_local"] = _vmap_init(k_tail, cfg, n_tail)
    return params


def param_axes(cfg: ModelConfig):
    axes: Dict[str, Any] = {
        "embed": L.axes_embed(cfg.tie_embeddings),
        "final_norm": (None,),
    }
    la = axes_layer(cfg)
    if cfg.local_global_pattern is None:
        axes["layers"] = _stack_axes(la, ("layers",))
    else:
        n_local, n_global = cfg.local_global_pattern
        period = n_local + n_global
        n_groups = cfg.num_layers // period
        n_tail = cfg.num_layers - n_groups * period
        axes["group_local"] = _stack_axes(la, ("groups", "layers"))
        axes["group_global"] = _stack_axes(la, ("groups",))
        if n_tail:
            axes["tail_local"] = _stack_axes(la, ("layers",))
    return axes


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _scan_stack(stacked, cfg, x, positions, dtype, window, collect, remat):
    body = functools.partial(layer_full, cfg=cfg, positions=positions,
                             dtype=dtype, window=window,
                             collect_cache=collect)

    def step(carry, lp):
        x, aux_sum = carry
        fn = body
        if remat:
            fn = jax.checkpoint(
                lambda lp_, x_: body(lp_, x=x_),
                policy=jax.checkpoint_policies.nothing_saveable)
            x2, cache, aux = fn(lp, x)
        else:
            x2, cache, aux = body(lp, x=x)
        return (x2, aux_sum + aux), cache

    (x, aux), caches = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                                    stacked, unroll=_unroll())
    return x, aux, caches


def forward(params, cfg: ModelConfig, x_embed: jax.Array,
            collect_cache: bool = False, remat: bool = False):
    """Embedded inputs -> (final hidden, aux loss, caches pytree or None)."""
    B, Sq, d = x_embed.shape
    dtype = jnp.dtype(cfg.dtype)
    positions = jnp.arange(Sq)
    x = x_embed
    caches: Dict[str, Any] = {}
    if cfg.local_global_pattern is None:
        window = cfg.sliding_window
        x, aux, c = _scan_stack(params["layers"], cfg, x, positions, dtype,
                                window, collect_cache, remat)
        caches["layers"] = c
    else:
        aux = jnp.zeros((), jnp.float32)

        def group_step(carry, gp):
            x, aux_sum = carry
            x, aux_l, c_loc = _scan_stack(
                gp["local"], cfg, x, positions, dtype,
                cfg.sliding_window, collect_cache, remat)
            x, c_glob, aux_g = layer_full(gp["global"], cfg, x, positions,
                                          dtype, None, collect_cache)
            return (x, aux_sum + aux_l + aux_g), (c_loc, c_glob)

        gp = {"local": params["group_local"], "global": params["group_global"]}
        (x, aux), (c_loc, c_glob) = jax.lax.scan(group_step, (x, aux), gp,
                                                 unroll=_unroll())
        caches["group_local"] = c_loc
        caches["group_global"] = c_glob
        if "tail_local" in params:
            x, aux_t, c_tail = _scan_stack(
                params["tail_local"], cfg, x, positions, dtype,
                cfg.sliding_window, collect_cache, remat)
            aux = aux + aux_t
            caches["tail_local"] = c_tail
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    return x, aux, (caches if collect_cache else None)


def logits_from_hidden(params, cfg: ModelConfig, x: jax.Array):
    return L.unembed(params["embed"], x, jnp.dtype(cfg.dtype))


def lm_forward(params, cfg: ModelConfig, tokens: jax.Array,
               remat: bool = False, prefix_embeds: jax.Array | None = None):
    """tokens (B, S) [-> optionally preceded by embeds] -> logits."""
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(params["embed"], tokens, dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
    x, aux, _ = forward(params, cfg, x, collect_cache=False, remat=remat)
    return logits_from_hidden(params, cfg, x), aux


# ---------------------------------------------------------------------------
# Decode path over stacked caches
# ---------------------------------------------------------------------------

def _scan_decode(stacked, cfg, x, pos, caches, dtype, window):
    def step(x, inp):
        lp, cache = inp
        x, new_cache = layer_decode(lp, cfg, x, pos, cache, dtype, window)
        return x, new_cache

    x, new_caches = jax.lax.scan(step, x, (stacked, caches),
                                 unroll=_unroll())
    return x, new_caches


def decode_step(params, cfg: ModelConfig, caches, token: jax.Array,
                pos: jax.Array):
    """token (B, 1) at absolute position pos -> (logits (B,1,V), caches)."""
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(params["embed"], token, dtype)
    new_caches = {}
    if cfg.local_global_pattern is None:
        x, new_caches["layers"] = _scan_decode(
            params["layers"], cfg, x, pos, caches["layers"], dtype,
            cfg.sliding_window)
    else:
        def group_step(x, inp):
            gp, cache = inp
            x, c_loc = _scan_decode(gp["local"], cfg, x, pos, cache[0],
                                    dtype, cfg.sliding_window)
            x, c_glob = layer_decode(gp["global"], cfg, x, pos, cache[1],
                                     dtype, None)
            return x, (c_loc, c_glob)

        gp = {"local": params["group_local"], "global": params["group_global"]}
        x, (c_loc, c_glob) = jax.lax.scan(
            group_step, x, (gp, (caches["group_local"],
                                 caches["group_global"])),
            unroll=_unroll())
        new_caches["group_local"] = c_loc
        new_caches["group_global"] = c_glob
        if "tail_local" in params:
            x, new_caches["tail_local"] = _scan_decode(
                params["tail_local"], cfg, x, pos, caches["tail_local"],
                dtype, cfg.sliding_window)
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    return logits_from_hidden(params, cfg, x), new_caches
