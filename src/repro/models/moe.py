"""Mixture-of-Experts layer: top-k routing with sort-based ragged
dispatch (capacity-bounded), expert-parallel over the ``model`` axis.

Dense one-hot dispatch would inflate FLOPs by E/k (16x for 128/top-8);
instead tokens are sorted by expert id and scattered into per-expert
capacity buffers — compute stays proportional to *active* parameters,
which is what the MoE rooflines must reflect. Overflowing tokens are
dropped (standard GShard/Switch semantics) and their share of the
residual stream falls through the skip connection.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import constrain, current_mesh, get_rules


def init_moe(key, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": L._dense_init(ks[0], (d, E)),
        "wi": L._dense_init(ks[1], (E, d, f)),
        "wg": L._dense_init(ks[2], (E, d, f)),
        "wo": L._dense_init(ks[3], (E, f, d)),
    }


def axes_moe():
    # experts take the whole TP ("model") axis, so the per-expert ffn dim
    # must NOT also map to it (one mesh axis per spec); d_model rows get
    # the FSDP ("data") shard instead.
    return {
        "router": ("embed", "experts"),
        "wi": ("experts", "embed_fsdp", None),
        "wg": ("experts", "embed_fsdp", None),
        "wo": ("experts", None, "embed_fsdp"),
    }


def moe(p, cfg: ModelConfig, x: jax.Array, dtype,
        capacity_factor: float | None = None):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    topv, topi = jax.lax.top_k(probs, k)                     # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss
    me = probs.mean(0)                                       # (E,)
    one_hot = jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(1)  # (T, E)
    ce = one_hot.mean(0) / k
    aux = E * jnp.sum(me * ce)

    # --- ragged dispatch: sort (token, expert) pairs by expert ---
    # Slot assignment is *shard-local*: tokens are ranked within their
    # own (data-shard, expert) bucket and written into that shard's
    # slice of the capacity axis. A globally-ranked scatter would cross
    # data shards, which XLA's SPMD partitioner implements by
    # replicating + all-reducing the whole (E, cap, d) buffer per layer
    # (TBs of traffic); shard-local slots keep every write local (this
    # is GShard's per-shard capacity semantics).
    cap = int(-(-T * k * capacity_factor // E))              # ceil
    cap = max(8, -(-cap // 8) * 8)
    flat_e = topi.reshape(-1)                                # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = topv.reshape(-1)
    order = jnp.argsort(flat_e)                              # stable
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    slot = jnp.arange(T * k, dtype=jnp.int32) - starts[se]   # rank in expert
    keep = slot < cap

    # --- dispatch as GATHER, not scatter -------------------------------
    # Scattering (T*k, d) activations into the expert-sharded buffer
    # makes XLA's SPMD partitioner replicate + all-reduce the whole
    # (E, cap, d) buffer every layer (TBs of collective traffic).
    # Instead scatter only the tiny int32 *index map* slot->token, then
    # move the big activations through gathers, which SPMD handles with
    # one all-gather of the (much smaller) source.
    tok_of_slot = jnp.zeros((E, cap), jnp.int32)
    tok_of_slot = tok_of_slot.at[
        se, jnp.where(keep, slot, cap)].set(st, mode="drop")
    has_tok = jnp.zeros((E, cap), bool).at[
        se, jnp.where(keep, slot, cap)].set(True, mode="drop")
    # replicate the gather SOURCE explicitly: one all-gather of (T, d)
    # activations per layer; otherwise SPMD partitions the gather by
    # all-reducing its (E, cap, d) f32 *output* (~10-70 GB/layer).
    xt_rep = constrain(xt.astype(dtype), None, None)
    buf = jnp.take(xt_rep, tok_of_slot.reshape(-1), axis=0)
    buf = buf.reshape(E, cap, d) * has_tok[..., None].astype(dtype)
    buf = constrain(buf, "experts", None, None)

    # --- expert GEMMs (batched over the expert-parallel axis) ---
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * h
    h = constrain(h, "experts", None, None)
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dtype))
    y = constrain(y, "experts", None, None)

    # --- combine: pure gathers, no scatter ------------------------------
    # token t's k assignments sit at flat positions t*k..t*k+k-1 in the
    # *unsorted* order, so un-permuting the expert outputs and a
    # reshape-sum replaces the scatter-add (which SPMD would otherwise
    # implement as replicate + all-reduce of the (T, d) activations).
    # replicate this gather's source too (same output-AR pathology as
    # dispatch; measured A4 vs A5 in EXPERIMENTS.md §Perf)
    y_flat = constrain(y.reshape(E * cap, d), None, None)
    gathered = jnp.take(y_flat, se * cap + jnp.minimum(slot, cap - 1),
                        axis=0)                              # (T*k, d)
    contrib = gathered * (sw * keep)[:, None].astype(dtype)
    inv = jnp.argsort(order)                                 # unsort
    out = jnp.take(contrib, inv, axis=0).reshape(T, k, d).sum(axis=1)
    out = constrain(out.reshape(B, S, d), "batch", None, None)
    return out, aux


def _dispatch_shards() -> int:
    """Number of data shards the token axis is split across (1 off-mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    rules = get_rules()
    n = 1
    for a in rules.get("batch", ()):
        n *= mesh.shape.get(a, 1)
    return max(1, n)
