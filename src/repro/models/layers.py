"""Shared model building blocks (pure-JAX, param pytrees).

Every ``init_*`` has a mirrored ``axes_*`` returning the same pytree
structure with logical-axis tuples instead of arrays (consumed by
``sharding.tree_shardings``); tests assert the structures match.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constrain


def _dense_init(key, shape, scale=None):
    scale = scale if scale is not None else shape[0] ** -0.5
    return jax.random.normal(key, shape, jnp.float32) * scale


def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, D) with D even; positions: (S,) or (B,S)."""
    D = x.shape[-1]
    half = D // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    # broadcast over head axis: x is (B, H, S, D), angles (B?, S, half)
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(num: int, dim: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (num, dim)."""
    half = dim // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    args = jnp.arange(num)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(args), jnp.cos(args)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d: int, tie: bool):
    ks = jax.random.split(key, 2)
    # tied: the table is also the unembedding, so keep logits O(1)
    p = {"tok": _dense_init(ks[0], (vocab, d), scale=d ** -0.5 if tie else 1.0)}
    if not tie:
        p["unembed"] = _dense_init(ks[1], (d, vocab))
    return p


def axes_embed(tie: bool):
    a = {"tok": ("vocab", "embed")}
    if not tie:
        a["unembed"] = ("embed", "vocab")
    return a


def embed_tokens(p, tokens: jax.Array, dtype) -> jax.Array:
    out = jnp.take(p["tok"].astype(dtype), tokens, axis=0)
    return constrain(out, "batch", None, None)


def unembed(p, x: jax.Array, dtype) -> jax.Array:
    if "unembed" in p:
        w = p["unembed"].astype(dtype)
    else:
        w = p["tok"].astype(dtype).T
    logits = jnp.einsum("...d,dv->...v", x, w)
    return constrain(logits, "batch", None, "vocab")


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int):
    ks = jax.random.split(key, 3)
    return {
        "wi": _dense_init(ks[0], (d, f)),
        "wg": _dense_init(ks[1], (d, f)),
        "wo": _dense_init(ks[2], (f, d)),
    }


def axes_mlp():
    return {"wi": ("embed_fsdp", "ffn"), "wg": ("embed_fsdp", "ffn"),
            "wo": ("ffn", "embed_fsdp")}


def mlp(p, x: jax.Array, dtype) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dtype))
    g = jnp.einsum("...d,df->...f", x, p["wg"].astype(dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * h
    h = constrain(h, "batch", None, "ffn")
    out = jnp.einsum("...f,fd->...d", h, p["wo"].astype(dtype))
    return constrain(out, "batch", None, None)
