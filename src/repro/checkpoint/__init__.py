"""Fault-tolerant checkpointing (atomic, async, elastic-restorable,
integrity-verified)."""
from repro.checkpoint.checkpointer import (SCHEMA_VERSION,
                                           CheckpointCorruptError,
                                           Checkpointer)

__all__ = ["Checkpointer", "CheckpointCorruptError", "SCHEMA_VERSION"]
