"""Fault-tolerant checkpointing (atomic, async, elastic-restorable)."""
from repro.checkpoint.checkpointer import Checkpointer

__all__ = ["Checkpointer"]
