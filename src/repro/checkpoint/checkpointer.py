"""Sharded checkpointing with atomic commits and async writes.

Layout per step::

    <dir>/step_<n>.tmp/   -> written, fsync'd, then os.replace ->
    <dir>/step_<n>/
        manifest.json     # treedef, shapes, dtypes, step
        arrays.npz        # flattened leaves keyed by path

Restore rebuilds the pytree and (optionally) re-device_puts every leaf
onto a *different* mesh/sharding — that is the elastic-restart path: a
job that lost a pod restores the same checkpoint onto the smaller mesh.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True) -> str:
        """Snapshot on the caller thread, write (optionally) async."""
        arrays, _ = _flatten(tree)
        manifest = {
            "step": int(step),
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                     for k, v in arrays.items()},
        }

        def write():
            final = os.path.join(self.dir, f"step_{step:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)          # atomic commit
            self._gc()

        self.wait()
        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return os.path.join(self.dir, f"step_{step:08d}")

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Rebuild `template`'s structure from disk.

        ``shardings`` (same structure, NamedSharding leaves) re-places
        every leaf — pass the *new* mesh's shardings for elastic restore.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for kpath, leaf in flat:
            key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in kpath)
            arr = data[key]
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(jnp.asarray(x), s),
                tree, shardings)
        else:
            tree = jax.tree.map(jnp.asarray, tree)
        return tree, step
