"""Sharded checkpointing with atomic commits, async writes, and
content integrity.

Layout per step::

    <dir>/step_<n>.tmp/   -> written, fsync'd, then os.replace ->
    <dir>/step_<n>/
        manifest.json     # schema, treedef, shapes, dtypes, step, checksum
        arrays.npz        # flattened leaves keyed by path

Restore rebuilds the pytree and (optionally) re-device_puts every leaf
onto a *different* mesh/sharding — that is the elastic-restart path: a
job that lost a pod restores the same checkpoint onto the smaller mesh.

Integrity: the manifest carries a ``schema`` version and the SHA-256 of
``arrays.npz``. Restore verifies both BEFORE any leaf is parsed and
raises :class:`CheckpointCorruptError` on a truncated, bit-flipped or
incompatibly-versioned checkpoint — resuming a multi-hour streaming run
from silently corrupted state would poison every step after it, so a
bad file must fail loudly at the resume boundary. The atomic-commit
protocol makes corruption unlikely (a torn write never lands on the
final path); the checksum covers what the protocol cannot: storage
rot, partial copies between machines, and human edits.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"

# Bump on any incompatible change to the on-disk layout. Version 1 =
# the original (manifest without integrity fields); absent fields are
# treated as version 1, so pre-upgrade checkpoints still restore.
SCHEMA_VERSION = 2


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed integrity verification: truncated/bit-flipped
    payload (checksum mismatch), unreadable manifest, or a schema
    version this code does not understand. Do NOT resume from it —
    delete the step directory (or the whole checkpoint dir) and restart
    from the previous good step or from scratch."""


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True,
             meta: Optional[dict] = None) -> str:
        """Snapshot on the caller thread, write (optionally) async.

        ``meta`` is an optional JSON-serialisable dict stored verbatim
        in the manifest (``manifest["meta"]``) — run provenance, config
        hashes, recorder cursors. It is observability payload only:
        restore ignores it entirely, so old readers and version-2
        manifests without the key are unaffected.
        """
        arrays, _ = _flatten(tree)
        manifest = {
            "schema": SCHEMA_VERSION,
            "step": int(step),
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                     for k, v in arrays.items()},
        }
        if meta is not None:
            manifest["meta"] = meta

        def write():
            final = os.path.join(self.dir, f"step_{step:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            npz = os.path.join(tmp, "arrays.npz")
            np.savez(npz, **arrays)
            # checksum the bytes as they landed on disk, not the
            # in-memory arrays: it must catch whatever happens to the
            # file after this point
            manifest["checksum"] = "sha256:" + _sha256(npz)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)          # atomic commit
            self._gc()

        self.wait()
        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return os.path.join(self.dir, f"step_{step:08d}")

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def verify(self, step: int) -> dict:
        """Integrity-check one step's files; returns the manifest.

        Raises :class:`CheckpointCorruptError` on an unreadable
        manifest, an unsupported schema version, or an ``arrays.npz``
        whose SHA-256 does not match the recorded checksum. Version-1
        checkpoints (written before the integrity header existed) have
        no checksum to verify and pass with a manifest-only check.
        """
        path = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise CheckpointCorruptError(
                f"checkpoint {path}: unreadable manifest ({e}); delete "
                f"the step directory and resume from an earlier step"
            ) from e
        schema = manifest.get("schema", 1)
        if not isinstance(schema, int) or schema > SCHEMA_VERSION:
            raise CheckpointCorruptError(
                f"checkpoint {path}: schema version {schema!r} is newer "
                f"than this code understands (<= {SCHEMA_VERSION}); "
                f"upgrade the code or re-create the checkpoint")
        recorded = manifest.get("checksum")
        if recorded is not None:
            npz = os.path.join(path, "arrays.npz")
            try:
                actual = "sha256:" + _sha256(npz)
            except OSError as e:
                raise CheckpointCorruptError(
                    f"checkpoint {path}: cannot read arrays.npz ({e})"
                ) from e
            if actual != recorded:
                raise CheckpointCorruptError(
                    f"checkpoint {path}: arrays.npz checksum mismatch "
                    f"(manifest {recorded}, file {actual}) — the "
                    f"payload is truncated or bit-flipped; delete the "
                    f"step directory and resume from an earlier step")
        return manifest

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Rebuild `template`'s structure from disk.

        ``shardings`` (same structure, NamedSharding leaves) re-places
        every leaf — pass the *new* mesh's shardings for elastic restore.
        Verifies the step's integrity header first (see ``verify``).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        self.verify(step)
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for kpath, leaf in flat:
            key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in kpath)
            arr = data[key]
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(jnp.asarray(x), s),
                tree, shardings)
        else:
            tree = jax.tree.map(jnp.asarray, tree)
        return tree, step
