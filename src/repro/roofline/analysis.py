"""Roofline terms from a compiled dry-run artifact.

Three terms per (arch, shape, mesh), all in seconds (EXPERIMENTS.md
§Roofline):

  compute    = HLO_FLOPs / peak_FLOP/s          (per-chip program)
  memory     = HLO_bytes / HBM_bw
  collective = collective_bytes / ICI_bw

``cost_analysis()`` supplies FLOPs/bytes of the per-device SPMD program.
Collective bytes are not in cost_analysis: we parse the optimized HLO
and sum collective operand traffic with per-op multipliers (all-reduce
moves ~2x its payload per chip in a ring; gather/scatter/a2a/permute
~1x).
"""
from __future__ import annotations

import re
from typing import Dict

from repro.roofline import hw

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
}
# an instruction line looks like: "  %name = <shape> opcode(...)"
_INSTR_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|\S+)\s+(?P<op>[\w-]+)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in hw.BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * hw.BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-type collective traffic [bytes] from optimized HLO text."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        # opcodes may carry suffixes like all-reduce-start
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):
                base = c
                break
        if base is None:
            continue
        if op.endswith("-done"):        # async pair: count the start only
            continue
        out[base] += _shape_bytes(m.group("shape")) * _COLLECTIVES[base]
        counts[base] += 1
    out["_counts"] = counts  # type: ignore
    return out


def roofline_terms(
    flops: float,
    hbm_bytes: float,
    coll_bytes: float,
    ici_links: int = 4,
) -> Dict[str, float]:
    """All three terms in seconds for the per-chip program."""
    compute = flops / hw.PEAK_FLOPS_BF16
    memory = hbm_bytes / hw.HBM_BW
    collective = coll_bytes / (hw.ICI_BW_PER_LINK * ici_links)
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda kv: kv[1])[0]
    total = max(compute, memory, collective)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "bound_s": total,
    }


def model_flops(param_count: float, active_param_count: float,
                tokens: float, kind: str) -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N D (inference) with N=active."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * active_param_count * tokens
