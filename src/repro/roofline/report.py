"""Build the EXPERIMENTS.md §Roofline table from dry-run JSONs.

  PYTHONPATH=src python -m repro.roofline.report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname: str, mesh: str | None = None, tag: str = ""):
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        r = json.load(open(f))
        if mesh and r.get("mesh") != mesh:
            continue
        if (r.get("tag") or "") != tag:
            continue
        rows.append(r)
    return rows


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def table(rows, include_mesh=False):
    hdr = ["arch", "shape"]
    if include_mesh:
        hdr.append("mesh")
    hdr += ["compute", "memory", "collective", "bound", "useful_flops",
            "status"]
    lines = ["| " + " | ".join(hdr) + " |",
             "|" + "---|" * len(hdr)]
    for r in rows:
        cells = [r["arch"], r["shape"]]
        if include_mesh:
            cells.append(r["mesh"])
        if r["status"] == "ok":
            t = r["roofline"]
            cells += [fmt_s(t["compute_s"]), fmt_s(t["memory_s"]),
                      fmt_s(t["collective_s"]),
                      f"**{t['dominant']}**",
                      f"{t['useful_flops_ratio']*100:.0f}%", "ok"]
        elif r["status"] == "skipped":
            cells += ["—"] * 5 + [f"skip: {r['reason'][:40]}"]
        else:
            cells += ["—"] * 5 + ["ERROR"]
        lines.append("| " + " | ".join(cells) + " |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    rows = load(args.dir, args.mesh, args.tag)
    print(table(rows))
    ok = [r for r in rows if r["status"] == "ok"]
    print(f"\n{len(ok)} ok / {len(rows)} cells on {args.mesh}")
    # candidates for hillclimbing
    worst = sorted(ok, key=lambda r: r["roofline"]["useful_flops_ratio"])[:5]
    print("\nworst useful-FLOPs ratio:")
    for r in worst:
        print(f"  {r['arch']} x {r['shape']}: "
              f"{r['roofline']['useful_flops_ratio']*100:.1f}% "
              f"(bound: {r['roofline']['dominant']})")
    coll = sorted(ok, key=lambda r: -(r["roofline"]["collective_s"] /
                                      max(r["roofline"]["bound_s"], 1e-30)))[:5]
    print("\nmost collective-bound:")
    for r in coll:
        t = r["roofline"]
        print(f"  {r['arch']} x {r['shape']}: coll {fmt_s(t['collective_s'])}"
              f" vs bound {fmt_s(t['bound_s'])}")


if __name__ == "__main__":
    main()
