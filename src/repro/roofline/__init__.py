"""Roofline analysis from compiled dry-run artifacts."""
from repro.roofline.analysis import (
    collective_bytes,
    model_flops,
    roofline_terms,
)
from repro.roofline import hw

__all__ = ["collective_bytes", "roofline_terms", "model_flops", "hw"]
