"""Logical-axis partitioning: one rule table maps model-level axis names
to mesh axes; models annotate activations/params with logical names only.

Mesh axes (launch/mesh.py):
  single pod : ("data", "model")            16 x 16 = 256 chips
  multi-pod  : ("pod", "data", "model")     2 x 16 x 16 = 512 chips

Default rules:
  batch    -> ("pod", "data")   data parallel across pods and the data axis
  seq      -> None              (context parallelism opts in via "ctx")
  ctx      -> ("data",)         long-context KV sequence sharding
  heads    -> ("model",)        tensor parallel attention
  kv_heads -> ("model",)
  ffn      -> ("model",)        tensor parallel MLP
  experts  -> ("model",)        expert parallel MoE
  vocab    -> ("model",)        sharded embedding / unembedding
  embed    -> None | ("data",)  FSDP: weight d_model rows over data axis
  layers, conv, state, head_dim -> None

Rules are a context-managed global so model code stays mesh-agnostic;
axes not present in the active mesh are dropped automatically.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.interpreters import pxla
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Tuple[str, ...]]

DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": (),
    "ctx": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "experts": ("model",),
    "expert_cap": (),
    "vocab": ("model",),
    "embed": (),
    "embed_fsdp": ("data",),
    "layers": (),
    "groups": (),
    "head_dim": (),
    "state": (),
    "conv": (),
    # player axis K *inside* one continuum simulation: bandit state
    # (rings, weights, KDE stats) shards over the dedicated mesh axis
    # of make_continuum_mesh; meshes without it replicate (dropped)
    "players": ("players",),
    "arms": (),
    # evaluation-grid scenario/seed axis: lanes are independent
    # simulations, embarrassingly sharded over the flat grid mesh
    # (launch/mesh.py::make_grid_mesh) or the data axis of the 2-D
    # continuum mesh (launch/mesh.py::make_continuum_mesh)
    "grid": ("data",),
    # decode KV-cache batch axis: defaults to the activation batch
    # sharding; the hybrid decode layout re-points it at the TP axis so
    # attention runs against an immovable cache (see launch/dryrun.py)
    "kv_batch": ("pod", "data"),
}

_rules: Rules = dict(DEFAULT_RULES)


def set_rules(rules: Rules) -> None:
    global _rules
    _rules = dict(DEFAULT_RULES)
    _rules.update(rules)


def get_rules() -> Rules:
    return dict(_rules)


@contextlib.contextmanager
def rule_overrides(**overrides: Tuple[str, ...]):
    global _rules
    old = dict(_rules)
    _rules.update(overrides)
    try:
        yield
    finally:
        _rules = old


def current_mesh() -> Optional[Mesh]:
    try:
        from jax._src import mesh as _mesh_lib
        m = _mesh_lib.thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover - fallback for older jax
        m = pxla.thread_resources.env.physical_mesh
    return None if m.empty else m


def logical_to_spec(
    logical: Sequence[Optional[str]],
    mesh: Optional[Mesh] = None,
) -> P:
    """Map logical axis names to a PartitionSpec for the active mesh.

    Logical axes resolve through the rule table; mesh axes that do not
    exist in the active mesh are dropped (so the same model code lowers
    on the 2-axis single-pod and 3-axis multi-pod meshes).
    """
    mesh = mesh or current_mesh()
    names = set(mesh.axis_names) if mesh is not None else set()
    spec = []
    used: set = set()        # a mesh axis may appear once per spec;
    for ax in logical:       # first logical occurrence wins
        if ax is None:
            spec.append(None)
            continue
        target = _rules.get(ax, ())
        kept = tuple(a for a in target if a in names and a not in used)
        used.update(kept)
        if not kept:
            spec.append(None)
        elif len(kept) == 1:
            spec.append(kept[0])
        else:
            spec.append(kept)
    return P(*spec)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op off-mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(logical, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def is_axes_leaf(x) -> bool:
    """A logical-axes leaf: plain tuple of axis names / None (not a
    NamedTuple, not a tuple of sub-trees)."""
    return (type(x) is tuple
            and all(isinstance(t, (str, type(None))) for t in x))


def tree_shardings(logical_tree, mesh: Optional[Mesh] = None):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    mesh = mesh or current_mesh()
    if mesh is None:
        raise ValueError("no active mesh")
    return jax.tree.map(
        lambda ax: NamedSharding(mesh, logical_to_spec(ax, mesh)),
        logical_tree,
        is_leaf=is_axes_leaf,
    )
