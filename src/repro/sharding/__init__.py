"""Distribution layer: logical-axis partitioning rules."""
from repro.sharding.partitioning import (
    DEFAULT_RULES,
    constrain,
    current_mesh,
    get_rules,
    logical_to_spec,
    rule_overrides,
    set_rules,
    tree_shardings,
)

__all__ = [
    "DEFAULT_RULES", "constrain", "current_mesh", "get_rules",
    "logical_to_spec", "rule_overrides", "set_rules", "tree_shardings",
]
