"""Flight recorder: a bounded ring buffer of structured events inside
the simulator's scan carry.

The streaming engine answers "what happened at step 37,412?" only by
re-running with ``trace=True`` at O(T) memory. The recorder closes that
gap: a fixed-capacity ring of (step, kind, entity, value) records rides
in the ``lax.scan`` carry and captures the *interesting* steps as they
happen — breaker trips/resets, retry exhaustions, control-plane actions
(scale up/down, shed, migrate), scenario event marks, per-player
QoS-miss spikes — at O(capacity) memory for any horizon.

Design contract (the same bar the resilience and control layers set):

* **Statically gated**: ``SimConfig.recorder=None`` (or a disabled
  :class:`RecorderConfig`) adds a ``None`` — an empty pytree — to the
  carry, so the disabled program compiles to byte-identical HLO versus
  the pre-recorder engine (tests/test_obs_recorder.py).
* **Shards on the players axis with no new in-loop collectives**: every
  per-player lane (trips, resets, retry exhaustions, sheds, spikes) is
  computed from shard-local data and lands in the shard's own ring;
  fleet-level lanes (scenario marks, control actions) are recorded only
  by the shard holding global player 0, so a sharded run records each
  fleet event exactly once. Rings concatenate across shards on readout
  (``recorder_events`` merges them into one (step, shard, seq)-ordered
  list). Sharded and unsharded runs record the same event *set*
  whenever neither ring wrapped (each shard retains its own most-recent
  ``capacity`` events, so retention under wraparound is per-shard).
* **Composes with chunking/checkpoint/resume**: the ring is ordinary
  carry state — it streams through ``run_sim_stream(chunk_steps=...)``
  and rides the checkpoint bit-exactly.

Append mechanics: each step contributes a fixed set of *candidate*
lanes (static shapes — jit-friendly); the valid candidates get ring
positions via an exclusive cumulative sum off the monotone ``ptr``,
candidates that would be overwritten within the same step's batch are
masked out (so scatter indices stay distinct and the write is
deterministic), invalid lanes scatter to an out-of-bounds sentinel slot
dropped by ``mode="drop"``. ``ptr`` counts every event ever appended;
``ptr - capacity`` (clamped at 0) is the number overwritten.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# Event kinds. Stable small integers: they appear in exported traces
# and run artifacts, so renumbering is a schema change.
KIND_MARK = 0             # scenario event onset (entity = mark index)
KIND_SCALE_UP = 1         # controller spawned standby capacity
KIND_SCALE_DOWN = 2       # controller killed standby capacity
KIND_MIGRATE = 3          # cross-region capacity migration fired
KIND_BREAKER_TRIP = 4     # entity = player id; value = arms newly open
KIND_BREAKER_RESET = 5    # entity = player id; value = arms newly closed
KIND_RETRY_EXHAUSTED = 6  # entity = player id; value = dropped requests
KIND_SHED = 7             # entity = player id; value = requests shed
KIND_QOS_SPIKE = 8        # entity = player id; value = step miss fraction

KIND_NAMES = {
    KIND_MARK: "scenario_mark",
    KIND_SCALE_UP: "scale_up",
    KIND_SCALE_DOWN: "scale_down",
    KIND_MIGRATE: "migrate",
    KIND_BREAKER_TRIP: "breaker_trip",
    KIND_BREAKER_RESET: "breaker_reset",
    KIND_RETRY_EXHAUSTED: "retry_exhausted",
    KIND_SHED: "shed",
    KIND_QOS_SPIKE: "qos_spike",
}

FLEET = -1    # entity sentinel for fleet-level events


def kind_name(kind: int) -> str:
    return KIND_NAMES.get(int(kind), f"kind_{int(kind)}")


@dataclass(frozen=True)
class RecorderConfig:
    """Static recorder knobs (a ``SimConfig`` field, like the control
    plane's config). ``capacity`` is the ring size per program instance
    (per shard under player sharding); ``capacity <= 0`` disables the
    recorder entirely — the carry gains a ``None`` and the program is
    byte-identical to the pre-recorder engine. ``qos_spike`` is the
    per-player per-step QoS-miss fraction at or above which a
    ``KIND_QOS_SPIKE`` event is recorded (players with no issued
    requests that step never spike)."""
    capacity: int = 1024
    qos_spike: float = 0.5

    @property
    def enabled(self) -> bool:
        return self.capacity > 0


def recorder_enabled(cfg) -> bool:
    """Static gate ``simulator.build_sim_parts`` keys the recorder path
    on (``cfg`` is a ``SimConfig``)."""
    rec = getattr(cfg, "recorder", None)
    return rec is not None and rec.enabled


class RecorderState(NamedTuple):
    """The in-carry ring. ``ptr`` is shaped (1,), not scalar, so the
    player-sharded out-spec concatenates per-shard pointers into a (D,)
    vector the readout can split the rings back with. ``prev_open`` is
    the previous step's breaker-open snapshot ((K, M) when breakers are
    on, (0, 0) otherwise) — trip/reset events are its step-over-step
    transitions, which also catches cooldown expiries between steps."""
    step: jax.Array       # (cap,) i32 global step index of each record
    kind: jax.Array       # (cap,) i32 event kind (KIND_*)
    entity: jax.Array     # (cap,) i32 global player id / mark idx / -1
    value: jax.Array      # (cap,) f32 event magnitude
    ptr: jax.Array        # (1,) i32 total events ever appended
    prev_open: jax.Array  # (K, M) bool breaker-open snapshot


def recorder_init(rcfg: RecorderConfig, K: int, M: int,
                  track_breakers: bool) -> RecorderState:
    cap = int(rcfg.capacity)
    return RecorderState(
        step=jnp.full((cap,), -1, jnp.int32),
        kind=jnp.full((cap,), -1, jnp.int32),
        entity=jnp.full((cap,), FLEET, jnp.int32),
        value=jnp.zeros((cap,), jnp.float32),
        ptr=jnp.zeros((1,), jnp.int32),
        prev_open=jnp.zeros((K, M) if track_breakers else (0, 0), bool),
    )


def _append(rec: RecorderState, t_idx, kinds, entities, values,
            valid) -> RecorderState:
    """Append the valid candidates in lane order. One cumsum + four
    scatters; indices are distinct by construction (candidates whose
    position would be overwritten later in the same batch are masked to
    the drop sentinel), so the write order is immaterial and the result
    deterministic."""
    cap = rec.step.shape[0]
    vi = valid.astype(jnp.int32)
    n_new = vi.sum()
    base = rec.ptr[0]
    pos = base + jnp.cumsum(vi) - vi                     # (E,) exclusive
    keep = valid & (pos >= base + n_new - cap)
    slot = jnp.where(keep, pos % cap, cap)               # OOB -> dropped
    return rec._replace(
        step=rec.step.at[slot].set(t_idx.astype(jnp.int32), mode="drop"),
        kind=rec.kind.at[slot].set(kinds, mode="drop"),
        entity=rec.entity.at[slot].set(entities, mode="drop"),
        value=rec.value.at[slot].set(values, mode="drop"),
        ptr=rec.ptr + n_new)


def record_step(
    rcfg: RecorderConfig,
    rec: RecorderState,
    *,
    t_idx: jax.Array,          # scalar i32 global step index
    pids: jax.Array,           # (K,) global player ids of this shard
    marks: jax.Array,          # (E,) scenario event-onset steps, -1 pad
    miss_k: jax.Array,         # (K,) f32 QoS misses this step
    iss_k: jax.Array,          # (K,) f32 issued requests this step
    retry_drop_k: jax.Array | None = None,   # (K,) f32 deadline drops
    shed_k: jax.Array | None = None,         # (K,) f32 admission sheds
    open_now: jax.Array | None = None,       # (K, M) bool breaker open
    ctl_deltas: tuple | None = None,         # (up, down, mig) f32 diffs
) -> RecorderState:
    """Build this step's candidate-event lanes and append the valid
    ones. Lane order is fixed (marks, control actions, then the
    per-player lanes), so records within a step have a deterministic
    sequence. Fleet-level lanes are gated on ``pids[0] == 0`` — the
    shard holding global player 0 — so a player-sharded run records
    each fleet event exactly once, from shard-local data, with no
    collective."""
    owner = pids[0] == 0
    kinds, ents, vals, valids = [], [], [], []

    def lane(kind, ent, val, valid):
        kinds.append(jnp.full(ent.shape, kind, jnp.int32))
        ents.append(ent.astype(jnp.int32))
        vals.append(val.astype(jnp.float32))
        valids.append(valid)

    # scenario event onsets (entity = mark index, value = onset step)
    E = marks.shape[0]
    lane(KIND_MARK, jnp.arange(E, dtype=jnp.int32),
         marks.astype(jnp.float32),
         (marks >= 0) & (marks == t_idx) & owner)

    # control-plane actions, detected as counter diffs across this
    # step's control_actuate call (post-warmup, like the counters)
    if ctl_deltas is not None:
        up_d, down_d, mig_d = ctl_deltas
        fleet = jnp.full((1,), FLEET, jnp.int32)
        lane(KIND_SCALE_UP, fleet, up_d[None], (up_d > 0)[None] & owner)
        lane(KIND_SCALE_DOWN, fleet, down_d[None],
             (down_d > 0)[None] & owner)
        lane(KIND_MIGRATE, fleet, mig_d[None], (mig_d > 0)[None] & owner)

    # breaker transitions: step-over-step open-mask diff per player
    if open_now is not None:
        trips = (open_now & ~rec.prev_open).sum(-1).astype(jnp.float32)
        resets = (rec.prev_open & ~open_now).sum(-1).astype(jnp.float32)
        lane(KIND_BREAKER_TRIP, pids, trips, trips > 0)
        lane(KIND_BREAKER_RESET, pids, resets, resets > 0)
        rec = rec._replace(prev_open=open_now)

    if retry_drop_k is not None:
        lane(KIND_RETRY_EXHAUSTED, pids, retry_drop_k, retry_drop_k > 0)
    if shed_k is not None:
        lane(KIND_SHED, pids, shed_k, shed_k > 0)

    # per-player QoS-miss spike: miss fraction of this step's issued
    # requests at or above the configured threshold
    frac = miss_k / jnp.maximum(iss_k, 1.0)
    lane(KIND_QOS_SPIKE, pids, frac,
         (iss_k > 0) & (frac >= rcfg.qos_spike))

    return _append(rec, t_idx, jnp.concatenate(kinds),
                   jnp.concatenate(ents), jnp.concatenate(vals),
                   jnp.concatenate(valids))


# ---------------------------------------------------------------------------
# Host-side readout.
# ---------------------------------------------------------------------------

class Event(NamedTuple):
    """One decoded record. ``shard`` is the ring it came from (0 for
    unsharded runs), ``seq`` its per-shard append sequence number."""
    step: int
    kind: int
    entity: int
    value: float
    shard: int
    seq: int

    @property
    def kind_str(self) -> str:
        return kind_name(self.kind)


def _rings(rec) -> tuple[np.ndarray, ...]:
    """Split the (possibly shard-concatenated) ring arrays back into
    (D, cap) views: D = ptr.size, cap = step.size // D."""
    ptr = np.asarray(rec.ptr).reshape(-1).astype(np.int64)
    D = max(ptr.shape[0], 1)
    step = np.asarray(rec.step).reshape(D, -1)
    kind = np.asarray(rec.kind).reshape(D, -1)
    entity = np.asarray(rec.entity).reshape(D, -1)
    value = np.asarray(rec.value).reshape(D, -1)
    return ptr, step, kind, entity, value


def recorder_events(rec) -> list[Event]:
    """Decode a ``RecorderState`` into chronologically ordered events.

    Handles unsharded ((cap,) arrays, (1,) ptr) and player-sharded
    ((D·cap,) concatenated arrays, (D,) ptr) states transparently.
    Events are sorted by (step, shard, seq) — within one shard the ring
    order is exact append order; across shards same-step events
    interleave by shard id."""
    ptr, step, kind, entity, value = _rings(rec)
    cap = step.shape[1]
    out = []
    for d in range(len(ptr)):
        p = int(ptr[d])
        for s in range(max(0, p - cap), p):
            sl = s % cap
            out.append(Event(int(step[d, sl]), int(kind[d, sl]),
                             int(entity[d, sl]), float(value[d, sl]),
                             d, s))
    out.sort(key=lambda e: (e.step, e.shard, e.seq))
    return out


def events_appended(rec) -> int:
    """Total events ever appended (across shards), wrapped or not."""
    ptr = np.asarray(rec.ptr).reshape(-1).astype(np.int64)
    return int(ptr.sum())


def events_dropped(rec) -> int:
    """Events overwritten by ring wraparound (across shards)."""
    ptr, step, *_ = _rings(rec)
    cap = step.shape[1]
    return int(np.maximum(ptr - cap, 0).sum())
