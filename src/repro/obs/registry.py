"""Unified metrics registry: one named-timeseries schema over the
engine's streaming outputs.

Every benchmark used to assemble its own ad-hoc dict shapes from the
``metrics.*_stream`` readouts. The registry gives them one vocabulary:

* :class:`Metric` — a named scalar (``gauge``/``counter``) or 1-D
  ``series``, with Prometheus-style labels and help text;
* :class:`MetricSet` — an ordered collection with exporters to
  versioned JSON (:meth:`MetricSet.to_json`) and the Prometheus text
  exposition format (:meth:`MetricSet.to_prometheus`; series metrics
  are point-in-time-less and are skipped there);
* :func:`collect_stream` — the canonical ``StreamOutputs -> MetricSet``
  mapping (accumulator summary stats, resilience counters, control
  counters, flight-recorder counts, per-event recovery records);
* :func:`stream_cell` — the shared benchmark-cell builder
  ``scenario_suite``'s three lanes previously hand-rolled; it
  reproduces their exact key set so artifact shapes are preserved.

Import note: this module pulls in ``repro.continuum`` and therefore
must NOT be imported from module scope inside the engine — it is one of
``repro.obs``'s lazy attributes.
"""
from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass, field

import numpy as np

from repro.continuum import metrics as qm
from repro.continuum.control import (control_stats_stream,
                                     per_tenant_qos_spread)
from repro.obs import recorder as obr

REGISTRY_SCHEMA_VERSION = 1

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
_KINDS = ("gauge", "counter", "series")


@dataclass
class Metric:
    """One named measurement. ``value`` is a float for scalar kinds, a
    1-D list/array for ``series``."""
    name: str
    value: object
    kind: str = "gauge"
    help: str = ""
    labels: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown metric kind {self.kind!r}")
        if not _NAME_RE.match(self.name):
            raise ValueError(f"invalid metric name {self.name!r}")
        for k in self.labels:
            if not _LABEL_RE.match(k):
                raise ValueError(f"invalid label name {k!r}")
        if self.kind == "series":
            self.value = [float(v) for v in np.asarray(self.value).ravel()]
        else:
            self.value = float(self.value)


class MetricSet:
    """An ordered, name+label-unique collection of :class:`Metric`."""

    def __init__(self):
        self._metrics: list[Metric] = []
        self._seen: set[tuple] = set()

    def add(self, name: str, value, kind: str = "gauge", help: str = "",
            **labels) -> "MetricSet":
        m = Metric(name, value, kind, help,
                   {k: str(v) for k, v in labels.items()})
        key = (m.name, tuple(sorted(m.labels.items())))
        if key in self._seen:
            raise ValueError(f"duplicate metric {key}")
        self._seen.add(key)
        self._metrics.append(m)
        return self

    def __iter__(self):
        return iter(self._metrics)

    def __len__(self):
        return len(self._metrics)

    def scalars(self) -> dict:
        """{name{labels}: value} for every non-series metric."""
        out = {}
        for m in self._metrics:
            if m.kind == "series":
                continue
            lbl = ",".join(f"{k}={v}" for k, v in sorted(m.labels.items()))
            out[f"{m.name}{{{lbl}}}" if lbl else m.name] = m.value
        return out

    # -- exporters ---------------------------------------------------------

    def to_json(self) -> dict:
        """Versioned JSON document (non-finite values serialized as the
        strings "nan"/"inf"/"-inf" so the output is strict-JSON
        parseable under ``allow_nan=False``)."""
        def one(v):
            if math.isnan(v):
                return "nan"
            if math.isinf(v):
                return "inf" if v > 0 else "-inf"
            return v

        def val(m):
            if m.kind == "series":
                return [one(v) for v in m.value]
            return one(m.value)

        return {
            "schema": "repro.obs.metrics",
            "schema_version": REGISTRY_SCHEMA_VERSION,
            "metrics": [
                {"name": m.name, "kind": m.kind, "value": val(m),
                 **({"help": m.help} if m.help else {}),
                 **({"labels": m.labels} if m.labels else {})}
                for m in self._metrics],
        }

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4). Series
        metrics have no point-in-time value and are skipped; NaN
        scalars export as ``NaN`` (valid Prometheus)."""
        lines = []
        helped = set()
        for m in self._metrics:
            if m.kind == "series":
                continue
            if m.name not in helped:
                if m.help:
                    lines.append(f"# HELP {m.name} {m.help}")
                lines.append(f"# TYPE {m.name} {m.kind}")
                helped.add(m.name)
            lbl = ""
            if m.labels:
                inner = ",".join(
                    f'{k}="{v}"' for k, v in sorted(m.labels.items()))
                lbl = "{" + inner + "}"
            v = "NaN" if math.isnan(m.value) else repr(m.value)
            lines.append(f"{m.name}{lbl} {v}")
        return "\n".join(lines) + "\n"


def metricset_from_json(doc: dict) -> MetricSet:
    """Round-trip loader for :meth:`MetricSet.to_json` documents."""
    if doc.get("schema") != "repro.obs.metrics":
        raise ValueError("not a repro.obs.metrics document")
    if doc.get("schema_version") != REGISTRY_SCHEMA_VERSION:
        raise ValueError(
            f"metrics schema v{doc.get('schema_version')} != "
            f"v{REGISTRY_SCHEMA_VERSION}")
    ms = MetricSet()

    _special = {"nan": float("nan"), "inf": float("inf"),
                "-inf": float("-inf")}

    def unval(v):
        if isinstance(v, list):
            return [_special.get(x, x) if isinstance(x, str) else x
                    for x in v]
        return _special.get(v, v) if isinstance(v, str) else v

    for m in doc["metrics"]:
        ms.add(m["name"], unval(m["value"]), m["kind"],
               m.get("help", ""), **m.get("labels", {}))
    return ms


def validate_metrics_json(doc: dict) -> list[str]:
    """Schema check; returns a list of problems (empty = valid)."""
    try:
        metricset_from_json(doc)
        return []
    except (KeyError, TypeError, ValueError) as e:
        return [str(e)]


def validate_prometheus(text: str) -> list[str]:
    """Line-level check of the text exposition format."""
    problems = []
    sample_re = re.compile(
        r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? "
        r"(NaN|[+-]?(Inf|[0-9.eE+-]+))$")
    for i, line in enumerate(text.splitlines()):
        if not line or line.startswith("# "):
            continue
        if not sample_re.match(line):
            problems.append(f"line {i + 1}: unparseable sample {line!r}")
    if not text.endswith("\n"):
        problems.append("missing trailing newline")
    return problems


# ---------------------------------------------------------------------------
# The canonical StreamOutputs -> MetricSet mapping.
# ---------------------------------------------------------------------------

def collect_stream(outs, *, rho: float, dt: float, bucket_s: float,
                   with_series: bool = True) -> MetricSet:
    """Everything the streaming run can report, under one namespace.

    ``outs`` is a single-run ``StreamOutputs`` (no leading lane axis).
    Adds control-counter metrics when ``outs.ctrl`` is present,
    recorder totals and per-kind event counts when ``outs.rec`` is, and
    one labelled record per scenario event from
    ``metrics.event_recovery``.
    """
    acc = outs.acc
    ms = MetricSet()
    ms.add("repro_qos_satisfaction_pct",
           qm.client_qos_satisfaction_stream(acc, rho),
           help="clients with success ratio >= rho, % (Fig. 5)")
    ms.add("repro_jain_fairness", qm.jain_fairness_stream(acc),
           help="Jain index over per-instance arrival totals (Fig. 7)")
    res = qm.resilience_stats_stream(acc)
    for k, v in res.items():
        kind = "counter" if k in ("requests", "attempts", "timeouts",
                                  "drops") else "gauge"
        ms.add(f"repro_{k}", v, kind,
               help=f"post-warmup {k.replace('_', ' ')}")
    ms.add("repro_steps_measured", float(np.asarray(acc.steps_measured)),
           "counter", help="post-warmup steps accumulated")
    ms.add("repro_regret_total",
           float(np.asarray(acc.regret_k, np.float64).sum()), "counter",
           help="cumulative system regret (post-warmup)")
    rates = qm.request_rate_per_instance_stream(acc, dt)
    for m_i, r in enumerate(rates):
        ms.add("repro_instance_request_rate", float(r),
               help="per-instance arrival rate, req/s", instance=m_i)
    for e, r in enumerate(qm.event_recovery(acc, bucket_s)):
        for k in ("pre", "dip", "dip_s", "steady"):
            ms.add(f"repro_event_{k}",
                   float("nan") if r[k] is None else r[k],
                   help=f"event-recovery {k}", event=e)
        ms.add("repro_event_recovered", 1.0 if r["recovered"] else 0.0,
               help="event QoS recovered inside the observed windows",
               event=e)
        ms.add("repro_event_recovery_s",
               float("nan") if r["recovery_s"] is None else r["recovery_s"],
               help="time-to-recover from the dip, s", event=e)
    if outs.ctrl is not None:
        for k, v in control_stats_stream(acc, outs.ctrl).items():
            ms.add(f"repro_{k}", v,
                   "counter" if k.startswith("ctrl_") and "rate" not in k
                   else "gauge", help=f"control-plane {k}")
    if outs.rec is not None:
        ms.add("repro_recorder_events_appended",
               obr.events_appended(outs.rec), "counter",
               help="flight-recorder events appended (incl. overwritten)")
        ms.add("repro_recorder_events_dropped",
               obr.events_dropped(outs.rec), "counter",
               help="flight-recorder events lost to ring wraparound")
        by_kind: dict = {}
        for ev in obr.recorder_events(outs.rec):
            by_kind[ev.kind_str] = by_kind.get(ev.kind_str, 0) + 1
        for k in sorted(by_kind):
            ms.add("repro_recorder_events_retained", by_kind[k],
                   "counter", help="flight-recorder events in the ring",
                   event_kind=k)
    if with_series and outs.series is not None:
        ms.add("repro_step_succ", np.asarray(outs.series.succ), "series",
               help="per-step fleet QoS successes")
        ms.add("repro_step_issued", np.asarray(outs.series.issued),
               "series", help="per-step fleet issued requests")
        ms.add("repro_step_regret", np.asarray(outs.series.regret),
               "series", help="per-step system regret")
        ms.add("repro_step_attempts", np.asarray(outs.series.attempts),
               "series", help="per-step attempts incl. retries")
    return ms


# ---------------------------------------------------------------------------
# The shared benchmark-cell builder (scenario_suite's three lanes).
# ---------------------------------------------------------------------------

def _finite_dips(recs: list[dict]) -> list[float]:
    return [r["dip"] for r in recs if math.isfinite(r["dip"])]


def recovery_summary(recs: list[dict], *,
                     max_recovery: bool = True) -> dict:
    """worst_dip / unrecovered_events / max_recovery_s from an
    ``event_recovery`` readout — empty dict when there were no events.
    NaN-explicit degenerate events (no data-bearing post buckets) count
    as unrecovered but are excluded from the dip minimum."""
    if not recs:
        return {}
    out = {}
    dips = _finite_dips(recs)
    if dips:
        out["worst_dip"] = min(dips)
    recovered = [r["recovery_s"] for r in recs if r["recovered"]]
    out["unrecovered_events"] = len(recs) - len(recovered)
    if max_recovery and recovered:
        out["max_recovery_s"] = max(recovered)
    return out


def stream_cell(outs, *, rho: float, bucket_s: float,
                jain: bool = False, n_events: bool = False,
                resilience: bool = False, breaker_frac: bool = False,
                tenants: bool = False, drop_rate: bool = False,
                control: bool = False, max_recovery: bool = True) -> dict:
    """One benchmark-cell dict from a single-run ``StreamOutputs``.

    The default cell is ``{"qos_sat_pct": ...}`` plus the
    :func:`recovery_summary` keys when the run had scenario events; the
    keyword switches add the per-lane extras the scenario-suite lanes
    use. Key names and value semantics match the hand-rolled dicts they
    replace on every non-degenerate run, with one intentional
    difference: the NaN-explicit ``event_recovery`` now emits a record
    even for degenerate events whose post-event buckets carry no data,
    so such scenarios gain ``unrecovered_events`` (without
    ``worst_dip``) and larger ``events`` counts where the old code
    emitted no recovery keys at all — degenerate events are *reported*
    rather than silently absent.
    """
    import jax.numpy as jnp
    acc = outs.acc
    recs = qm.event_recovery(acc, bucket_s)
    cell = {"qos_sat_pct": qm.client_qos_satisfaction_stream(acc, rho)}
    if jain:
        cell["jain"] = qm.jain_fairness_stream(acc)
    if tenants:
        spread = per_tenant_qos_spread(acc)
        cell["tenant_qos_spread"] = spread["spread"]
        cell["tenant_qos_min"] = spread["min"]
    if resilience:
        cell.update(qm.resilience_stats_stream(acc))
    elif drop_rate:
        cell["drop_rate"] = qm.resilience_stats_stream(acc)["drop_rate"]
    if breaker_frac:
        cell["breaker_open_frac"] = float(
            jnp.asarray(qm.breaker_open_fraction_stream(acc)).mean())
    if n_events:
        cell["events"] = len(recs)
    cell.update(recovery_summary(recs, max_recovery=max_recovery))
    if control and outs.ctrl is not None:
        cell.update(control_stats_stream(acc, outs.ctrl))
    return cell


# ---------------------------------------------------------------------------
# Multi-tenant collectors (StreamOutputs.acc is a tuple of S accumulators).
# ---------------------------------------------------------------------------

def collect_tenants(outs, *, rho: float) -> MetricSet:
    """Per-tenant QoS + cross-tenant fairness from a tenant run
    (``SimConfig.tenancy`` with S >= 2, where ``outs.acc`` is a tuple
    of per-service accumulators)."""
    accs = outs.acc
    if not isinstance(accs, tuple):
        raise TypeError("collect_tenants expects a tenant run "
                        "(StreamOutputs.acc must be a tuple of per-"
                        "tenant MetricAccumulators)")
    ms = MetricSet()
    sat = qm.tenant_qos_satisfaction_stream(accs, rho)
    qos = qm.tenant_qos_stream(accs)
    served = qm.tenant_served_stream(accs)
    for s in range(len(accs)):
        ms.add("repro_tenant_qos_satisfaction_pct", float(sat[s]),
               help="tenant clients with success ratio >= rho, %",
               tenant=s)
        ms.add("repro_tenant_qos_ratio", float(qos[s]),
               help="tenant overall QoS success ratio", tenant=s)
        ms.add("repro_tenant_requests", float(served[s]), "counter",
               help="tenant post-warmup issued requests", tenant=s)
    for k, v in qm.tenant_fairness_stream(accs).items():
        ms.add(f"repro_fairness_{k}", v,
               help=f"cross-tenant fairness index: {k.replace('_', ' ')}")
    part = qm.tenant_partition_stream(accs)
    ms.add("repro_tenant_partition_index", part["partition_index"],
           help="1 - mean pairwise routing overlap between tenants")
    ms.add("repro_tenant_mean_overlap", part["mean_overlap"],
           help="mean pairwise min-overlap of tenant routing profiles")
    return ms


def tenant_cell(outs, *, rho: float) -> dict:
    """One multi-tenant benchmark-cell dict: per-tenant QoS columns
    (index = tenant id) plus the cross-tenant fairness and
    self-partitioning scalars — the ``multi_tenant`` lane's schema."""
    accs = outs.acc
    cell = {
        "tenant_qos_sat_pct": [
            float(v) for v in qm.tenant_qos_satisfaction_stream(accs, rho)],
        "tenant_qos_ratio": [float(v) for v in qm.tenant_qos_stream(accs)],
        "tenant_requests": [float(v) for v in qm.tenant_served_stream(accs)],
    }
    cell.update(qm.tenant_fairness_stream(accs))
    cell.update(qm.tenant_partition_stream(accs))
    return cell


def write_metrics(ms: MetricSet, json_path=None, prom_path=None) -> None:
    if json_path is not None:
        with open(json_path, "w") as f:
            json.dump(ms.to_json(), f, indent=1, allow_nan=False)
    if prom_path is not None:
        with open(prom_path, "w") as f:
            f.write(ms.to_prometheus())
