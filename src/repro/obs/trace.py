"""Chrome trace-event export: recorder events and host-side timings as
a Perfetto-loadable timeline.

Two time domains share one trace, on separate process lanes:

* **Simulated time** — flight-recorder events at ``ts = step * dt`` (in
  trace microseconds). One process (``pid``) per player shard, one
  thread (``tid``) per event-kind class, so Perfetto renders lanes like
  ``shard 0 / breaker_trip``. Fleet-level events (scenario marks,
  control actions) get their own ``fleet`` process.
* **Host wall time** — :class:`HostTimeline` spans (chunk dispatch,
  compile, export, …) as duration events on a ``host`` process,
  re-based so the first span starts at t=0.

The emitted document is the standard JSON object format
(``{"traceEvents": [...]}``) with ``ph`` "i" instant events for
recorder records, "X" complete events for host spans and "M" metadata
events naming the lanes — loads in ``ui.perfetto.dev`` and
``chrome://tracing`` as-is. :func:`validate_chrome_trace` is the schema
gate CI runs on every exported trace.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager

from repro.obs import recorder as obr

TRACE_SCHEMA_VERSION = 1

# fixed pid blocks so lanes sort stably in the UI
_PID_FLEET = 1
_PID_SHARD0 = 10
_PID_HOST = 1000

_FLEET_KINDS = frozenset({obr.KIND_MARK, obr.KIND_SCALE_UP,
                          obr.KIND_SCALE_DOWN, obr.KIND_MIGRATE})


def _meta(pid, tid, key, name) -> dict:
    return {"ph": "M", "pid": pid, "tid": tid, "name": key,
            "args": {"name": name}}


def recorder_trace_events(rec_or_events, dt: float) -> list[dict]:
    """Lower recorder events to Chrome instant events (+ lane
    metadata). Accepts a ``RecorderState`` or a pre-decoded event
    list."""
    events = (rec_or_events if isinstance(rec_or_events, list)
              else obr.recorder_events(rec_or_events))
    out = []
    lanes_named: set[tuple] = set()

    def name_lane(pid, tid, pname, tname):
        if (pid, None) not in lanes_named:
            out.append(_meta(pid, 0, "process_name", pname))
            lanes_named.add((pid, None))
        if (pid, tid) not in lanes_named:
            out.append(_meta(pid, tid, "thread_name", tname))
            lanes_named.add((pid, tid))

    for ev in events:
        fleet = ev.kind in _FLEET_KINDS
        pid = _PID_FLEET if fleet else _PID_SHARD0 + ev.shard
        tid = ev.kind + 1
        name_lane(pid, tid,
                  "fleet" if fleet else f"player shard {ev.shard}",
                  ev.kind_str)
        out.append({
            "ph": "i", "s": "g" if fleet else "t",
            "pid": pid, "tid": tid,
            "name": ev.kind_str,
            "cat": "recorder",
            "ts": ev.step * dt * 1e6,       # simulated µs
            "args": {"step": ev.step, "entity": ev.entity,
                     "value": ev.value, "seq": ev.seq},
        })
    return out


class HostTimeline:
    """Wall-clock span collector for the host side of a run (compile,
    chunk dispatch, checkpoint write, export). Spans become ph="X"
    complete events on the ``host`` process lane, re-based to the
    timeline's construction time."""

    def __init__(self):
        self._t0 = time.perf_counter()
        self.events: list[dict] = [
            _meta(_PID_HOST, 0, "process_name", "host"),
            _meta(_PID_HOST, 1, "thread_name", "driver"),
        ]

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    @contextmanager
    def span(self, name: str, cat: str = "host", **args):
        t0 = self._now_us()
        try:
            yield
        finally:
            self.events.append({
                "ph": "X", "pid": _PID_HOST, "tid": 1, "name": name,
                "cat": cat, "ts": t0, "dur": self._now_us() - t0,
                **({"args": args} if args else {})})

    def instant(self, name: str, cat: str = "host", **args):
        self.events.append({
            "ph": "i", "s": "t", "pid": _PID_HOST, "tid": 1,
            "name": name, "cat": cat, "ts": self._now_us(),
            **({"args": args} if args else {})})


def chrome_trace(*event_lists, meta: dict | None = None) -> dict:
    """Assemble event lists into one trace document."""
    events = [e for lst in event_lists for e in lst]
    doc = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"schema": "repro.obs.trace",
                      "schema_version": TRACE_SCHEMA_VERSION},
    }
    if meta:
        doc["otherData"].update(meta)
    return doc


def write_chrome_trace(path, *event_lists, meta: dict | None = None) -> dict:
    doc = chrome_trace(*event_lists, meta=meta)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


_PHASES = {"i", "X", "M", "B", "E", "b", "e", "n", "C"}


def validate_chrome_trace(doc) -> list[str]:
    """Schema check; returns a list of problems (empty = valid)."""
    problems = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["not a JSON-object-format trace (no traceEvents)"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return ["traceEvents is not a list"]
    for i, e in enumerate(evs):
        if not isinstance(e, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = e.get("ph")
        if ph not in _PHASES:
            problems.append(f"event {i}: bad ph {ph!r}")
            continue
        if "name" not in e or "pid" not in e:
            problems.append(f"event {i}: missing name/pid")
        if ph in ("i", "X") and not isinstance(e.get("ts"), (int, float)):
            problems.append(f"event {i}: missing numeric ts")
        if ph == "X" and not isinstance(e.get("dur"), (int, float)):
            problems.append(f"event {i}: X event missing dur")
        if ph == "i" and e.get("s") not in (None, "g", "p", "t"):
            problems.append(f"event {i}: bad instant scope {e.get('s')!r}")
    return problems
