"""Provenance stamping for result artifacts.

Every ``results/benchmarks/*.json`` payload gains a ``provenance`` key
recording what produced it: artifact schema version, git sha, jax
version, backend, device count and a content hash of the benchmark's
``SimConfig``. The stamp is additive — keys are merged into the
existing payload dict, never wrapped around it — so artifact readers
written before the stamp keep working unchanged.

:func:`validate_artifact`/:func:`validate_all` are the round-trip gate:
they re-parse an artifact and check its provenance block's presence and
field types, and CI runs them over the whole results directory.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import subprocess

ARTIFACT_SCHEMA_VERSION = 1

_FIELDS = {
    "schema_version": int,
    "git_sha": str,
    "jax_version": str,
    "backend": str,
    "device_count": int,
    "config_hash": str,
}


def git_sha(repo_dir: str | None = None) -> str:
    """HEAD sha of the repo containing this file (or ``repo_dir``);
    "unknown" outside a git checkout."""
    if repo_dir is None:
        repo_dir = os.path.dirname(os.path.abspath(__file__))
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=repo_dir,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _canonical(obj):
    """A deterministically-serializable view of configs: dataclasses
    and NamedTuples flatten to sorted dicts, everything else reprs."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _canonical(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if hasattr(obj, "_asdict"):                       # NamedTuple
        return {k: _canonical(v) for k, v in obj._asdict().items()}
    if isinstance(obj, dict):
        return {str(k): _canonical(v) for k, v in sorted(obj.items())}
    if isinstance(obj, (list, tuple)):
        return [_canonical(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def config_hash(config) -> str:
    """Stable short hash of a config object (``SimConfig``,
    ``ControlConfig``, plain dict, ...)."""
    blob = json.dumps(_canonical(config), sort_keys=True,
                      separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def provenance(config=None, extra: dict | None = None) -> dict:
    """The provenance block for the current process."""
    import jax
    block = {
        "schema_version": ARTIFACT_SCHEMA_VERSION,
        "git_sha": git_sha(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "config_hash": config_hash(config) if config is not None else "",
    }
    if extra:
        block.update(extra)
    return block


def stamp(payload: dict, config=None, extra: dict | None = None) -> dict:
    """Merge the provenance block into an artifact payload, in place.

    Additive by design: readers indexing the payload's existing keys
    never see a changed shape."""
    payload["provenance"] = provenance(config, extra)
    return payload


def validate_artifact(path_or_doc) -> list[str]:
    """Round-trip one artifact; returns a list of problems."""
    problems = []
    if isinstance(path_or_doc, (str, os.PathLike)):
        try:
            with open(path_or_doc) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            return [f"unreadable: {e}"]
    else:
        doc = path_or_doc
    if not isinstance(doc, dict):
        return ["artifact is not a JSON object"]
    prov = doc.get("provenance")
    if not isinstance(prov, dict):
        return ["missing provenance block"]
    for k, typ in _FIELDS.items():
        if k not in prov:
            problems.append(f"provenance missing {k!r}")
        elif not isinstance(prov[k], typ):
            problems.append(
                f"provenance {k!r} is {type(prov[k]).__name__}, "
                f"want {typ.__name__}")
    sv = prov.get("schema_version")
    if isinstance(sv, int) and sv > ARTIFACT_SCHEMA_VERSION:
        problems.append(f"schema_version {sv} is from the future")
    return problems


def validate_all(results_dir: str) -> dict:
    """{filename: [problems]} over every ``*.json`` in a directory;
    empty lists mean valid."""
    out = {}
    for name in sorted(os.listdir(results_dir)):
        if name.endswith(".json"):
            out[name] = validate_artifact(os.path.join(results_dir, name))
    return out
