"""`python -m repro.obs report <run_dir>` — render a run directory.

Pure host-side formatting over :mod:`repro.obs.runlog` output: the
provenance header, headline metrics, per-event recovery windows, the
flight-recorder timeline, and whatever per-device memory / overhead
figures the producing benchmark put in the manifest.
"""
from __future__ import annotations

from repro.obs import runlog as obl


def _fmt_val(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _timeline(events: list[dict], limit: int = 60) -> list[str]:
    lines = []
    shown = events if len(events) <= limit else events[:limit]
    for e in shown:
        ent = "fleet" if e["entity"] == -1 else f"player {e['entity']}"
        lines.append(f"  t={e['t']:9.2f}s  step {e['step']:>8}  "
                     f"{e['kind']:<16} {ent:<12} value={e['value']:g}")
    if len(events) > limit:
        lines.append(f"  ... {len(events) - limit} more "
                     f"(see events.json)")
    return lines


def render(run_dir: str) -> str:
    loaded = obl.load_run(run_dir)
    if not loaded:
        return f"{run_dir}: not a run directory (no manifest/metrics)"
    out = [f"run: {run_dir}"]

    man = loaded.get("manifest", {})
    prov = man.get("provenance", {})
    if prov:
        out.append(
            f"  provenance: git {prov.get('git_sha', '?')[:12]}  "
            f"jax {prov.get('jax_version', '?')}  "
            f"{prov.get('backend', '?')}×{prov.get('device_count', '?')}  "
            f"config {prov.get('config_hash') or '-'}")
    for key in ("label", "overhead_ratio", "recorder_us_per_step",
                "baseline_us_per_step", "peak_memory_mb"):
        if key in man:
            out.append(f"  {key}: {_fmt_val(man[key])}")

    ms = loaded.get("metrics")
    if ms is not None:
        out.append("metrics:")
        ev_lines = []
        for name, val in ms.scalars().items():
            line = f"  {name} = {_fmt_val(val)}"
            (ev_lines if name.startswith("repro_event_") else out).append(
                line)
        if ev_lines:
            out.append("recovery windows:")
            out.extend(ev_lines)

    ev = loaded.get("events")
    if ev is not None:
        out.append(
            f"flight recorder: {len(ev['events'])} events retained "
            f"({ev['appended']} appended, {ev['dropped']} lost to "
            f"wraparound)")
        out.extend(_timeline(ev["events"]))

    tr = loaded.get("trace")
    if tr is not None:
        n = len(tr.get("traceEvents", []))
        out.append(f"trace.json: {n} trace events "
                   f"(load in ui.perfetto.dev or chrome://tracing)")

    probs = obl.validate_run(run_dir)
    bad = {f: p for f, p in probs.items() if p}
    out.append("schema validation: "
               + ("OK" if not bad else f"PROBLEMS {bad}"))
    return "\n".join(out)
