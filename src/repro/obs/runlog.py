"""Run-directory writer/loader: one directory per observed run.

Layout (all optional except the manifest):

```
<run_dir>/
  manifest.json   # provenance + config echo + index of present files
  metrics.json    # MetricSet, versioned JSON (registry schema)
  metrics.prom    # same scalars, Prometheus text exposition format
  trace.json      # Chrome trace-event JSON (Perfetto-loadable)
  events.json     # decoded flight-recorder events, one record each
```

``python -m repro.obs report <run_dir>`` renders any such directory;
the obs CI lane validates every file against its schema and uploads the
manifest as a workflow artifact.
"""
from __future__ import annotations

import json
import os

from repro.obs import provenance as obp
from repro.obs import recorder as obr
from repro.obs import registry as obreg
from repro.obs import trace as obt

MANIFEST_SCHEMA_VERSION = 1


def write_run(
    run_dir: str,
    *,
    metrics: "obreg.MetricSet | None" = None,
    rec=None,
    dt: float | None = None,
    timeline: "obt.HostTimeline | None" = None,
    config=None,
    manifest_extra: dict | None = None,
) -> dict:
    """Write a run directory; returns the manifest dict.

    ``rec`` is a ``RecorderState`` (its events become ``events.json``
    and, together with ``timeline``'s host spans, ``trace.json``;
    ``dt`` is required to place them on the simulated-time axis).
    """
    os.makedirs(run_dir, exist_ok=True)
    files = {}

    if metrics is not None:
        obreg.write_metrics(metrics,
                            json_path=os.path.join(run_dir, "metrics.json"),
                            prom_path=os.path.join(run_dir, "metrics.prom"))
        files["metrics"] = "metrics.json"
        files["prometheus"] = "metrics.prom"

    rec_events = []
    if rec is not None:
        if dt is None:
            raise ValueError("rec needs dt to place events in time")
        rec_events = obr.recorder_events(rec)
        with open(os.path.join(run_dir, "events.json"), "w") as f:
            json.dump({
                "schema": "repro.obs.events",
                "schema_version": MANIFEST_SCHEMA_VERSION,
                "appended": obr.events_appended(rec),
                "dropped": obr.events_dropped(rec),
                "events": [{"step": e.step, "t": e.step * dt,
                            "kind": e.kind_str, "entity": e.entity,
                            "value": e.value, "shard": e.shard,
                            "seq": e.seq} for e in rec_events],
            }, f, indent=1)
        files["events"] = "events.json"

    if rec is not None or timeline is not None:
        lists = []
        if rec is not None:
            lists.append(obt.recorder_trace_events(rec_events, dt))
        if timeline is not None:
            lists.append(timeline.events)
        obt.write_chrome_trace(os.path.join(run_dir, "trace.json"), *lists)
        files["trace"] = "trace.json"

    manifest = {
        "schema": "repro.obs.manifest",
        "schema_version": MANIFEST_SCHEMA_VERSION,
        "provenance": obp.provenance(config),
        "files": files,
    }
    if manifest_extra:
        manifest.update(manifest_extra)
    with open(os.path.join(run_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def load_run(run_dir: str) -> dict:
    """Load whatever a run directory holds. Returns a dict with any of
    ``manifest`` / ``metrics`` (MetricSet) / ``metrics_doc`` /
    ``events`` / ``trace`` present."""
    out: dict = {}
    mpath = os.path.join(run_dir, "manifest.json")
    if os.path.exists(mpath):
        with open(mpath) as f:
            out["manifest"] = json.load(f)
    jpath = os.path.join(run_dir, "metrics.json")
    if os.path.exists(jpath):
        with open(jpath) as f:
            out["metrics_doc"] = json.load(f)
        try:
            out["metrics"] = obreg.metricset_from_json(out["metrics_doc"])
        except (KeyError, TypeError, ValueError):
            pass    # corrupt/mismatched doc: validate_run reports it
    epath = os.path.join(run_dir, "events.json")
    if os.path.exists(epath):
        with open(epath) as f:
            out["events"] = json.load(f)
    tpath = os.path.join(run_dir, "trace.json")
    if os.path.exists(tpath):
        with open(tpath) as f:
            out["trace"] = json.load(f)
    ppath = os.path.join(run_dir, "metrics.prom")
    if os.path.exists(ppath):
        with open(ppath) as f:
            out["prometheus"] = f.read()
    return out


def validate_run(run_dir: str) -> dict:
    """{file: [problems]} for every schema-bearing file present."""
    out: dict = {}
    loaded = load_run(run_dir)
    if "manifest" not in loaded:
        return {"manifest.json": ["missing"]}
    man = loaded["manifest"]
    probs = []
    if man.get("schema") != "repro.obs.manifest":
        probs.append("bad manifest schema tag")
    probs += obp.validate_artifact(man)
    out["manifest.json"] = probs
    if "metrics_doc" in loaded:
        out["metrics.json"] = obreg.validate_metrics_json(
            loaded["metrics_doc"])
    if "prometheus" in loaded:
        out["metrics.prom"] = obreg.validate_prometheus(
            loaded["prometheus"])
    if "trace" in loaded:
        out["trace.json"] = obt.validate_chrome_trace(loaded["trace"])
    if "events" in loaded:
        ev = loaded["events"]
        out["events.json"] = (
            [] if isinstance(ev.get("events"), list) else ["no events list"])
    return out
