"""``python -m repro.obs`` — the observability CLI.

* ``report <run_dir>`` renders a run directory written by
  :func:`repro.obs.runlog.write_run` (timeline, recovery windows,
  provenance, overhead figures).
* ``smoke [--out DIR]`` is the CI `obs` lane body: drive the
  ``retry_storm`` scenario with the flight recorder on, export the
  full run directory, validate every file against its schema, replay
  the recorded scenario-mark timeline against the accumulator's
  event windows, and (when the committed ``bandit_scale`` artifact is
  present) assert the recorded K=1000×M=50 recorder overhead anchor is
  under 1.10×.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time


def _cmd_report(args) -> int:
    from repro.obs import report
    print(report.render(args.run_dir))
    return 0


def _cmd_smoke(args) -> int:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.continuum import (compile_scenario, event_recovery,
                                 get_library, make_topology)
    from repro.continuum.simulator import SimConfig, run_sim_stream
    from repro.obs import (KIND_MARK, RecorderConfig, recorder_events,
                           registry, runlog, trace)

    K, M = 30, 10
    warm = 50
    base = dict(horizon=args.horizon, tau=0.150, attempt_timeout=0.090,
                max_retries=2, retry_backoff=0.002, breaker_threshold=5,
                breaker_cooldown=1.0)
    cfg_off = SimConfig(**base)
    cfg_on = SimConfig(**base, recorder=RecorderConfig(capacity=4096))

    topo = make_topology(jax.random.PRNGKey(1), K, M)
    rtt = topo.lb_instance_rtt()
    lib = get_library(cfg_on.horizon, K, M)
    drv = compile_scenario(lib["retry_storm"], cfg_on,
                           jax.random.PRNGKey(7))
    key = jax.random.PRNGKey(11)
    timeline = trace.HostTimeline()

    def run(cfg, label):
        with timeline.span(f"run:{label}", "dispatch"):
            out = run_sim_stream("qedgeproxy", rtt, cfg, key,
                                 drivers=drv, warmup_steps=warm)
            jax.block_until_ready(out.acc)
        return out

    run(cfg_off, "warmup_off")              # compile + warm
    t0 = time.perf_counter()
    out_off = run(cfg_off, "recorder_off")
    off_s = time.perf_counter() - t0
    run(cfg_on, "warmup_on")
    t0 = time.perf_counter()
    out_on = run(cfg_on, "recorder_on")
    on_s = time.perf_counter() - t0
    steps = cfg_on.num_steps
    ratio = on_s / max(off_s, 1e-9)
    print(f"smoke cell K={K} M={M} T={steps}: recorder off "
          f"{off_s * 1e6 / steps:.1f} us/step, on "
          f"{on_s * 1e6 / steps:.1f} us/step "
          f"(ratio {ratio:.3f}, informational — the gate is the "
          f"committed anchor)")

    # recorder on/off parity on every accumulator field
    mismatch = [
        f for f in out_off.acc._fields
        if not np.array_equal(np.asarray(getattr(out_off.acc, f)),
                              np.asarray(getattr(out_on.acc, f)))]
    if mismatch:
        print(f"FAIL: recorder changed accumulator fields {mismatch}")
        return 1

    # replay check: the recorded scenario-mark timeline must match the
    # accumulator's event windows exactly — same count, same steps
    evs = recorder_events(out_on.rec)
    mark_evs = sorted(e.step for e in evs if e.kind == KIND_MARK)
    marks = sorted(int(m) for m in np.asarray(drv.marks) if m >= 0)
    recs = event_recovery(out_on.acc, cfg_on.ev_bucket)
    if mark_evs != marks or len(recs) != len(marks):
        print(f"FAIL: recorded marks {mark_evs} vs scenario marks "
              f"{marks} vs {len(recs)} event windows")
        return 1
    print(f"replay: {len(mark_evs)} recorded marks == scenario marks "
          f"== {len(recs)} accumulator event windows")

    # export + validate the run directory
    out_dir = args.out or tempfile.mkdtemp(prefix="obs_smoke_")
    ms = registry.collect_stream(out_on, rho=cfg_on.rho, dt=cfg_on.dt,
                                 bucket_s=cfg_on.ev_bucket)
    with timeline.span("export", "host"):
        runlog.write_run(
            out_dir, metrics=ms, rec=out_on.rec, dt=cfg_on.dt,
            timeline=timeline, config=cfg_on,
            manifest_extra={
                "label": "obs_smoke:retry_storm",
                "overhead_ratio": ratio,
                "recorder_us_per_step": on_s * 1e6 / steps,
                "baseline_us_per_step": off_s * 1e6 / steps,
            })
    problems = {f: p for f, p in runlog.validate_run(out_dir).items() if p}
    if problems:
        print(f"FAIL: schema validation {problems}")
        return 1
    print(f"run dir {out_dir}: all schemas valid")

    # trace replay: the exported Chrome trace must carry the same mark
    # timeline at the right simulated timestamps
    with open(os.path.join(out_dir, "trace.json")) as f:
        doc = json.load(f)
    tr_marks = sorted(
        round(e["ts"] / (cfg_on.dt * 1e6))
        for e in doc["traceEvents"]
        if e.get("ph") == "i" and e.get("name") == "scenario_mark")
    if tr_marks != marks:
        print(f"FAIL: trace marks {tr_marks} != scenario marks {marks}")
        return 1
    print(f"trace replay: {len(tr_marks)} scenario_mark instants at the "
          f"exact mark steps")

    # the committed benchmark anchor is the actual overhead gate: the
    # K=1000xM=50 scale cell is the hard bound (small cells are noisy
    # on loaded CI runners and print informationally)
    if os.path.exists(args.anchor):
        with open(args.anchor) as f:
            anchor = json.load(f)
        cells = {k: v for k, v in anchor.items()
                 if isinstance(v, dict) and "recorder_overhead" in v}
        if not cells:
            print(f"FAIL: {args.anchor} has no recorder_overhead cells")
            return 1
        if "K1000_M50" not in cells:
            print(f"FAIL: {args.anchor} lacks the K1000_M50 anchor cell")
            return 1
        for name, cell in sorted(cells.items()):
            ov = cell["recorder_overhead"]
            gated = name == "K1000_M50"
            print(f"anchor {name}: recorder_overhead {ov:.3f}"
                  + ("" if gated else " (informational)"))
            if gated and ov >= 1.10:
                print(f"FAIL: {name} recorder overhead {ov:.3f} >= 1.10")
                return 1
    else:
        print(f"anchor {args.anchor} not present; skipping overhead gate")
    print("obs smoke OK")
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = p.add_subparsers(dest="cmd", required=True)
    pr = sub.add_parser("report", help="render a run directory")
    pr.add_argument("run_dir")
    pr.set_defaults(fn=_cmd_report)
    ps = sub.add_parser("smoke", help="CI obs lane: record, export, "
                                      "validate, replay")
    ps.add_argument("--out", default=None, help="run directory to write")
    ps.add_argument("--horizon", type=float, default=60.0)
    ps.add_argument("--anchor",
                    default="results/benchmarks/bandit_scale.json",
                    help="bandit_scale artifact with the overhead anchor")
    ps.set_defaults(fn=_cmd_smoke)
    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
