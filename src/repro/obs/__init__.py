"""Observability subsystem: flight recorder, unified metrics registry,
trace export, provenance.

Only :mod:`repro.obs.recorder` is imported eagerly — it is
dependency-free (jax + numpy) and is what the simulator needs at import
time. Everything else (registry, trace, provenance, runlog, report)
imports ``repro.continuum`` and is exposed lazily to avoid a circular
import: ``repro.continuum.simulator`` imports ``repro.obs`` while the
``repro.continuum`` package is itself mid-import.
"""
from __future__ import annotations

import importlib

from repro.obs import recorder
from repro.obs.recorder import (  # noqa: F401  (re-exported surface)
    FLEET,
    KIND_BREAKER_RESET,
    KIND_BREAKER_TRIP,
    KIND_MARK,
    KIND_MIGRATE,
    KIND_QOS_SPIKE,
    KIND_RETRY_EXHAUSTED,
    KIND_SCALE_DOWN,
    KIND_SCALE_UP,
    KIND_SHED,
    KIND_NAMES,
    Event,
    RecorderConfig,
    RecorderState,
    events_appended,
    events_dropped,
    kind_name,
    recorder_enabled,
    recorder_events,
    recorder_init,
    record_step,
)

_LAZY = ("registry", "trace", "provenance", "runlog", "report")

__all__ = ["recorder", *_LAZY, "RecorderConfig", "RecorderState", "Event"]


def __getattr__(name: str):
    if name in _LAZY:
        mod = importlib.import_module(f"repro.obs.{name}")
        globals()[name] = mod
        return mod
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
