"""Smooth Weighted Round Robin (paper §V-B, NGINX-style).

Classic SWRR per player: ``cw += w``; pick ``argmax(cw)``; subtract the
total weight from the winner. Smooths bursts compared to independent
sampling. Vectorized over the leading player axis; fully jittable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swrr_select(weights: jax.Array, cw: jax.Array):
    """One SWRR selection per player (row).

    ``weights``: (K, M) nonnegative routing weights (rows may sum to
    anything; zero rows fall back to uniform over nothing => arm 0 with
    a ``valid=False`` flag so callers can drop the request).
    ``cw``: (K, M) SWRR current-weight state.

    Returns ``(choice (K,), new_cw (K, M), valid (K,))``.
    """
    total = weights.sum(-1, keepdims=True)
    valid = (total[..., 0] > 0)
    cw = cw + weights
    # break exact ties deterministically by lower index (argmax does this)
    choice = jnp.argmax(cw, axis=-1)
    onehot = jax.nn.one_hot(choice, weights.shape[-1], dtype=cw.dtype)
    cw = cw - onehot * total
    return choice, cw, valid


def swrr_reset_like(weights: jax.Array) -> jax.Array:
    return jnp.zeros_like(weights)
