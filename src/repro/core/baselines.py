"""Baseline routing strategies the paper compares against (§VII-A5).

* ``proxy_mity_weights`` — Fahs & Pierre [3]: static proximity-biased
  weights; alpha=1.0 routes everything to the nearest instance, alpha=0.9
  keeps 10% spread across the rest. Weights are fixed at init (the paper
  observes they "are fixed at initialization and never updated").
* ``DecSarsa*`` — Mattia & Beraldi [7] adapted per §VII-A5: each LB is a
  differential-SARSA agent; state combines a recent-latency bucket with
  a proximity bucket, actions are instances, reward is the deadline
  indicator. Per-request eps-greedy updates.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import prand


# ---------------------------------------------------------------------------
# proxy-mity
# ---------------------------------------------------------------------------

def proxy_mity_weights(
    rtt: jax.Array,           # (K, M)
    alpha: float,
    active: jax.Array | None = None,  # (M,) bool
) -> jax.Array:
    """alpha * onehot(nearest active) + (1-alpha) uniform over active."""
    K, M = rtt.shape
    if active is None:
        active = jnp.ones((M,), bool)
    big = jnp.finfo(rtt.dtype).max
    masked = jnp.where(active[None, :], rtt, big)
    nearest = jnp.argmin(masked, axis=-1)
    onehot = jax.nn.one_hot(nearest, M, dtype=rtt.dtype)
    actf = active.astype(rtt.dtype)[None, :]
    uni = actf / jnp.maximum(actf.sum(-1, keepdims=True), 1.0)
    w = alpha * onehot + (1.0 - alpha) * uni
    return w / jnp.maximum(w.sum(-1, keepdims=True), 1e-30)


# ---------------------------------------------------------------------------
# Dec-SARSA
# ---------------------------------------------------------------------------

N_LOAD_BUCKETS = 4


class DecSarsaParams(NamedTuple):
    beta: float = 0.1          # Q learning rate
    alpha_r: float = 0.01      # average-reward step (differential SARSA)
    eps: float = 0.10          # eps-greedy exploration
    eps_decay: float = 0.999   # per-request decay
    eps_min: float = 0.01
    tau: float = 0.080
    # latency bucket edges relative to tau (state discretization)
    b1: float = 0.25
    b2: float = 0.6
    b3: float = 1.0


class DecSarsaState(NamedTuple):
    q: jax.Array           # (K, S, M) action values
    rbar: jax.Array        # (K,) average reward estimate
    prev_s: jax.Array      # (K,) i32 previous state id
    prev_a: jax.Array      # (K,) i32 previous action
    has_prev: jax.Array    # (K,) bool
    last_lat: jax.Array    # (K,) recent-latency EMA (state feature)
    eps: jax.Array         # (K,) current exploration rate


def decsarsa_init(
    num_players: int, num_arms: int, rtt: jax.Array, params: DecSarsaParams,
    rtt_max: jax.Array | None = None,
) -> DecSarsaState:
    K, M = num_players, num_arms
    # optimistic init biased by proximity so early behaviour matches [7].
    # rtt.max() reduces over ALL players — the one cross-player term in
    # this baseline — so a player-sharded simulator must pass the
    # global max (pmax over its shards) as ``rtt_max``.
    if rtt_max is None:
        rtt_max = rtt.max()
    q0 = 0.5 + 0.5 * (1.0 - rtt / jnp.maximum(rtt_max, 1e-9))
    q = jnp.broadcast_to(q0[:, None, :], (K, N_LOAD_BUCKETS, M)).astype(jnp.float32)
    return DecSarsaState(
        q=jnp.array(q),
        rbar=jnp.zeros((K,), jnp.float32),
        prev_s=jnp.zeros((K,), jnp.int32),
        prev_a=jnp.zeros((K,), jnp.int32),
        has_prev=jnp.zeros((K,), bool),
        last_lat=jnp.zeros((K,), jnp.float32),
        eps=jnp.full((K,), params.eps, jnp.float32),
    )


def _bucket(lat: jax.Array, p: DecSarsaParams) -> jax.Array:
    rel = lat / p.tau
    return (
        (rel > p.b1).astype(jnp.int32)
        + (rel > p.b2).astype(jnp.int32)
        + (rel > p.b3).astype(jnp.int32)
    )


def decsarsa_select(
    state: DecSarsaState,
    params: DecSarsaParams,
    active: jax.Array,      # (M,) bool
    key: jax.Array,
    pids: jax.Array | None = None,   # (K,) i32 global player ids
):
    """eps-greedy action per player from the current state bucket.

    With ``pids``, the exploration draws are keyed per global player id
    (``prand``) so a player-sharded simulation reproduces the unsharded
    stream; without it, one bulk draw (standalone callers).
    """
    K, S, M = state.q.shape
    s = _bucket(state.last_lat, params)                     # (K,)
    qs = state.q[jnp.arange(K), s]                          # (K, M)
    neg = jnp.finfo(qs.dtype).min
    qs = jnp.where(active[None, :], qs, neg)
    greedy = jnp.argmax(qs, axis=-1)
    ku, kc = jax.random.split(key)
    # uniform random over active arms
    if pids is not None:
        gumbel = prand.player_gumbel(kc, pids, M)
        u = prand.player_uniform(ku, pids)
    else:
        gumbel = jax.random.gumbel(kc, (K, M))
        u = jax.random.uniform(ku, (K,))
    rand = jnp.argmax(jnp.where(active[None, :], gumbel, neg), axis=-1)
    explore = u < state.eps
    choice = jnp.where(explore, rand, greedy)
    return choice, s


def decsarsa_update(
    state: DecSarsaState,
    params: DecSarsaParams,
    s: jax.Array,          # (K,) state used for the action just taken
    a: jax.Array,          # (K,) action just taken
    reward: jax.Array,     # (K,) binary deadline indicator
    latency: jax.Array,    # (K,) observed latency (next-state feature)
    mask: jax.Array,       # (K,) request actually issued
) -> DecSarsaState:
    """Differential SARSA: Q[s,a] += beta (r - rbar + Q[s',a'] - Q[s,a])."""
    K, S, M = state.q.shape
    kidx = jnp.arange(K)
    last_lat = jnp.where(
        mask, 0.7 * state.last_lat + 0.3 * latency, state.last_lat)
    s_next = _bucket(last_lat, params)
    # on-policy next action = greedy wrt current Q (eps part is noise term)
    a_next = jnp.argmax(state.q[kidx, s_next], axis=-1)

    q_sa = state.q[kidx, s, a]
    q_next = state.q[kidx, s_next, a_next]
    td = reward - state.rbar + q_next - q_sa
    upd = jnp.where(mask & state.has_prev | mask, params.beta * td, 0.0)
    q = state.q.at[kidx, s, a].add(upd)
    rbar = jnp.where(mask, state.rbar + params.alpha_r * (reward - state.rbar),
                     state.rbar)
    eps = jnp.where(mask,
                    jnp.maximum(state.eps * params.eps_decay, params.eps_min),
                    state.eps)
    return state._replace(
        q=q, rbar=rbar, prev_s=s_next, prev_a=a_next,
        has_prev=state.has_prev | mask, last_lat=last_lat, eps=eps,
    )
