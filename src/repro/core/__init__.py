"""QEdgeProxy core: decentralized MP-MAB QoS-aware load balancing.

The paper's primary contribution (§IV–V) as a composable JAX module:
KDE-based QoS estimation, QoS-pool maintenance, adaptive epsilon
exploration, SWRR routing, cooldown, and instance add/remove handling —
all vectorized over (players, arms) and jittable.
"""
from repro.core.bandit import (
    BanditParams,
    BanditState,
    init_state,
    instance_added,
    instance_removed,
    maintenance,
    maintenance_subset,
    record,
    record_batch,
    record_feedback,
    record_rings_batch,
    select,
    sync_active,
)
from repro.core.baselines import (
    DecSarsaParams,
    DecSarsaState,
    decsarsa_init,
    decsarsa_select,
    decsarsa_update,
    proxy_mity_weights,
)
from repro.core.kde import (
    empirical_success_prob,
    kde_success_prob,
    masked_quantile,
    silverman_bandwidth,
)
from repro.core.oracle import oracle_weights, step_regret, variation_budget
from repro.core.swrr import swrr_select

__all__ = [
    "BanditParams", "BanditState", "init_state", "select", "record",
    "record_batch", "record_feedback", "record_rings_batch",
    "maintenance", "maintenance_subset",
    "instance_added", "instance_removed", "sync_active",
    "DecSarsaParams", "DecSarsaState", "decsarsa_init", "decsarsa_select",
    "decsarsa_update", "proxy_mity_weights",
    "kde_success_prob", "empirical_success_prob", "silverman_bandwidth",
    "masked_quantile",
    "oracle_weights", "step_regret", "variation_budget",
    "swrr_select",
]
