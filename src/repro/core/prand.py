"""Player-indexed randomness: shard-invariant per-player draws.

The simulator shards the player axis K across devices (`shard_map`
over the ``players`` mesh axis, see ``repro/continuum/simulator.py``).
For a sharded run to reproduce the unsharded run bit-for-bit, every
per-player random quantity must depend only on the *global* player id
and the step key — never on how the (K,) axis happens to be laid out
over devices. Drawing ``normal(key, (K,))`` breaks that: a shard
holding players [lo, hi) cannot cheaply reproduce rows [lo, hi) of the
full-width draw.

These helpers therefore key every draw as ``fold_in(key, player_id)``
and draw per player. A shard folds in its own global ids and gets
exactly the numbers the unsharded engine computes for those players;
work is O(K_local), not O(K_global). Each player's draw is an
independent threefry stream, so the statistics match the bulk draws
these replace.

``pids`` is always the (K_local,) i32 array of *global* player ids
(``arange(K)`` in an unsharded run).
"""
from __future__ import annotations

import jax


def player_normal(key: jax.Array, pids: jax.Array) -> jax.Array:
    """(K,) standard normal, one per player id."""
    return jax.vmap(
        lambda i: jax.random.normal(jax.random.fold_in(key, i)))(pids)


def player_uniform(key: jax.Array, pids: jax.Array) -> jax.Array:
    """(K,) uniform [0, 1), one per player id."""
    return jax.vmap(
        lambda i: jax.random.uniform(jax.random.fold_in(key, i)))(pids)


def player_uniform_row(key: jax.Array, pids: jax.Array, n: int) -> jax.Array:
    """(K, n) uniform [0, 1), one row per player id."""
    return jax.vmap(
        lambda i: jax.random.uniform(jax.random.fold_in(key, i), (n,)))(pids)


def player_gumbel(key: jax.Array, pids: jax.Array, n: int) -> jax.Array:
    """(K, n) standard Gumbel, one row per player id (for per-player
    categorical sampling via argmax(logits + gumbel))."""
    return jax.vmap(
        lambda i: jax.random.gumbel(jax.random.fold_in(key, i), (n,)))(pids)
