"""Oracle weights and regret (paper §IV-D, Eq. 8–9).

The oracle knows the true per-arm success probabilities mu_{k,m}(t)
(available from the simulator's internal latency model). The oracle
weight vector w*_k(t) = argmax_w sum_m w_m mu_{k,m}(t) is a one-hot on
the best arm (the objective is linear in w), so per-step regret is
``max_m mu - <w, mu>``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def oracle_weights(mu: jax.Array, active: jax.Array | None = None) -> jax.Array:
    """(K, M) one-hot on argmax_m mu_{k,m} over active arms."""
    if active is not None:
        mu = jnp.where(active[None, :], mu, -jnp.inf)
    best = jnp.argmax(mu, axis=-1)
    return jax.nn.one_hot(best, mu.shape[-1], dtype=jnp.float32)


def step_regret(
    weights: jax.Array,     # (K, M) learned weights
    mu: jax.Array,          # (K, M) true success probabilities
    active: jax.Array | None = None,
) -> jax.Array:
    """Per-player instantaneous regret (Eq. 8 summand). Returns (K,)."""
    mu_eff = jnp.where(active[None, :], mu, -jnp.inf) if active is not None else mu
    best = jnp.max(mu_eff, axis=-1)
    got = (weights * jnp.where(jnp.isfinite(mu_eff), mu, 0.0)).sum(-1)
    return jnp.maximum(best - got, 0.0)


def variation_budget(mu_t: jax.Array) -> jax.Array:
    """V_k(T) (Definition 1) from a (T, K, M) trajectory of true mus."""
    d = jnp.abs(mu_t[1:] - mu_t[:-1])      # (T-1, K, M)
    return d.max(-1).sum(0)                 # (K,)
