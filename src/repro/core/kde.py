"""Kernel Density Estimation of QoS success probabilities (paper §V-A).

The estimate the paper needs is not the density itself but the CDF at
the latency threshold::

    mu_hat = P(l <= tau) = (1/n) * sum_i Phi((tau - l_i) / h)

with a Gaussian kernel (Phi = standard normal CDF) over the samples in
the sliding window. Bandwidth defaults to Silverman's rule computed on
the masked window. ``empirical`` mode (plain fraction below tau) is the
prior-work [2] estimator, kept for ablation.

The pure-jnp implementation here is the oracle for the Pallas kernel in
``repro/kernels/kde.py`` (see ``repro/kernels/ops.py`` for dispatch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_INV_SQRT2 = 0.7071067811865476


def normal_cdf(x: jax.Array) -> jax.Array:
    return 0.5 * (1.0 + jax.lax.erf(x * _INV_SQRT2))


def silverman_bandwidth(
    lat: jax.Array, mask: jax.Array, min_bandwidth: float = 1e-4
) -> jax.Array:
    """Per-row Silverman bandwidth h = 1.06 * sigma * n^(-1/5).

    ``lat``: (..., R) samples, ``mask``: (..., R) validity. Rows with
    fewer than 2 samples fall back to ``min_bandwidth``.
    """
    m = mask.astype(lat.dtype)
    n = jnp.maximum(m.sum(-1), 1.0)
    mean = (lat * m).sum(-1) / n
    var = ((lat - mean[..., None]) ** 2 * m).sum(-1) / n
    sigma = jnp.sqrt(jnp.maximum(var, 0.0))
    h = 1.06 * sigma * n ** (-0.2)
    return jnp.maximum(h, min_bandwidth)


def kde_success_prob(
    lat: jax.Array,
    mask: jax.Array,
    tau: float | jax.Array,
    bandwidth: jax.Array | None = None,
    min_bandwidth: float = 1e-4,
) -> jax.Array:
    """P(latency <= tau) via Gaussian-kernel CDF over masked samples.

    ``lat``: (..., R) latency window, ``mask``: (..., R) validity.
    Returns (...,) in [0, 1]. Rows with zero valid samples return 0
    (callers decide the unseen-instance policy — see bandit.py).
    """
    if bandwidth is None:
        bandwidth = silverman_bandwidth(lat, mask, min_bandwidth)
    m = mask.astype(lat.dtype)
    n = m.sum(-1)
    z = (tau - lat) / bandwidth[..., None]
    contrib = (normal_cdf(z) * m).sum(-1)
    return jnp.where(n > 0, contrib / jnp.maximum(n, 1.0), 0.0)


def empirical_success_prob(
    lat: jax.Array, mask: jax.Array, tau: float | jax.Array
) -> jax.Array:
    """Plain windowed success fraction (the [2] baseline estimator)."""
    m = mask.astype(lat.dtype)
    n = m.sum(-1)
    succ = ((lat <= tau) * m).sum(-1)
    return jnp.where(n > 0, succ / jnp.maximum(n, 1.0), 0.0)


def masked_quantile(x: jax.Array, mask: jax.Array, q: float) -> jax.Array:
    """q-quantile over masked samples along the last axis.

    Invalid entries are pushed to +inf before sorting; the quantile index
    is scaled by the per-row valid count. Rows with no samples -> +inf.
    """
    big = jnp.asarray(jnp.finfo(x.dtype).max, x.dtype)
    xs = jnp.sort(jnp.where(mask, x, big), axis=-1)
    n = mask.sum(-1)
    idx = jnp.clip((q * (n - 1)).astype(jnp.int32), 0, x.shape[-1] - 1)
    val = jnp.take_along_axis(xs, idx[..., None], axis=-1)[..., 0]
    return jnp.where(n > 0, val, big)
