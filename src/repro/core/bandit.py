"""QEdgeProxy MP-MAB core (paper §IV–V, Algorithms 1–4).

Fully decentralized: the state factorizes over players (load balancers);
no cross-player terms exist anywhere in the update. We still *store* all
K players in one pytree of (K, M, ...) arrays so the whole fleet updates
in a single fused XLA program — the decentralization claim is preserved
because every reduction is over the trailing (per-player) axes only.

State layout (R = ring-buffer capacity per (player, arm)):
  lat_buf (K,M,R) f32   end-to-end latency samples
  ts_buf  (K,M,R) f32   sample timestamps (-inf = empty)
  ptr     (K,M)   i32   ring pointers
  mu_hat  (K,M)   f32   KDE success-probability estimates
  weights (K,M)   f32   routing weights (rows sum to 1 over the pool)
  cw      (K,M)   f32   SWRR current weights
  eps     (K,)    f32   exploration budget epsilon(t)
  err     (K,M)   i32   consecutive-error counters (Alg 2 line 5)
  cooldown_until (K,M) f32
  active  (M,)    bool  instance liveness (Alg 3/4)
  in_pool (K,M)   bool  QoS pool membership Q_k(t)
  explore (K,M)   bool  exploration-pool membership X_k(t)
  r_buf   (K,Rq)  f32   own-request reward ring (QoS_a degradation test)
  rts_buf (K,Rq)  f32   reward timestamps
  rptr    (K,)    i32
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kde as kde_mod
from repro.core import prand
from repro.core.swrr import swrr_select
from repro.kernels import ops as kernel_ops


class BanditParams(NamedTuple):
    """QoS requirements + algorithm hyperparameters (paper Table I/II)."""

    tau: float = 0.080          # latency threshold [s]
    rho: float = 0.9            # required success ratio
    window: float = 10.0        # sliding window W [s]
    gamma: float = 0.01         # epsilon-decay factor
    eta: float = 0.01           # score smoothing floor
    err_thresh: int = 5         # E_t
    cooldown: float = 10.0      # Delta_cd [s]
    decay_mode: int = 0         # 0: eps*=(1-gamma)  1: eps*=gamma (literal)
    kde_mode: int = 0           # 0: KDE  1: empirical fraction (ablation)
    min_bandwidth: float = 1e-4
    reset_hysteresis: float = 0.0   # QoS_a drop needed to trigger reset
    ucb_coef: float = 0.0       # >0 enables beyond-paper UCB bonus
    unseen_mu: float = -1.0     # <0 => rho - 1e-6 (paper Alg 3 semantics)
    weight_ema: float = 0.0     # beyond-paper: damp weight jumps
    # w <- (1-ema)*w_new + ema*w_old. The paper's undamped update can
    # oscillate near capacity (herd -> overload -> flee); see EXPERIMENTS.md.


class BanditState(NamedTuple):
    lat_buf: jax.Array
    ts_buf: jax.Array
    ptr: jax.Array
    mu_hat: jax.Array
    weights: jax.Array
    cw: jax.Array
    eps: jax.Array
    err: jax.Array
    cooldown_until: jax.Array
    active: jax.Array
    in_pool: jax.Array
    explore: jax.Array
    r_buf: jax.Array
    rts_buf: jax.Array
    rptr: jax.Array

    @property
    def num_players(self) -> int:
        return self.lat_buf.shape[0]

    @property
    def num_arms(self) -> int:
        return self.lat_buf.shape[1]


NEG_INF = -1e30


def init_state(
    num_players: int,
    num_arms: int,
    params: BanditParams,
    ring: int = 64,
    reward_ring: int = 512,
    active: jax.Array | None = None,
    key: jax.Array | None = None,
    pids: jax.Array | None = None,
) -> BanditState:
    """Paper Alg 1 lines 1–5: uniform weights, eps = 1 - rho.

    ``key`` randomizes the SWRR phase. Real deployments are
    asynchronous (each LB ticks on its own clock); in a bulk-synchronous
    simulation identical weights + identical phase would make every
    player pick the *same* arm each round (herding the paper's testbed
    cannot exhibit). A random phase offset restores the async behaviour.

    ``pids`` (optional, (K,) i32 *global* player ids) switches the
    phase draw to player-indexed keying (``prand``), which is what lets
    a player-sharded simulation initialize its shard of the state
    bit-identically to the unsharded engine. The simulator always
    passes it; standalone callers may omit it and get one bulk draw.
    """
    K, M, R = num_players, num_arms, ring
    if active is None:
        active = jnp.ones((M,), dtype=bool)
    act = active.astype(jnp.float32)[None, :] * jnp.ones((K, 1), jnp.float32)
    n_act = jnp.maximum(act.sum(-1, keepdims=True), 1.0)
    if key is None:
        cw0 = jnp.zeros((K, M), jnp.float32)
    elif pids is not None:
        cw0 = prand.player_uniform_row(key, pids, M) / jnp.maximum(n_act, 1.0)
    else:
        cw0 = jax.random.uniform(key, (K, M)) / jnp.maximum(n_act, 1.0)
    return BanditState(
        lat_buf=jnp.zeros((K, M, R), jnp.float32),
        ts_buf=jnp.full((K, M, R), NEG_INF, jnp.float32),
        ptr=jnp.zeros((K, M), jnp.int32),
        mu_hat=jnp.zeros((K, M), jnp.float32),
        weights=act / n_act,
        cw=cw0,
        eps=jnp.full((K,), 1.0 - params.rho, jnp.float32),
        err=jnp.zeros((K, M), jnp.int32),
        cooldown_until=jnp.full((K, M), NEG_INF, jnp.float32),
        active=active,
        in_pool=active[None, :] * jnp.ones((K, M), bool),
        explore=active[None, :] * jnp.ones((K, M), bool),
        r_buf=jnp.zeros((K, reward_ring), jnp.float32),
        rts_buf=jnp.full((K, reward_ring), NEG_INF, jnp.float32),
        rptr=jnp.zeros((K,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Request path (Alg 2): select via SWRR, record feedback, cooldown.
# ---------------------------------------------------------------------------

def select(state: BanditState):
    """SWRR selection for every player. Returns (choice, state, valid)."""
    choice, cw, valid = swrr_select(state.weights, state.cw)
    return choice, state._replace(cw=cw), valid


def _record_control(
    state: BanditState,
    params: BanditParams,
    choice: jax.Array,      # (K,)
    reward: jax.Array,      # (K,) 1/0 QoS outcome
    t: jax.Array,
    mask: jax.Array,        # (K,)
) -> BanditState:
    """Error/cooldown/pool/weight part of one record round (Alg 2
    lines 5-9). Touches only (K, M) fields — the (K, M, R) ring writes
    live in ``record`` / ``record_batch``."""
    K, M, R = state.lat_buf.shape
    kidx = jnp.arange(K)
    old_err = state.err[kidx, choice]
    new_err = jnp.where(reward > 0, 0, old_err + 1).astype(jnp.int32)
    trip = mask & (new_err >= params.err_thresh)
    err = state.err.at[kidx, choice].set(
        jnp.where(mask, jnp.where(trip, 0, new_err), old_err))
    cd = state.cooldown_until.at[kidx, choice].set(
        jnp.where(trip, t + params.cooldown, state.cooldown_until[kidx, choice]))

    # remove tripped arms from the pool immediately and renormalize
    tripped_onehot = jax.nn.one_hot(choice, M, dtype=bool) & trip[:, None]
    in_pool = state.in_pool & ~tripped_onehot
    w = jnp.where(tripped_onehot, 0.0, state.weights)
    wsum = w.sum(-1, keepdims=True)
    # if the tripped arm carried all the weight, spread uniformly over
    # the arms still in the pool (or all active arms as a last resort)
    remaining = in_pool & state.active[None, :]
    rem_any = remaining.any(-1, keepdims=True)
    fallback = jnp.where(
        rem_any, remaining,
        state.active[None, :] & ~tripped_onehot).astype(jnp.float32)
    fallback = fallback / jnp.maximum(fallback.sum(-1, keepdims=True), 1.0)
    weights = jnp.where(wsum > 0, w / jnp.maximum(wsum, 1e-30), fallback)

    # a cooled-down arm must not keep winning on stale SWRR credit
    cw = jnp.where(tripped_onehot, 0.0, state.cw)

    return state._replace(
        err=err, cooldown_until=cd, in_pool=in_pool, weights=weights, cw=cw,
    )


def record(
    state: BanditState,
    params: BanditParams,
    choice: jax.Array,      # (K,) selected arm per player
    latency: jax.Array,     # (K,) end-to-end latency [s]
    t: jax.Array,           # scalar time [s]
    mask: jax.Array,        # (K,) bool: player actually issued a request
) -> BanditState:
    """Record one request per player (Alg 2 lines 4–9), vectorized.

    Masked players leave the state untouched. Repeated calls handle
    multiple requests per player per step; ``record_batch`` ingests all
    of them in one fused scatter instead.
    """
    K, M, R = state.lat_buf.shape
    kidx = jnp.arange(K)
    reward = (latency <= params.tau).astype(jnp.float32)

    # --- latency ring write at (k, choice[k], ptr) ---
    p = state.ptr[kidx, choice]
    lat_buf = state.lat_buf.at[kidx, choice, p].set(
        jnp.where(mask, latency, state.lat_buf[kidx, choice, p]))
    ts_buf = state.ts_buf.at[kidx, choice, p].set(
        jnp.where(mask, t, state.ts_buf[kidx, choice, p]))
    ptr = state.ptr.at[kidx, choice].set(
        jnp.where(mask, (p + 1) % R, p))

    # --- per-player reward ring (for the degradation test) ---
    rp = state.rptr
    r_buf = state.r_buf.at[kidx, rp].set(
        jnp.where(mask, reward, state.r_buf[kidx, rp]))
    rts_buf = state.rts_buf.at[kidx, rp].set(
        jnp.where(mask, t, state.rts_buf[kidx, rp]))
    rptr = jnp.where(mask, (rp + 1) % state.r_buf.shape[1], rp)

    state = state._replace(
        lat_buf=lat_buf, ts_buf=ts_buf, ptr=ptr,
        r_buf=r_buf, rts_buf=rts_buf, rptr=rptr)
    return _record_control(state, params, choice, reward, t, mask)


def record_feedback(
    state: BanditState,
    params: BanditParams,
    choice: jax.Array,      # (K,)
    latency: jax.Array,     # (K,)
    t: jax.Array,
    mask: jax.Array,        # (K,)
) -> BanditState:
    """Control half of one record round: err/cooldown/pool/weights but
    NO ring writes. Pair with ``record_rings_batch`` — the simulator
    interleaves this with selection (so in-step trips still steer the
    remaining rounds, exactly like sequential ``record``) and defers
    the expensive (K, M, R) scatters to one fused write per step."""
    reward = (latency <= params.tau).astype(jnp.float32)
    return _record_control(state, params, choice, reward, t, mask)


def record_rings_batch(
    state: BanditState,
    params: BanditParams,
    choices: jax.Array,     # (K, C) selected arm per player per round
    latencies: jax.Array,   # (K, C) end-to-end latency [s]
    t: jax.Array,           # scalar time [s] (shared by the batch)
    mask: jax.Array,        # (K, C) bool: request actually issued
) -> BanditState:
    """Ring-buffer half of ``record_batch``: all C requests' latency /
    timestamp / reward samples land in one fused scatter.

    Ring slots are computed with per-(player, arm) offset arithmetic —
    the j-th masked write of the batch to arm m lands at
    ``(ptr + j) % R`` — so the C rounds of (K, M, R)/(K, Rq) scatters
    collapse to one. Writes that a later same-slot write of the same
    batch would overwrite are dropped up front, keeping scatter indices
    unique (deterministic). Final buffer contents are bit-for-bit what
    C sequential ``record`` calls leave behind; control flow
    (err/trips/weights) is NOT applied here.
    """
    K, M, R = state.lat_buf.shape
    C = choices.shape[1]
    Rq = state.r_buf.shape[1]
    kk = jnp.broadcast_to(jnp.arange(K)[:, None], (K, C))
    t_arr = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (K, C))
    reward = (latencies <= params.tau).astype(jnp.float32)
    maski = mask.astype(jnp.int32)

    # --- latency rings: offset arithmetic over per-(k, arm) ranks ---
    onehot = (choices[..., None] == jnp.arange(M)) & mask[..., None]
    cnt = jnp.cumsum(onehot.astype(jnp.int32), axis=1)        # inclusive
    total = cnt[:, -1, :]                                     # (K, M)
    rank = jnp.take_along_axis(                               # exclusive
        cnt - onehot.astype(jnp.int32), choices[..., None], axis=2)[..., 0]
    p0 = jnp.take_along_axis(state.ptr, choices, axis=1)      # (K, C)
    slot = (p0 + rank) % R
    tot_c = jnp.take_along_axis(total, choices, axis=1)
    keep = mask & (rank >= tot_c - R)       # drop within-batch overwrites
    slot = jnp.where(keep, slot, R)         # out of bounds => dropped
    lat_buf = state.lat_buf.at[kk, choices, slot].set(latencies, mode="drop")
    ts_buf = state.ts_buf.at[kk, choices, slot].set(t_arr, mode="drop")
    ptr = (state.ptr + total) % R

    # --- per-player reward ring ---
    crank = jnp.cumsum(maski, axis=1) - maski                 # (K, C)
    totk = maski.sum(1)                                       # (K,)
    rslot = (state.rptr[:, None] + crank) % Rq
    keep_r = mask & (crank >= totk[:, None] - Rq)
    rslot = jnp.where(keep_r, rslot, Rq)
    r_buf = state.r_buf.at[kk, rslot].set(reward, mode="drop")
    rts_buf = state.rts_buf.at[kk, rslot].set(t_arr, mode="drop")
    rptr = (state.rptr + totk) % Rq

    return state._replace(
        lat_buf=lat_buf, ts_buf=ts_buf, ptr=ptr,
        r_buf=r_buf, rts_buf=rts_buf, rptr=rptr)


def record_batch(
    state: BanditState,
    params: BanditParams,
    choices: jax.Array,     # (K, C)
    latencies: jax.Array,   # (K, C)
    t: jax.Array,
    mask: jax.Array,        # (K, C)
) -> BanditState:
    """Ingest all C requests of a step: one fused ring scatter plus an
    in-order replay of the cheap (K, M) control flow. Bit-for-bit
    equal to C sequential ``record`` calls (tests/test_bandit_batch.py).

    The replay is a ``lax.scan`` over the C columns, so the control
    step is traced once instead of C times (same trick as the
    simulator's round loop — the compile-cost term in C goes away
    while execution order, and therefore every float, is unchanged).
    """
    state = record_rings_batch(state, params, choices, latencies, t, mask)

    def replay(st, x):
        c, l, m = x
        return record_feedback(st, params, c, l, t, m), None

    state, _ = jax.lax.scan(
        replay, state, (choices.T, latencies.T, mask.T))
    return state


# ---------------------------------------------------------------------------
# Request-lifecycle resilience: circuit breakers + censored observations.
#
# The breaker sits BETWEEN the balancer and the wire (Envoy-style outlier
# ejection): the bandit still owns selection, but an arm whose last
# `threshold` attempts all timed out is ejected for `cooldown` seconds
# and traffic re-routes over the remaining pool. After the cooldown the
# arm is half-open: one probe request is admitted, and a single further
# timeout re-trips the breaker while a success closes it fully. The
# state factorizes over players — (K, M) arrays, no cross-player terms —
# so it shards on the `players` mesh axis like the bandit state itself.
# ---------------------------------------------------------------------------


class BreakerState(NamedTuple):
    """Per-(player, arm) circuit breaker state.

    fails      (K, M) i32  consecutive timed-out attempts
    open_until (K, M) f32  ejected until this sim time (NEG_INF = closed)
    """

    fails: jax.Array
    open_until: jax.Array


def breaker_init(num_players: int, num_arms: int) -> BreakerState:
    return BreakerState(
        fails=jnp.zeros((num_players, num_arms), jnp.int32),
        open_until=jnp.full((num_players, num_arms), NEG_INF, jnp.float32))


def breaker_is_open(brk: BreakerState, t: jax.Array) -> jax.Array:
    """(K, M) bool: arm currently ejected for this player."""
    return t < brk.open_until


def breaker_update(
    brk: BreakerState,
    choice: jax.Array,      # (K,) arm each player attempted
    timed_out: jax.Array,   # (K,) bool: the attempt exceeded its timeout
    attempted: jax.Array,   # (K,) bool: player actually sent the attempt
    t: jax.Array,
    threshold: int,
    cooldown: float,
) -> BreakerState:
    """Advance the breaker after one attempt per player.

    A success fully closes the breaker (counter and ejection cleared); a
    timeout increments the consecutive-failure counter and, at
    `threshold`, opens the arm for `cooldown` seconds. The counter is
    left at `threshold - 1` while open so the post-cooldown half-open
    probe re-trips on a single failure.
    """
    K, M = brk.fails.shape
    kidx = jnp.arange(K)
    old_f = brk.fails[kidx, choice]
    new_f = jnp.where(timed_out, old_f + 1, 0).astype(jnp.int32)
    trip = attempted & (new_f >= threshold)
    new_f = jnp.where(trip, threshold - 1, new_f)
    old_ou = brk.open_until[kidx, choice]
    new_ou = jnp.where(trip, t + cooldown,
                       jnp.where(timed_out, old_ou, NEG_INF))
    return BreakerState(
        fails=brk.fails.at[kidx, choice].set(
            jnp.where(attempted, new_f, old_f)),
        open_until=brk.open_until.at[kidx, choice].set(
            jnp.where(attempted, new_ou, old_ou)))


def breaker_reset_arms(brk: BreakerState, changed: jax.Array) -> BreakerState:
    """Clear breaker columns for arms whose liveness changed (Alg 3/4
    placement events reset the bandit's per-arm data the same way)."""
    row = changed[None, :]
    return BreakerState(
        fails=jnp.where(row, 0, brk.fails),
        open_until=jnp.where(row, NEG_INF, brk.open_until))


def masked_pick(weights: jax.Array, ok: jax.Array,
                gumbel: jax.Array) -> jax.Array:
    """(K,) weighted sample over the arms allowed by `ok` via the Gumbel
    trick: argmax(log w + g) restricted to `ok`. Zero-weight allowed
    arms keep a tiny floor so a pool whose weight mass is entirely
    masked out still routes somewhere instead of an arbitrary arm 0."""
    score = jnp.log(weights + 1e-30) + gumbel
    return jnp.argmax(jnp.where(ok, score, NEG_INF), axis=-1)


def breaker_veto(
    choice: jax.Array,      # (K,) the bandit's pick
    brk: BreakerState,
    t: jax.Array,
    weights: jax.Array,     # (K, M) current routing weights
    active: jax.Array,      # (M,) instance liveness
    gumbel: jax.Array,      # (K, M) pre-drawn Gumbel noise
    mask: jax.Array,        # (K,) bool: player issues a request this round
) -> jax.Array:
    """Post-selection ejection mask: if the chosen arm is open, re-route
    to a weighted pick over closed active arms. Fails open — when every
    active arm is ejected the veto is waived entirely (shedding all
    traffic would be strictly worse than probing an ejected arm)."""
    K, M = weights.shape
    open_now = breaker_is_open(brk, t)
    ok = active[None, :] & ~open_now
    ok = jnp.where(ok.any(-1, keepdims=True), ok, active[None, :])
    alt = masked_pick(weights, ok, gumbel)
    blocked = mask & open_now[jnp.arange(K), choice]
    return jnp.where(blocked, alt, choice)


def retry_pick(
    weights: jax.Array,          # (K, M)
    active: jax.Array,           # (M,)
    avoid: jax.Array,            # (K,) the arm that just timed out
    open_now: jax.Array | None,  # (K, M) bool, or None when breakers off
    gumbel: jax.Array,           # (K, M)
) -> jax.Array:
    """Re-selection for a retry attempt: weighted pick over active,
    breaker-closed arms excluding the arm that just failed. Degrades
    gracefully rather than refusing to route: if nothing is closed the
    breaker constraint is dropped, and if the failed arm is the only
    active one it is retried."""
    K, M = weights.shape
    ok = active[None, :] & (jnp.arange(M)[None, :] != avoid[:, None])
    if open_now is not None:
        okb = ok & ~open_now
        ok = jnp.where(okb.any(-1, keepdims=True), okb, ok)
    ok = jnp.where(ok.any(-1, keepdims=True), ok, active[None, :])
    return masked_pick(weights, ok, gumbel)


def censored_latency(attempt_timeout: float, tau: float) -> float:
    """Imputed observation for a timed-out (right-censored) attempt.

    The client only learns `latency > attempt_timeout`; we record the
    lower bound pushed strictly past the QoS threshold so the attempt
    counts as a miss and the KDE sees a pessimistic point mass above
    tau. This biases mu_hat for slow arms DOWN — the safe direction for
    a load balancer (an arm that times out looks worse than it might
    be, never better). Static Python float: both knobs are config."""
    return max(float(attempt_timeout), float(tau)) + float(tau)


# ---------------------------------------------------------------------------
# Maintenance (Alg 1): pools, KDE estimates, scores, weights, eps schedule.
# ---------------------------------------------------------------------------

def _rolling_qos(state: BanditState, t, window):
    """(QoS over [t-W, t), QoS over [t-2W, t-W)) per player."""
    ts = state.rts_buf
    cur_m = (ts >= t - window) & (ts < t)
    prev_m = (ts >= t - 2 * window) & (ts < t - window)

    def mean(mask):
        n = mask.sum(-1)
        s = (state.r_buf * mask).sum(-1)
        return jnp.where(n > 0, s / jnp.maximum(n, 1), 1.0), n

    cur, ncur = mean(cur_m)
    prev, nprev = mean(prev_m)
    return cur, prev, ncur, nprev


def maintenance(
    state: BanditState,
    params: BanditParams,
    rtt: jax.Array,     # (K, M) current network RTT estimates [s]
    t: jax.Array,       # scalar time [s]
    lb_mask: jax.Array | None = None,   # (K,) bool: players updating now
) -> BanditState:
    """One decision step of Alg 1 (lines 6–30), vectorized over players.

    ``lb_mask`` restricts the update to a subset of players. Real
    deployments run each proxy's maintenance timer on its own clock;
    staggering the decision steps avoids the synchronized-rebalance
    oscillation a bulk-synchronous update would introduce.
    """
    K, M, R = state.lat_buf.shape

    # --- window mask over latency samples ---
    win = (state.ts_buf >= t - params.window) & (state.ts_buf < t) \
        & (state.ts_buf > NEG_INF / 2)

    # --- fused per-(player, arm) window stats: Silverman-bandwidth KDE
    # success probability (line 12) + rho-quantile of the processing
    # component (line 8). One VMEM pass on TPU (kernels/kde.py), the
    # bit-identical jnp composition elsewhere (kernels/ref.py). ---
    if params.kde_mode == 0:
        mu_flat, proc_q_flat = kernel_ops.bandit_maintenance_stats(
            state.lat_buf.reshape(K * M, R), win.reshape(K * M, R),
            rtt.reshape(K * M), params.tau, params.rho,
            min_bandwidth=params.min_bandwidth)
        mu = mu_flat.reshape(K, M)
        proc_q = proc_q_flat.reshape(K, M)
    else:
        proc = jnp.maximum(state.lat_buf - rtt[..., None], 0.0)
        proc_q = kde_mod.masked_quantile(proc, win, params.rho)   # (K, M)
        mu = kde_mod.empirical_success_prob(state.lat_buf, win, params.tau)

    # --- best expected processing latency l^{p*} (line 8 / Alg 3 line 1) ---
    big = jnp.finfo(jnp.float32).max
    any_obs = (win.sum((-1, -2)) > 0)                             # (K,)
    l_p_star = jnp.where(any_obs, jnp.min(proc_q, axis=-1), 0.0)  # optimistic 0 if no data
    l_p_star = jnp.where(l_p_star >= big, 0.0, l_p_star)

    # --- feasible set F_k(t) (line 9) ---
    not_cd = t >= state.cooldown_until
    feasible = (rtt + l_p_star[:, None] <= params.tau) & not_cd \
        & state.active[None, :]
    n_samples = win.sum(-1)
    unseen_mu = params.unseen_mu if params.unseen_mu >= 0 else params.rho - 1e-6
    mu = jnp.where(n_samples > 0, mu, unseen_mu)   # Alg 3: unseen => top explore score
    if params.ucb_coef > 0.0:                       # beyond-paper option
        total = jnp.maximum(n_samples.sum(-1, keepdims=True), 1.0)
        bonus = params.ucb_coef * jnp.sqrt(
            jnp.log(total) / jnp.maximum(n_samples, 1.0))
        mu = jnp.clip(mu + jnp.where(n_samples > 0, bonus, 0.0), 0.0, 1.0)

    # --- pools (lines 13-19) ---
    exploit = feasible & (mu >= params.rho)
    explore = feasible & (mu < params.rho)
    in_pool = exploit | explore

    # --- budgets & scores (lines 20-22) ---
    eps = state.eps
    s_e = jnp.where(exploit, (mu - params.rho) + params.eta, 0.0)
    s_x = jnp.where(explore, mu + params.eta, 0.0)
    sum_e = s_e.sum(-1, keepdims=True)
    sum_x = s_x.sum(-1, keepdims=True)
    has_e = sum_e[..., 0] > 0
    has_x = sum_x[..., 0] > 0
    # pool budgets; an empty pool donates its budget to the other
    w_e_budget = jnp.where(has_x, 1.0 - eps, 1.0) * has_e
    w_x_budget = jnp.where(has_e, eps, 1.0) * has_x
    w = s_e / jnp.maximum(sum_e, 1e-30) * w_e_budget[:, None] \
        + s_x / jnp.maximum(sum_x, 1e-30) * w_x_budget[:, None]
    # fallback: nothing feasible => uniform over active (keep traffic flowing)
    none = ~(has_e | has_x)
    uni = state.active.astype(jnp.float32)[None, :]
    uni = uni / jnp.maximum(uni.sum(-1, keepdims=True), 1.0)
    weights = jnp.where(none[:, None], uni, w)

    if params.weight_ema > 0.0:     # beyond-paper damping (see above)
        mixed = (1.0 - params.weight_ema) * weights \
            + params.weight_ema * state.weights
        # stay inside the new pool: zero out arms that left it
        mixed = jnp.where(in_pool | none[:, None], mixed, 0.0)
        msum = mixed.sum(-1, keepdims=True)
        weights = jnp.where(msum > 0, mixed / jnp.maximum(msum, 1e-30),
                            weights)

    # --- exploration schedule (lines 24-29) ---
    cur, prev, ncur, nprev = _rolling_qos(state, t, params.window)
    degraded = (ncur > 0) & (nprev > 0) \
        & (cur < prev - params.reset_hysteresis)
    if params.decay_mode == 0:
        eps_next = eps * (1.0 - params.gamma)
    else:
        eps_next = eps * params.gamma
    eps = jnp.where(degraded, 1.0 - params.rho, eps_next)

    # keep SWRR state bounded & consistent with the new pool
    cw = jnp.where(in_pool | none[:, None], state.cw, 0.0)

    if lb_mask is not None:
        keep = ~lb_mask
        mu = jnp.where(keep[:, None], state.mu_hat, mu)
        weights = jnp.where(keep[:, None], state.weights, weights)
        cw = jnp.where(keep[:, None], state.cw, cw)
        eps = jnp.where(keep, state.eps, eps)
        in_pool = jnp.where(keep[:, None], state.in_pool, in_pool)
        explore = jnp.where(keep[:, None], state.explore, explore)

    return state._replace(
        mu_hat=mu, weights=weights, cw=cw, eps=eps,
        in_pool=in_pool, explore=explore,
    )


def maintenance_subset(
    state: BanditState,
    params: BanditParams,
    rtt: jax.Array,         # (K, M)
    t: jax.Array,
    player_idx: jax.Array,  # (P,) i32 players due now; >= K entries = padding
) -> BanditState:
    """Alg 1 for a fixed-size subset of players; everyone else frozen.

    The state factorizes over players, so gather → maintenance →
    scatter commits exactly what ``maintenance(..., lb_mask)`` would for
    the same players, at ~P/K of the O(K·M·R) estimate+sort cost. The
    simulator's staggered decision clocks touch only ~K/H_d players per
    step, which is where the saving lands. ``player_idx`` entries must
    be unique (scatter rows would race otherwise); padding uses K.
    """
    K = state.lat_buf.shape[0]
    safe = jnp.minimum(player_idx, K - 1)

    sub = state._replace(
        lat_buf=state.lat_buf[safe], ts_buf=state.ts_buf[safe],
        ptr=state.ptr[safe], mu_hat=state.mu_hat[safe],
        weights=state.weights[safe], cw=state.cw[safe], eps=state.eps[safe],
        err=state.err[safe], cooldown_until=state.cooldown_until[safe],
        in_pool=state.in_pool[safe], explore=state.explore[safe],
        r_buf=state.r_buf[safe], rts_buf=state.rts_buf[safe],
        rptr=state.rptr[safe])                  # active is (M,): shared
    out = maintenance(sub, params, rtt[safe], t)

    tgt = jnp.where(player_idx < K, player_idx, K)      # drop padding rows
    return state._replace(
        mu_hat=state.mu_hat.at[tgt].set(out.mu_hat, mode="drop"),
        weights=state.weights.at[tgt].set(out.weights, mode="drop"),
        cw=state.cw.at[tgt].set(out.cw, mode="drop"),
        eps=state.eps.at[tgt].set(out.eps, mode="drop"),
        in_pool=state.in_pool.at[tgt].set(out.in_pool, mode="drop"),
        explore=state.explore.at[tgt].set(out.explore, mode="drop"),
    )


# ---------------------------------------------------------------------------
# Placement events (Alg 3 / Alg 4).
# ---------------------------------------------------------------------------

def instance_added(
    state: BanditState,
    params: BanditParams,
    m_new: jax.Array,          # scalar arm index
    rtt: jax.Array,            # (K, M)
    t: jax.Array,
) -> BanditState:
    """Alg 3: activate arm; join pools lazily with weight 0.

    Reachability (l^n + l^{p*} <= tau) is re-checked per player at the
    next maintenance step; here we clear stale feedback and mark active.
    """
    K, M, R = state.lat_buf.shape
    onehot = jax.nn.one_hot(m_new, M, dtype=bool)
    return state._replace(
        active=state.active | onehot,
        lat_buf=jnp.where(onehot[None, :, None], 0.0, state.lat_buf),
        ts_buf=jnp.where(onehot[None, :, None], NEG_INF, state.ts_buf),
        ptr=jnp.where(onehot[None, :], 0, state.ptr),
        err=jnp.where(onehot[None, :], 0, state.err),
        cooldown_until=jnp.where(onehot[None, :], NEG_INF, state.cooldown_until),
        # weight 0 until next maintenance (paper: w_{k,m_new} <- 0)
        weights=jnp.where(onehot[None, :], 0.0, state.weights),
        mu_hat=jnp.where(onehot[None, :], params.rho - 1e-6, state.mu_hat),
    )


def sync_active(
    state: BanditState,
    params: BanditParams,
    new_active: jax.Array,     # (M,) bool target liveness
) -> BanditState:
    """Vectorized Alg 3 + Alg 4 against a target liveness vector.

    Arms turning OFF are purged and weights renormalized (Alg 4); arms
    turning ON are reset with weight 0 and optimistic mu (Alg 3). Useful
    for elastic-scaling events where several replicas change at once.
    """
    added = new_active & ~state.active          # (M,)
    removed = state.active & ~new_active
    changed = (added | removed)[None, :]        # (K, M) broadcast
    w = jnp.where(removed[None, :], 0.0, state.weights)
    wsum = w.sum(-1, keepdims=True)
    unif = new_active.astype(jnp.float32)[None, :]
    unif = unif / jnp.maximum(unif.sum(-1, keepdims=True), 1.0)
    weights = jnp.where(wsum > 0, w / jnp.maximum(wsum, 1e-30), unif)
    weights = jnp.where(added[None, :], 0.0, weights)   # Alg 3: start at 0
    return state._replace(
        active=new_active,
        in_pool=state.in_pool & ~removed[None, :],
        explore=state.explore & ~removed[None, :],
        weights=weights,
        cw=jnp.where(changed, 0.0, state.cw),
        lat_buf=jnp.where(changed[..., None], 0.0, state.lat_buf),
        ts_buf=jnp.where(changed[..., None], NEG_INF, state.ts_buf),
        ptr=jnp.where(changed, 0, state.ptr),
        err=jnp.where(changed, 0, state.err),
        cooldown_until=jnp.where(changed, NEG_INF, state.cooldown_until),
        mu_hat=jnp.where(added[None, :], params.rho - 1e-6, state.mu_hat),
    )


def instance_removed(state: BanditState, m_rem: jax.Array) -> BanditState:
    """Alg 4: purge local data for the arm; renormalize weights."""
    K, M, R = state.lat_buf.shape
    onehot = jax.nn.one_hot(m_rem, M, dtype=bool)
    w = jnp.where(onehot[None, :], 0.0, state.weights)
    wsum = w.sum(-1, keepdims=True)
    uni = state.active & ~onehot
    unif = uni.astype(jnp.float32)[None, :]
    unif = unif / jnp.maximum(unif.sum(-1, keepdims=True), 1.0)
    weights = jnp.where(wsum > 0, w / jnp.maximum(wsum, 1e-30), unif)
    return state._replace(
        active=state.active & ~onehot,
        in_pool=state.in_pool & ~onehot[None, :],
        explore=state.explore & ~onehot[None, :],
        weights=weights,
        cw=jnp.where(onehot[None, :], 0.0, state.cw),
        lat_buf=jnp.where(onehot[None, :, None], 0.0, state.lat_buf),
        ts_buf=jnp.where(onehot[None, :, None], NEG_INF, state.ts_buf),
        ptr=jnp.where(onehot[None, :], 0, state.ptr),
        err=jnp.where(onehot[None, :], 0, state.err),
        cooldown_until=jnp.where(onehot[None, :], NEG_INF, state.cooldown_until),
    )
