"""Pallas TPU kernel: batched KDE success-probability estimation.

The paper's per-decision-step hot spot (§V-F bounds it O(|Q_k|) per LB;
fleet-wide it is a dense (K·M, R) fused reduction). Each row is one
(player, arm) sliding window of R latency samples; the kernel computes

    out[r] = (1/n_r) * sum_i mask[r,i] * Phi((tau - lat[r,i]) / h[r])

entirely in VMEM: one row-block tile of (BLOCK_ROWS, R) samples + mask,
the per-row bandwidths, and the erf-based Gaussian CDF evaluated on the
VPU. Rows are independent => trivially parallel grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INV_SQRT2 = 0.7071067811865476

BLOCK_ROWS = 256


def _kde_kernel(tau_ref, lat_ref, mask_ref, bw_ref, out_ref):
    lat = lat_ref[...].astype(jnp.float32)          # (BR, R)
    m = mask_ref[...].astype(jnp.float32)
    bw = bw_ref[...].astype(jnp.float32)            # (BR, 1)
    tau = tau_ref[0]
    z = (tau - lat) / bw
    cdf = 0.5 * (1.0 + jax.lax.erf(z * _INV_SQRT2))
    s = jnp.sum(cdf * m, axis=-1, keepdims=True)    # (BR, 1)
    n = jnp.sum(m, axis=-1, keepdims=True)
    out_ref[...] = jnp.where(n > 0, s / jnp.maximum(n, 1.0), 0.0)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def kde_success_prob(
    lat: jax.Array,          # (rows, R)
    mask: jax.Array,         # (rows, R) bool
    tau: jax.Array | float,  # scalar
    bandwidth: jax.Array,    # (rows,)
    interpret: bool = False,
    block_rows: int = BLOCK_ROWS,
) -> jax.Array:
    rows, R = lat.shape
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        lat = jnp.pad(lat, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
        bandwidth = jnp.pad(bandwidth, (0, pad), constant_values=1.0)
    padded = rows + pad
    tau_arr = jnp.asarray([tau], jnp.float32)

    out = pl.pallas_call(
        _kde_kernel,
        grid=(padded // br,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),                   # tau
            pl.BlockSpec((br, R), lambda i: (i, 0)),              # lat
            pl.BlockSpec((br, R), lambda i: (i, 0)),              # mask
            pl.BlockSpec((br, 1), lambda i: (i, 0)),              # bandwidth
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, 1), jnp.float32),
        interpret=interpret,
    )(tau_arr, lat, mask.astype(jnp.float32), bandwidth[:, None])
    return out[:rows, 0]
