"""Pallas TPU kernels: batched KDE estimation + fused Alg-1 maintenance.

The paper's per-decision-step hot spot (§V-F bounds it O(|Q_k|) per LB;
fleet-wide it is a dense (K·M, R) fused reduction). Each row is one
(player, arm) sliding window of R latency samples.

``kde_success_prob`` computes only the CDF sum

    out[r] = (1/n_r) * sum_i mask[r,i] * Phi((tau - lat[r,i]) / h[r])

against precomputed bandwidths. The bool mask is passed into the
kernel as-is (the single f32 conversion happens in the kernel body);
CI exercises the interpret path only — if a Mosaic version ever
rejects i1 block inputs, cast to int8 at the call sites. ``fused_maintenance`` goes further and
does the whole per-row maintenance estimate in a single VMEM pass:
Silverman bandwidth (masked mean/var), the Gaussian-CDF success
probability at tau, AND the masked rho-quantile of the processing
component max(lat - rtt, 0) — previously three separate XLA ops with a
full (rows, R) sort. The quantile is rank-selected in-register (R
compare/accumulate sweeps over the row, stable-sort tie-break by lane
index), so nothing ever leaves VMEM between the three estimates. Rows
are independent => trivially parallel grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INV_SQRT2 = 0.7071067811865476

BLOCK_ROWS = 256


def _kde_kernel(tau_ref, lat_ref, mask_ref, bw_ref, out_ref):
    lat = lat_ref[...].astype(jnp.float32)          # (BR, R)
    m = mask_ref[...].astype(jnp.float32)
    bw = bw_ref[...].astype(jnp.float32)            # (BR, 1)
    tau = tau_ref[0]
    z = (tau - lat) / bw
    cdf = 0.5 * (1.0 + jax.lax.erf(z * _INV_SQRT2))
    s = jnp.sum(cdf * m, axis=-1, keepdims=True)    # (BR, 1)
    n = jnp.sum(m, axis=-1, keepdims=True)
    out_ref[...] = jnp.where(n > 0, s / jnp.maximum(n, 1.0), 0.0)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def kde_success_prob(
    lat: jax.Array,          # (rows, R)
    mask: jax.Array,         # (rows, R) bool
    tau: jax.Array | float,  # scalar
    bandwidth: jax.Array,    # (rows,)
    interpret: bool = False,
    block_rows: int = BLOCK_ROWS,
) -> jax.Array:
    rows, R = lat.shape
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        lat = jnp.pad(lat, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
        bandwidth = jnp.pad(bandwidth, (0, pad), constant_values=1.0)
    padded = rows + pad
    tau_arr = jnp.asarray([tau], jnp.float32)

    out = pl.pallas_call(
        _kde_kernel,
        grid=(padded // br,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),                   # tau
            pl.BlockSpec((br, R), lambda i: (i, 0)),              # lat
            pl.BlockSpec((br, R), lambda i: (i, 0)),              # mask
            pl.BlockSpec((br, 1), lambda i: (i, 0)),              # bandwidth
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, 1), jnp.float32),
        interpret=interpret,
    )(tau_arr, lat, mask, bandwidth[:, None])
    return out[:rows, 0]


def _maintenance_kernel(scal_ref, lat_ref, mask_ref, rtt_ref,
                        mu_ref, q_ref):
    lat = lat_ref[...].astype(jnp.float32)          # (BR, R)
    m = mask_ref[...].astype(jnp.float32)
    rtt = rtt_ref[...].astype(jnp.float32)          # (BR, 1)
    tau, rho, min_bw = scal_ref[0], scal_ref[1], scal_ref[2]
    BR, R = lat.shape

    # --- Silverman bandwidth h = 1.06 * sigma * n^(-1/5) ---
    n = jnp.sum(m, axis=-1, keepdims=True)          # (BR, 1)
    nc = jnp.maximum(n, 1.0)
    mean = jnp.sum(lat * m, axis=-1, keepdims=True) / nc
    var = jnp.sum((lat - mean) ** 2 * m, axis=-1, keepdims=True) / nc
    sigma = jnp.sqrt(jnp.maximum(var, 0.0))
    h = jnp.maximum(1.06 * sigma * nc ** (-0.2), min_bw)

    # --- Gaussian-CDF success probability at tau ---
    z = (tau - lat) / h
    cdf = 0.5 * (1.0 + jax.lax.erf(z * _INV_SQRT2))
    s = jnp.sum(cdf * m, axis=-1, keepdims=True)
    mu_ref[...] = jnp.where(n > 0, s / nc, 0.0)

    # --- masked rho-quantile of proc = max(lat - rtt, 0) ---
    # Rank selection instead of a sort: rank[i] = #{j : x_j < x_i or
    # (x_j == x_i and j < i)} reproduces a stable ascending sort's
    # position exactly, and the target rank is the quantile index.
    big = jnp.float32(jnp.finfo(jnp.float32).max)
    proc = jnp.where(m > 0, jnp.maximum(lat - rtt, 0.0), big)
    lane = jax.lax.broadcasted_iota(jnp.int32, (BR, R), 1)
    tgt = jnp.clip((rho * (n - 1.0)).astype(jnp.int32), 0, R - 1)  # (BR, 1)

    def body(j, acc):
        xj = jax.lax.dynamic_slice_in_dim(proc, j, 1, axis=1)      # (BR, 1)
        before = (xj < proc) | ((xj == proc) & (j < lane))
        return acc + before.astype(jnp.int32)

    rank = jax.lax.fori_loop(0, R, body, jnp.zeros((BR, R), jnp.int32))
    sel = jnp.sum(jnp.where(rank == tgt, proc, 0.0), axis=-1, keepdims=True)
    q_ref[...] = jnp.where(n > 0, sel, big)


@functools.partial(
    jax.jit, static_argnames=("interpret", "block_rows"))
def fused_maintenance(
    lat: jax.Array,          # (rows, R) latency windows
    mask: jax.Array,         # (rows, R) bool validity
    rtt: jax.Array,          # (rows,) network RTT per row
    tau: jax.Array | float,
    rho: jax.Array | float,
    min_bandwidth: jax.Array | float = 1e-4,
    interpret: bool = False,
    block_rows: int = BLOCK_ROWS,
):
    """Bandwidth + KDE success prob + rho-quantile, one pass per row.

    Returns ``(mu (rows,), proc_q (rows,))``; numerically locked to
    ``ref.bandit_maintenance_stats`` (the quantile is exact — value
    selection, no arithmetic).
    """
    rows, R = lat.shape
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        lat = jnp.pad(lat, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
        rtt = jnp.pad(rtt, (0, pad))
    padded = rows + pad
    scal = jnp.asarray([tau, rho, min_bandwidth], jnp.float32)

    mu, q = pl.pallas_call(
        _maintenance_kernel,
        grid=(padded // br,),
        in_specs=[
            pl.BlockSpec((3,), lambda i: (0,)),                   # scalars
            pl.BlockSpec((br, R), lambda i: (i, 0)),              # lat
            pl.BlockSpec((br, R), lambda i: (i, 0)),              # mask
            pl.BlockSpec((br, 1), lambda i: (i, 0)),              # rtt
        ],
        out_specs=(
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((padded, 1), jnp.float32),
            jax.ShapeDtypeStruct((padded, 1), jnp.float32),
        ),
        interpret=interpret,
    )(scal, lat, mask, rtt[:, None])
    return mu[:rows, 0], q[:rows, 0]
