"""Blockwise (flash-style) attention in pure XLA ops.

The Pallas kernel cannot lower on non-TPU backends, but the *algorithm*
(online softmax over kv tiles, no S x S materialization) is expressible
with plain jnp: an unrolled triangular loop over (q block, kv block)
pairs. This is the production fallback path AND what the CPU-hosted
dry-run lowers, so the roofline's memory term reflects the tiled
algorithm rather than a naive O(S^2) buffer. Causal masking skips
whole blocks exactly (triangular FLOPs, like the kernel); sliding
windows skip out-of-window blocks (bounds gemma3/hymba local layers).

Numerically locked to ref.attention by tests/test_kernels_xla.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_NEG = -1e30


def attention_blockwise(
    q: jax.Array,            # (B, Hq, S, D)
    k: jax.Array,            # (B, Hkv, S, D)
    v: jax.Array,            # (B, Hkv, S, D)
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block: int = 1024,
) -> jax.Array:
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    blk = min(block, S)
    pad = (-S) % blk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    n = Sp // blk
    qg = q.reshape(B, Hkv, G, Sp, D)

    out_blocks = []
    for iq in range(n):
        q_lo = iq * blk
        qb = qg[:, :, :, q_lo:q_lo + blk]                    # (B,Hkv,G,bq,D)
        m = jnp.full((B, Hkv, G, blk, 1), _NEG, jnp.float32)
        l = jnp.zeros((B, Hkv, G, blk, 1), jnp.float32)
        acc = jnp.zeros((B, Hkv, G, blk, D), jnp.float32)
        for ik in range(n):
            k_lo = ik * blk
            if causal and k_lo > q_lo + blk - 1:
                continue                                     # above diagonal
            if window is not None and k_lo + blk - 1 <= q_lo - window:
                continue                                     # out of window
            kb = k[:, :, k_lo:k_lo + blk]
            vb = v[:, :, k_lo:k_lo + blk]
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            rows = q_lo + jax.lax.broadcasted_iota(
                jnp.int32, (blk, blk), 0)
            cols = k_lo + jax.lax.broadcasted_iota(
                jnp.int32, (blk, blk), 1)
            mask = cols < S
            if causal:
                mask &= cols <= rows
            if window is not None:
                mask &= rows - cols < window
            s = jnp.where(mask, s, _NEG)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1, keepdims=True)
            acc = acc * alpha + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(v.dtype), vb,
                preferred_element_type=jnp.float32)
            m = m_new
        out_blocks.append(acc / jnp.maximum(l, 1e-30))
    out = jnp.concatenate(out_blocks, axis=3)
    return out.reshape(B, Hq, Sp, D)[:, :, :S].astype(q.dtype)


import os as _os


def decode_attention_lowcast(
    q: jax.Array,            # (B, Hq, D)
    k: jax.Array,            # (B, Hkv, S, D) cache (bf16/int8-dequanted)
    v: jax.Array,
    length: jax.Array,       # (B,)
    scale: float | None = None,
) -> jax.Array:
    """Decode attention without materializing f32 copies of the cache:
    bf16 operands with f32 accumulation (MXU semantics). Halves the
    bytes touched per step vs the astype(f32) reference.

    REPRO_DECODE_SHARDED=1 (default) additionally pins the score matrix
    to the *cache's* layout ("ctx"-sharded seq) so the softmax runs as a
    distributed flash-decode (tiny max/sum all-reduces + a partial-sum
    reduction of the (B,Hq,D) output) instead of XLA repartitioning the
    whole cache through collective-permutes every layer.
    """
    from repro.sharding import constrain
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    sharded = _os.environ.get("REPRO_DECODE_SHARDED", "1") == "1"
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(B, Hkv, G, D)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qg, k,
                        preferred_element_type=jnp.float32)
    if sharded:
        logits = constrain(logits, "batch", None, None, "ctx")
    valid = jnp.arange(S)[None, :] < length[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    if sharded:
        p = constrain(p, "batch", None, None, "ctx")
    out = jnp.einsum("bhgk,bhkd->bhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hq, D).astype(q.dtype)
