"""jit'd public wrappers for the Pallas kernels with backend dispatch.

On TPU the Pallas path runs; elsewhere (this CPU container, and the
CPU-hosted dry-run where Mosaic cannot lower) the pure-jnp reference is
used, with `interpret=True` available for kernel-body validation. The
two paths are numerically locked by tests/test_kernels_*.
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

import os

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import kde as _kde
from repro.kernels import ref
from repro.kernels import round_fused as _round
from repro.kernels import ssd as _ssd
from repro.kernels import xla_flash

_VALID_MODES = ("auto", "pallas", "interpret", "ref")

# "auto"  : pallas on TPU else reference
# "pallas": force pallas (compiled)
# "interpret": pallas kernel body in interpret mode (CPU validation)
# "ref"   : force the pure-jnp oracle
# REPRO_KERNEL_MODE pins the process-wide default (CI's interpret lane).
_MODE = os.environ.get("REPRO_KERNEL_MODE", "auto")
assert _MODE in _VALID_MODES, _MODE


def set_mode(mode: str) -> None:
    global _MODE
    assert mode in _VALID_MODES, mode
    _MODE = mode


@contextlib.contextmanager
def mode(m: str):
    """Scoped `set_mode`: restores the previous mode on exit, so tests
    and benchmarks can't leak a forced backend into each other."""
    assert m in _VALID_MODES, m
    global _MODE
    prev = _MODE
    _MODE = m
    try:
        yield
    finally:
        _MODE = prev


def _use_pallas() -> bool | str:
    if _MODE == "pallas":
        return True
    if _MODE == "interpret":
        return "interpret"
    if _MODE == "ref":
        return False
    return jax.default_backend() == "tpu"


def attention(q, k, v, causal: bool = True, window: int | None = None,
              scale: float | None = None):
    """Causal GQA attention (prefill). (B,Hq,S,D)x(B,Hkv,S,D) -> (B,Hq,S,D).

    Non-TPU XLA impl selected by REPRO_ATTN_IMPL:
      blockwise (default) — flash-style tiled online softmax (no S x S
                            buffer; exact triangular/window block skips)
      naive               — reference O(S^2) materialization
    """
    use = _use_pallas()
    if use:
        return _fa.flash_attention(
            q, k, v, causal=causal, window=window, scale=scale,
            interpret=(use == "interpret"))
    if os.environ.get("REPRO_GQA_IMPL", "") == "repeat" and \
            k.shape[1] != q.shape[1]:
        # repeat KV heads to Hq: the grouped einsum's Hkv dim cannot
        # shard across a TP axis wider than Hkv (XLA falls back to
        # "involuntary full rematerialization" copies); post-repeat the
        # head dim shards cleanly. Trades KV gather bytes for clean TP.
        g = q.shape[1] // k.shape[1]
        import jax.numpy as _jnp
        k = _jnp.repeat(k, g, axis=1)
        v = _jnp.repeat(v, g, axis=1)
    impl = os.environ.get("REPRO_ATTN_IMPL", "blockwise")
    if impl == "blockwise" and q.shape[2] > 1024:
        return xla_flash.attention_blockwise(
            q, k, v, causal=causal, window=window, scale=scale)
    return ref.attention(q, k, v, causal=causal, window=window, scale=scale)


def decode_attention(q, k, v, length, scale: float | None = None):
    """One-token GQA attention vs KV cache. (B,Hq,D) -> (B,Hq,D).

    REPRO_DECODE_IMPL: lowcast (default; bf16 operands, f32 accum — no
    f32 cache copies) | naive (reference casts).
    """
    use = _use_pallas()
    if use:
        return _dec.decode_attention(
            q, k, v, length, scale=scale, interpret=(use == "interpret"))
    if os.environ.get("REPRO_DECODE_IMPL", "lowcast") == "lowcast":
        return xla_flash.decode_attention_lowcast(q, k, v, length, scale)
    return ref.decode_attention(q, k, v, length, scale=scale)


def ssd(x, dt, A, Bm, Cm, chunk: int = 128):
    """Mamba-2 SSD over a sequence. (B,S,H,P) -> (B,S,H,P)."""
    use = _use_pallas()
    if use:
        return _ssd.ssd(x, dt, A, Bm, Cm, chunk=chunk,
                        interpret=(use == "interpret"))
    return ref.ssd(x, dt, A, Bm, Cm)


def ssd_decode_step(h, x, dt, A, Bm, Cm):
    """O(1)-state single-token SSD update (no kernel needed: rank-1)."""
    return ref.ssd_decode_step(h, x, dt, A, Bm, Cm)


def kde_success_prob(lat, mask, tau, bandwidth):
    """Batched windowed KDE P(l <= tau). (rows,R) -> (rows,)."""
    use = _use_pallas()
    if use:
        return _kde.kde_success_prob(
            lat, mask, tau, bandwidth, interpret=(use == "interpret"))
    return ref.kde_success_prob(lat, mask, tau, bandwidth)


def round_step(weights, cw, err, cooldown_until, in_pool, active,
               lat_buf, ts_buf, ptr, r_buf, rts_buf, rptr,
               q, nc, z, rtt_t, s_m, served_per_round, t,
               tau: float, err_thresh: int, cooldown: float):
    """Fused simulator round: all C SWRR rounds of one step.

    Selection -> shared-queue recursion -> feedback control -> ring
    scatter, with the player block's bandit state resident in VMEM on
    the Pallas path. Both paths are bit-identical by construction
    (tests/test_round_fused.py); returns `ref.RoundStepOut`.
    """
    use = _use_pallas()
    if use:
        return _round.round_step_swrr(
            weights, cw, err, cooldown_until, in_pool, active,
            lat_buf, ts_buf, ptr, r_buf, rts_buf, rptr,
            q, nc, z, rtt_t, s_m, served_per_round, t,
            tau=tau, err_thresh=err_thresh, cooldown=cooldown,
            interpret=(use == "interpret"))
    return ref.round_step_swrr(
        weights, cw, err, cooldown_until, in_pool, active,
        lat_buf, ts_buf, ptr, r_buf, rts_buf, rptr,
        q, nc, z, rtt_t, s_m, served_per_round, t,
        tau=tau, err_thresh=err_thresh, cooldown=cooldown)


def round_step_gumbel(weights, q, nc, z, gum, rtt_t, s_m, served_per_round):
    """Fused proxy-of-MITY round (no kernel needed: selection is
    queue-independent, so the scatter-free batched jnp path IS the
    fused form — one argmax over (C,K,M) plus a tiny (M,)-queue scan).
    Returns (q, arrivals, choices, lats, procs)."""
    return ref.round_step_gumbel(weights, q, nc, z, gum, rtt_t, s_m,
                                 served_per_round)


def bandit_maintenance_stats(lat, mask, rtt, tau, rho, min_bandwidth=1e-4):
    """Fused Alg-1 window stats per (player, arm) row.

    Silverman bandwidth + Gaussian-CDF success prob at tau + masked
    rho-quantile of max(lat - rtt, 0) in one VMEM pass on TPU; the
    bit-identical jnp composition elsewhere.
    (rows,R) -> ((rows,), (rows,)).
    """
    use = _use_pallas()
    if use:
        return _kde.fused_maintenance(
            lat, mask, rtt, tau, rho, min_bandwidth,
            interpret=(use == "interpret"))
    return ref.bandit_maintenance_stats(lat, mask, rtt, tau, rho,
                                        min_bandwidth)
