"""jit'd public wrappers for the Pallas kernels with backend dispatch.

On TPU the Pallas path runs; elsewhere (this CPU container, and the
CPU-hosted dry-run where Mosaic cannot lower) the pure-jnp reference is
used, with `interpret=True` available for kernel-body validation. The
two paths are numerically locked by tests/test_kernels_*.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

import os

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import kde as _kde
from repro.kernels import ref
from repro.kernels import ssd as _ssd
from repro.kernels import xla_flash

# "auto"  : pallas on TPU else reference
# "pallas": force pallas (compiled)
# "interpret": pallas kernel body in interpret mode (CPU validation)
# "ref"   : force the pure-jnp oracle
_MODE = "auto"


def set_mode(mode: str) -> None:
    global _MODE
    assert mode in ("auto", "pallas", "interpret", "ref"), mode
    _MODE = mode


def _use_pallas() -> bool | str:
    if _MODE == "pallas":
        return True
    if _MODE == "interpret":
        return "interpret"
    if _MODE == "ref":
        return False
    return jax.default_backend() == "tpu"


def attention(q, k, v, causal: bool = True, window: int | None = None,
              scale: float | None = None):
    """Causal GQA attention (prefill). (B,Hq,S,D)x(B,Hkv,S,D) -> (B,Hq,S,D).

    Non-TPU XLA impl selected by REPRO_ATTN_IMPL:
      blockwise (default) — flash-style tiled online softmax (no S x S
                            buffer; exact triangular/window block skips)
      naive               — reference O(S^2) materialization
    """
    use = _use_pallas()
    if use:
        return _fa.flash_attention(
            q, k, v, causal=causal, window=window, scale=scale,
            interpret=(use == "interpret"))
    if os.environ.get("REPRO_GQA_IMPL", "") == "repeat" and \
            k.shape[1] != q.shape[1]:
        # repeat KV heads to Hq: the grouped einsum's Hkv dim cannot
        # shard across a TP axis wider than Hkv (XLA falls back to
        # "involuntary full rematerialization" copies); post-repeat the
        # head dim shards cleanly. Trades KV gather bytes for clean TP.
        g = q.shape[1] // k.shape[1]
        import jax.numpy as _jnp
        k = _jnp.repeat(k, g, axis=1)
        v = _jnp.repeat(v, g, axis=1)
    impl = os.environ.get("REPRO_ATTN_IMPL", "blockwise")
    if impl == "blockwise" and q.shape[2] > 1024:
        return xla_flash.attention_blockwise(
            q, k, v, causal=causal, window=window, scale=scale)
    return ref.attention(q, k, v, causal=causal, window=window, scale=scale)


def decode_attention(q, k, v, length, scale: float | None = None):
    """One-token GQA attention vs KV cache. (B,Hq,D) -> (B,Hq,D).

    REPRO_DECODE_IMPL: lowcast (default; bf16 operands, f32 accum — no
    f32 cache copies) | naive (reference casts).
    """
    use = _use_pallas()
    if use:
        return _dec.decode_attention(
            q, k, v, length, scale=scale, interpret=(use == "interpret"))
    if os.environ.get("REPRO_DECODE_IMPL", "lowcast") == "lowcast":
        return xla_flash.decode_attention_lowcast(q, k, v, length, scale)
    return ref.decode_attention(q, k, v, length, scale=scale)


def ssd(x, dt, A, Bm, Cm, chunk: int = 128):
    """Mamba-2 SSD over a sequence. (B,S,H,P) -> (B,S,H,P)."""
    use = _use_pallas()
    if use:
        return _ssd.ssd(x, dt, A, Bm, Cm, chunk=chunk,
                        interpret=(use == "interpret"))
    return ref.ssd(x, dt, A, Bm, Cm)


def ssd_decode_step(h, x, dt, A, Bm, Cm):
    """O(1)-state single-token SSD update (no kernel needed: rank-1)."""
    return ref.ssd_decode_step(h, x, dt, A, Bm, Cm)


def kde_success_prob(lat, mask, tau, bandwidth):
    """Batched windowed KDE P(l <= tau). (rows,R) -> (rows,)."""
    use = _use_pallas()
    if use:
        return _kde.kde_success_prob(
            lat, mask, tau, bandwidth, interpret=(use == "interpret"))
    return ref.kde_success_prob(lat, mask, tau, bandwidth)


def bandit_maintenance_stats(lat, mask, rtt, tau, rho, min_bandwidth=1e-4):
    """Fused Alg-1 window stats per (player, arm) row.

    Silverman bandwidth + Gaussian-CDF success prob at tau + masked
    rho-quantile of max(lat - rtt, 0) in one VMEM pass on TPU; the
    bit-identical jnp composition elsewhere.
    (rows,R) -> ((rows,), (rows,)).
    """
    use = _use_pallas()
    if use:
        return _kde.fused_maintenance(
            lat, mask, rtt, tau, rho, min_bandwidth,
            interpret=(use == "interpret"))
    return ref.bandit_maintenance_stats(lat, mask, rtt, tau, rho,
                                        min_bandwidth)
