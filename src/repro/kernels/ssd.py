"""Pallas TPU kernel: Mamba-2 SSD chunked scan [arXiv:2405.21060].

TPU-native re-think of the SSD block decomposition: the sequence is cut
into chunks of length C; within a chunk the quadratic form
``(C B^T ⊙ decay) X`` runs on the MXU, while the inter-chunk state
``h ∈ (N, P)`` is carried in VMEM scratch across the (sequential,
innermost) chunk axis of the grid — the cross-chunk recurrence costs one
rank-C update + one (C,N)x(N,P) matmul per chunk instead of a length-S
scan. ngroups=1 (B/C shared across heads), matching the configs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_scr,
                *, chunk, nc):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)           # (C, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (C,)
    A = a_ref[0]                                     # scalar (this head)
    Bm = b_ref[0].astype(jnp.float32)                # (C, N)
    Cm = c_ref[0].astype(jnp.float32)                # (C, N)

    a = A * dt                                       # (C,) decay exponents
    cum = jnp.cumsum(a)                              # inclusive
    # within-chunk causal decay: G[i, j] = exp(cum_i - cum_j) for j <= i
    gi = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = jj <= ii
    G = jnp.where(causal, jnp.exp(jnp.where(causal, gi, 0.0)), 0.0)

    # diagonal (intra-chunk) term: ((C B^T) ⊙ G ⊙ dt_j) X
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (C, C)
    y = jax.lax.dot_general(cb * G * dt[None, :], x,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (C, P)

    # off-diagonal term: state entering the chunk
    h = h_scr[...]                                   # (N, P)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # state update: h' = exp(cum_last) h + sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
    w = jnp.exp(cum[-1] - cum) * dt                  # (C,)
    h_scr[...] = jnp.exp(cum[-1]) * h + jax.lax.dot_general(
        Bm * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0, :, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jax.Array,            # (B, S, H, P)
    dt: jax.Array,           # (B, S, H) positive step sizes
    A: jax.Array,            # (H,) negative decay rates
    Bm: jax.Array,           # (B, S, N)
    Cm: jax.Array,           # (B, S, N)
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        # dt=0 rows are inert: decay 1, zero state contribution
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // c

    kernel = functools.partial(_ssd_kernel, chunk=c, nc=nc)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, c, 1, P), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, c, 1), lambda b, h, ic: (b, ic, h)),
            pl.BlockSpec((1,), lambda b, h, ic: (h,)),
            pl.BlockSpec((1, c, N), lambda b, h, ic: (b, ic, 0)),
            pl.BlockSpec((1, c, N), lambda b, h, ic: (b, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, 1, P), lambda b, h, ic: (b, ic, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm)
    return out[:, :S]
