"""Pure-jnp oracles for every Pallas kernel in this package.

These are the numerical ground truth: each kernel's test sweeps shapes
and dtypes and asserts allclose against the function here. They are
also the XLA fallback path used on non-TPU backends (and for the
CPU-hosted dry-run, where Mosaic cannot lower).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

_NEG = -1e30


# ---------------------------------------------------------------------------
# Attention (prefill): causal GQA with optional sliding window.
# ---------------------------------------------------------------------------

def attention(
    q: jax.Array,            # (B, Hq, S, D)
    k: jax.Array,            # (B, Hkv, S, D)
    v: jax.Array,            # (B, Hkv, S, D)
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B, Hkv, G, S, D)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf)
    idx = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= idx[:, None] >= idx[None, :]
    if window is not None:
        mask &= idx[:, None] - idx[None, :] < window
    logits = jnp.where(mask[None, None, None], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(B, Hq, S, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention (decode): one query token against a KV cache.
# ---------------------------------------------------------------------------

def decode_attention(
    q: jax.Array,            # (B, Hq, D)
    k: jax.Array,            # (B, Hkv, S, D) cache
    v: jax.Array,            # (B, Hkv, S, D)
    length: jax.Array,       # (B,) valid cache entries
    scale: float | None = None,
) -> jax.Array:
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, G, D)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qf, k.astype(jnp.float32))
    valid = jnp.arange(S)[None, :] < length[:, None]          # (B, S)
    logits = jnp.where(valid[:, None, None, :], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD: exact sequential recurrence (the semantic definition).
# ---------------------------------------------------------------------------

def ssd(
    x: jax.Array,            # (B, S, H, P) inputs per head
    dt: jax.Array,           # (B, S, H) softplus'd step sizes (>0)
    A: jax.Array,            # (H,) negative state decay rates
    Bm: jax.Array,           # (B, S, N) input projections (ngroups=1)
    Cm: jax.Array,           # (B, S, N) output projections
) -> jax.Array:
    """y_t = C_t^T h_t;  h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_t^T.

    State h has shape (H, N, P) per batch element. Returns (B, S, H, P).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def scan_one(b):
        def step(h, inp):
            xt, dtt, Bt, Ct = inp                  # (H,P) (H,) (N,) (N,)
            decay = jnp.exp(Af * dtt)              # (H,)
            h = h * decay[:, None, None] + (
                dtt[:, None, None] * Bt[None, :, None] * xt[:, None, :])
            y = jnp.einsum("n,hnp->hp", Ct, h)
            return h, y

        h0 = jnp.zeros((H, N, P), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (xf[b], dtf[b], Bf[b], Cf[b]))
        return ys                                   # (S, H, P)

    out = jax.vmap(scan_one)(jnp.arange(Bsz))
    return out.astype(x.dtype)


def ssd_decode_step(
    h: jax.Array,            # (B, H, N, P) carried state
    x: jax.Array,            # (B, H, P) current token input
    dt: jax.Array,           # (B, H)
    A: jax.Array,            # (H,)
    Bm: jax.Array,           # (B, N)
    Cm: jax.Array,           # (B, N)
):
    """Single-token SSD update (serving decode). Returns (h', y)."""
    decay = jnp.exp(A[None, :] * dt)                          # (B, H)
    h = h * decay[..., None, None] + (
        dt[..., None, None] * Bm[:, None, :, None] * x[:, :, None, :])
    y = jnp.einsum("bn,bhnp->bhp", Cm, h)
    return h, y.astype(x.dtype)


# ---------------------------------------------------------------------------
# KDE success probability (the bandit's per-decision hot spot).
# ---------------------------------------------------------------------------

def kde_success_prob(
    lat: jax.Array,          # (rows, R) latency windows
    mask: jax.Array,         # (rows, R) validity
    tau: float,
    bandwidth: jax.Array,    # (rows,)
) -> jax.Array:
    m = mask.astype(jnp.float32)
    n = m.sum(-1)
    z = (tau - lat.astype(jnp.float32)) / bandwidth[:, None]
    cdf = 0.5 * (1.0 + jax.lax.erf(z * 0.7071067811865476))
    s = (cdf * m).sum(-1)
    return jnp.where(n > 0, s / jnp.maximum(n, 1.0), 0.0)


def _bitonic_sort_rows(x: jax.Array) -> jax.Array:
    """Ascending per-row sort of a (rows, R) array, R a power of two,
    as a branchless bitonic network (21 min/max stages at R=64).

    XLA:CPU lowers ``jnp.sort`` to a scalar comparator loop — ~35 ms
    for the (5000, 64) maintenance batch at K=1000×M=50, which made
    the rho-quantile the single hottest op of the whole simulator. The
    network is pure reshape+minimum/maximum, so it vectorizes.

    Bit-exactness: for finite values with no -0.0 (the processing
    quantile input is ``max(lat - rtt, 0)`` / finfo.max fill), the
    ascending multiset of a row is unique, so the output is
    bit-identical to ``jnp.sort``.
    """
    rows, R = x.shape
    assert R & (R - 1) == 0, R
    k = 2
    while k <= R:
        j = k // 2
        while j >= 1:
            x4 = x.reshape(rows, R // (2 * j), 2, j)
            lo, hi = x4[:, :, 0, :], x4[:, :, 1, :]
            mn, mx = jnp.minimum(lo, hi), jnp.maximum(lo, hi)
            # ascending iff bit k of the element's global index is 0
            blk = jnp.arange(R // (2 * j)) * (2 * j)
            asc = ((blk & k) == 0)[None, :, None]
            x = jnp.stack(
                (jnp.where(asc, mn, mx), jnp.where(asc, mx, mn)),
                axis=2).reshape(rows, R)
            j //= 2
        k *= 2
    return x


def bandit_maintenance_stats(
    lat: jax.Array,          # (rows, R) latency windows
    mask: jax.Array,         # (rows, R) validity (bool)
    rtt: jax.Array,          # (rows,) network RTT per row
    tau: float,
    rho: float,
    min_bandwidth: float = 1e-4,
):
    """Fused Alg-1 window stats per (player, arm) row: Silverman
    bandwidth -> Gaussian-CDF success probability at tau, plus the
    masked rho-quantile of the processing component max(lat - rtt, 0).

    Oracle for ``kernels/kde.py::fused_maintenance``. Mirrors the
    repro/core/kde.py composition op-for-op (bit-identical on CPU);
    kept self-contained because importing repro.core here would close a
    core -> kernels -> core cycle. Returns ``(mu (rows,), q (rows,))``.
    """
    latf = lat.astype(jnp.float32)
    m = mask.astype(jnp.float32)

    # Silverman bandwidth h = 1.06 * sigma * n^(-1/5) (core silverman_bandwidth)
    nc = jnp.maximum(m.sum(-1), 1.0)
    mean = (latf * m).sum(-1) / nc
    var = ((latf - mean[..., None]) ** 2 * m).sum(-1) / nc
    sigma = jnp.sqrt(jnp.maximum(var, 0.0))
    h = jnp.maximum(1.06 * sigma * nc ** (-0.2), min_bandwidth)

    # Gaussian-kernel CDF estimate of P(lat <= tau) (core kde_success_prob)
    n = m.sum(-1)
    z = (tau - latf) / h[..., None]
    cdf = 0.5 * (1.0 + jax.lax.erf(z * 0.7071067811865476))
    contrib = (cdf * m).sum(-1)
    mu = jnp.where(n > 0, contrib / jnp.maximum(n, 1.0), 0.0)

    # masked rho-quantile of processing latency (core masked_quantile)
    proc = jnp.maximum(latf - rtt[..., None], 0.0)
    big = jnp.asarray(jnp.finfo(jnp.float32).max, jnp.float32)
    filled = jnp.where(mask, proc, big)
    R = lat.shape[-1]
    if R & (R - 1) == 0:
        xs = _bitonic_sort_rows(filled)      # bit-identical, ~10x faster
    else:
        xs = jnp.sort(filled, axis=-1)
    ni = mask.sum(-1)
    idx = jnp.clip((rho * (ni - 1)).astype(jnp.int32), 0, lat.shape[-1] - 1)
    val = jnp.take_along_axis(xs, idx[..., None], axis=-1)[..., 0]
    q = jnp.where(ni > 0, val, big)
    return mu, q


# ---------------------------------------------------------------------------
# Fused simulator round (the per-step hot path).
#
# One call covers ALL C request rounds of one simulator step: SWRR
# selection, the shared (M,)-queue recursion, the per-round feedback
# control (error counters / cooldown trips / weight renormalization)
# and the deferred ring scatter. Mirrors, op for op:
#   repro.core.swrr.swrr_select
#   repro.core.bandit._record_control      (via record_feedback)
#   repro.core.bandit.record_rings_batch
#   the round scan in repro.continuum.simulator.build_sim_parts
# Kept self-contained (no repro.core imports) for the same reason as
# ``bandit_maintenance_stats``: core -> kernels -> core would cycle.
#
# Bit-exactness contract (tests/test_round_fused.py): every output is
# bit-identical to the unfused round scan. The two deliberate
# reassociations are provably exact — ``arrivals`` sums integer-valued
# f32 counts (< 2**24), and the batch ring scatter is the proven
# equivalent of C sequential ring writes (tests/test_bandit_batch.py).
# The per-round processing-noise draws arrive PREcomputed as ``z``
# (C, K): each element is the same threefry stream the sequential loop
# draws, just batched (a pure function of (step key, round, player id)).
# ---------------------------------------------------------------------------


class RoundStepOut(NamedTuple):
    """Everything one fused round produces: the updated bandit tensors,
    the shared queue, and the per-request outputs the metric
    accumulator consumes."""
    weights: jax.Array          # (K, M)
    cw: jax.Array               # (K, M)
    err: jax.Array              # (K, M) i32
    cooldown_until: jax.Array   # (K, M)
    in_pool: jax.Array          # (K, M) bool
    lat_buf: jax.Array          # (K, M, R)
    ts_buf: jax.Array           # (K, M, R)
    ptr: jax.Array              # (K, M) i32
    r_buf: jax.Array            # (K, Rq)
    rts_buf: jax.Array          # (K, Rq)
    rptr: jax.Array             # (K,) i32
    q: jax.Array                # (M,) queue after all C rounds
    arrivals: jax.Array         # (M,) requests per instance this step
    choices: jax.Array          # (K, C) i32
    lats: jax.Array             # (K, C)
    procs: jax.Array            # (K, C)


def _ring_scatter(lat_buf, ts_buf, ptr, r_buf, rts_buf, rptr,
                  choices, lats, t, mask, tau):
    """`core.bandit.record_rings_batch` mirrored op-for-op."""
    K, M, R = lat_buf.shape
    C = choices.shape[1]
    Rq = r_buf.shape[1]
    kk = jnp.broadcast_to(jnp.arange(K)[:, None], (K, C))
    t_arr = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (K, C))
    reward = (lats <= tau).astype(jnp.float32)
    maski = mask.astype(jnp.int32)

    onehot = (choices[..., None] == jnp.arange(M)) & mask[..., None]
    cnt = jnp.cumsum(onehot.astype(jnp.int32), axis=1)
    total = cnt[:, -1, :]
    rank = jnp.take_along_axis(
        cnt - onehot.astype(jnp.int32), choices[..., None], axis=2)[..., 0]
    p0 = jnp.take_along_axis(ptr, choices, axis=1)
    slot = (p0 + rank) % R
    tot_c = jnp.take_along_axis(total, choices, axis=1)
    keep = mask & (rank >= tot_c - R)
    slot = jnp.where(keep, slot, R)
    lat_buf = lat_buf.at[kk, choices, slot].set(lats, mode="drop")
    ts_buf = ts_buf.at[kk, choices, slot].set(t_arr, mode="drop")
    ptr = (ptr + total) % R

    crank = jnp.cumsum(maski, axis=1) - maski
    totk = maski.sum(1)
    rslot = (rptr[:, None] + crank) % Rq
    keep_r = mask & (crank >= totk[:, None] - Rq)
    rslot = jnp.where(keep_r, rslot, Rq)
    r_buf = r_buf.at[kk, rslot].set(reward, mode="drop")
    rts_buf = rts_buf.at[kk, rslot].set(t_arr, mode="drop")
    rptr = (rptr + totk) % Rq
    return lat_buf, ts_buf, ptr, r_buf, rts_buf, rptr


def round_step_swrr(
    weights: jax.Array,         # (K, M)
    cw: jax.Array,              # (K, M) SWRR current weights
    err: jax.Array,             # (K, M) i32 consecutive-error counters
    cooldown_until: jax.Array,  # (K, M)
    in_pool: jax.Array,         # (K, M) bool
    active: jax.Array,          # (M,) bool instance liveness
    lat_buf: jax.Array,         # (K, M, R)
    ts_buf: jax.Array,          # (K, M, R)
    ptr: jax.Array,             # (K, M) i32
    r_buf: jax.Array,           # (K, Rq)
    rts_buf: jax.Array,         # (K, Rq)
    rptr: jax.Array,            # (K,) i32
    q: jax.Array,               # (M,) queue at step start
    nc: jax.Array,              # (K,) i32 admitted client slots
    z: jax.Array,               # (C, K) processing-noise factors e^{sigma N}
    rtt_t: jax.Array,           # (K, M) effective RTT this step
    s_m: jax.Array,             # (M,) service-time row
    served_per_round: jax.Array,  # (M,) dt / (C * s_m)
    t: jax.Array,               # scalar sim time [s]
    tau: float,
    err_thresh: int,
    cooldown: float,
    unroll: bool = False,
) -> RoundStepOut:
    """All C SWRR rounds of one step, fused (jnp oracle).

    The round loop stays a scan (rounds are genuinely sequential: each
    sees the queue its predecessors filled) with the C per-round PRNG
    dispatches gone — ``z`` arrives batched. ``unroll`` trades compile
    time and L2 pressure for cross-round fusion; on XLA:CPU the rolled
    loop measured faster at K=1000×M=50 (the unrolled body spills its
    8x (K, M) intermediates), so it is off by default.
    """
    K, M, R = lat_buf.shape
    C = z.shape[0]
    kidx = jnp.arange(K)

    def body(carry, xs):
        w, cw_c, err_c, cd, pool, qc = carry
        r, z_r = xs
        mask = r < nc
        # --- core.swrr.swrr_select ---
        total = w.sum(-1, keepdims=True)
        cw_c = cw_c + w
        choice = jnp.argmax(cw_c, axis=-1)
        onehot_f = jax.nn.one_hot(choice, M, dtype=cw_c.dtype)
        cw_c = cw_c - onehot_f * total
        # --- latency (simulator round_body) ---
        q_seen = qc[choice]
        proc = (q_seen + 1.0) * s_m[choice] * z_r
        lat = rtt_t[kidx, choice] + proc
        # --- core.bandit._record_control ---
        reward = (lat <= tau).astype(jnp.float32)
        old_err = err_c[kidx, choice]
        new_err = jnp.where(reward > 0, 0, old_err + 1).astype(jnp.int32)
        trip = mask & (new_err >= err_thresh)
        err_c = err_c.at[kidx, choice].set(
            jnp.where(mask, jnp.where(trip, 0, new_err), old_err))
        cd = cd.at[kidx, choice].set(
            jnp.where(trip, t + cooldown, cd[kidx, choice]))
        tripped = jax.nn.one_hot(choice, M, dtype=bool) & trip[:, None]
        pool = pool & ~tripped
        w2 = jnp.where(tripped, 0.0, w)
        wsum = w2.sum(-1, keepdims=True)
        remaining = pool & active[None, :]
        rem_any = remaining.any(-1, keepdims=True)
        fallback = jnp.where(
            rem_any, remaining,
            active[None, :] & ~tripped).astype(jnp.float32)
        fallback = fallback / jnp.maximum(
            fallback.sum(-1, keepdims=True), 1.0)
        w = jnp.where(wsum > 0, w2 / jnp.maximum(wsum, 1e-30), fallback)
        cw_c = jnp.where(tripped, 0.0, cw_c)
        # --- shared-queue recursion ---
        arr_r = jax.ops.segment_sum(
            mask.astype(jnp.float32), choice, num_segments=M)
        qc = jnp.maximum(qc + arr_r - served_per_round, 0.0)
        return (w, cw_c, err_c, cd, pool, qc), (choice, lat, proc, arr_r)

    carry, (ch_r, lat_r, proc_r, arr_cr) = jax.lax.scan(
        body, (weights, cw, err, cooldown_until, in_pool, q),
        (jnp.arange(C), z), unroll=C if unroll else 1)
    weights, cw, err, cooldown_until, in_pool, q = carry
    choices, lats, procs = ch_r.T, lat_r.T, proc_r.T
    arrivals = arr_cr.sum(0)                 # integer-valued: order-free
    mask_kc = jnp.arange(C)[None, :] < nc[:, None]
    lat_buf, ts_buf, ptr, r_buf, rts_buf, rptr = _ring_scatter(
        lat_buf, ts_buf, ptr, r_buf, rts_buf, rptr,
        choices, lats, t, mask_kc, tau)
    return RoundStepOut(weights, cw, err, cooldown_until, in_pool,
                        lat_buf, ts_buf, ptr, r_buf, rts_buf, rptr,
                        q, arrivals, choices, lats, procs)


def round_step_gumbel(
    weights: jax.Array,         # (K, M) static routing weights
    q: jax.Array,               # (M,)
    nc: jax.Array,              # (K,) i32
    z: jax.Array,               # (C, K)
    gum: jax.Array,             # (C, K, M) selection Gumbel rows
    rtt_t: jax.Array,           # (K, M)
    s_m: jax.Array,             # (M,)
    served_per_round: jax.Array,  # (M,)
):
    """All C Gumbel-categorical rounds of one step, fully vectorized.

    Stateless strategies (proxy-mity) pick arms from FIXED weights, so
    selection is queue-independent: every round's argmax happens at
    once and only the tiny (M,)-wide queue recursion stays sequential.
    Returns ``(q, arrivals, choices (K, C), lats, procs)``.
    """
    C, K, M = gum.shape
    logits = jnp.log(weights + 1e-30)
    choices_cr = jnp.argmax(logits[None] + gum, axis=-1)       # (C, K)
    mask_cr = jnp.arange(C)[:, None] < nc[None, :]             # (C, K)
    arr_cr = jax.vmap(
        lambda m, c: jax.ops.segment_sum(
            m.astype(jnp.float32), c, num_segments=M))(mask_cr, choices_cr)

    def qbody(qc, xs):
        c_r, a_r = xs
        q_seen = qc[c_r]
        return jnp.maximum(qc + a_r - served_per_round, 0.0), q_seen

    q, qseen_cr = jax.lax.scan(qbody, q, (choices_cr, arr_cr), unroll=C)
    procs_cr = (qseen_cr + 1.0) * s_m[choices_cr] * z
    lats_cr = rtt_t[jnp.arange(K)[None, :], choices_cr] + procs_cr
    arrivals = arr_cr.sum(0)                 # integer-valued: order-free
    return q, arrivals, choices_cr.T, lats_cr.T, procs_cr.T
