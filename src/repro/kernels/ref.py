"""Pure-jnp oracles for every Pallas kernel in this package.

These are the numerical ground truth: each kernel's test sweeps shapes
and dtypes and asserts allclose against the function here. They are
also the XLA fallback path used on non-TPU backends (and for the
CPU-hosted dry-run, where Mosaic cannot lower).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG = -1e30


# ---------------------------------------------------------------------------
# Attention (prefill): causal GQA with optional sliding window.
# ---------------------------------------------------------------------------

def attention(
    q: jax.Array,            # (B, Hq, S, D)
    k: jax.Array,            # (B, Hkv, S, D)
    v: jax.Array,            # (B, Hkv, S, D)
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B, Hkv, G, S, D)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf)
    idx = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= idx[:, None] >= idx[None, :]
    if window is not None:
        mask &= idx[:, None] - idx[None, :] < window
    logits = jnp.where(mask[None, None, None], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(B, Hq, S, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention (decode): one query token against a KV cache.
# ---------------------------------------------------------------------------

def decode_attention(
    q: jax.Array,            # (B, Hq, D)
    k: jax.Array,            # (B, Hkv, S, D) cache
    v: jax.Array,            # (B, Hkv, S, D)
    length: jax.Array,       # (B,) valid cache entries
    scale: float | None = None,
) -> jax.Array:
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, G, D)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qf, k.astype(jnp.float32))
    valid = jnp.arange(S)[None, :] < length[:, None]          # (B, S)
    logits = jnp.where(valid[:, None, None, :], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD: exact sequential recurrence (the semantic definition).
# ---------------------------------------------------------------------------

def ssd(
    x: jax.Array,            # (B, S, H, P) inputs per head
    dt: jax.Array,           # (B, S, H) softplus'd step sizes (>0)
    A: jax.Array,            # (H,) negative state decay rates
    Bm: jax.Array,           # (B, S, N) input projections (ngroups=1)
    Cm: jax.Array,           # (B, S, N) output projections
) -> jax.Array:
    """y_t = C_t^T h_t;  h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_t^T.

    State h has shape (H, N, P) per batch element. Returns (B, S, H, P).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def scan_one(b):
        def step(h, inp):
            xt, dtt, Bt, Ct = inp                  # (H,P) (H,) (N,) (N,)
            decay = jnp.exp(Af * dtt)              # (H,)
            h = h * decay[:, None, None] + (
                dtt[:, None, None] * Bt[None, :, None] * xt[:, None, :])
            y = jnp.einsum("n,hnp->hp", Ct, h)
            return h, y

        h0 = jnp.zeros((H, N, P), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (xf[b], dtf[b], Bf[b], Cf[b]))
        return ys                                   # (S, H, P)

    out = jax.vmap(scan_one)(jnp.arange(Bsz))
    return out.astype(x.dtype)


def ssd_decode_step(
    h: jax.Array,            # (B, H, N, P) carried state
    x: jax.Array,            # (B, H, P) current token input
    dt: jax.Array,           # (B, H)
    A: jax.Array,            # (H,)
    Bm: jax.Array,           # (B, N)
    Cm: jax.Array,           # (B, N)
):
    """Single-token SSD update (serving decode). Returns (h', y)."""
    decay = jnp.exp(A[None, :] * dt)                          # (B, H)
    h = h * decay[..., None, None] + (
        dt[..., None, None] * Bm[:, None, :, None] * x[:, :, None, :])
    y = jnp.einsum("bn,bhnp->bhp", Cm, h)
    return h, y.astype(x.dtype)


# ---------------------------------------------------------------------------
# KDE success probability (the bandit's per-decision hot spot).
# ---------------------------------------------------------------------------

def kde_success_prob(
    lat: jax.Array,          # (rows, R) latency windows
    mask: jax.Array,         # (rows, R) validity
    tau: float,
    bandwidth: jax.Array,    # (rows,)
) -> jax.Array:
    m = mask.astype(jnp.float32)
    n = m.sum(-1)
    z = (tau - lat.astype(jnp.float32)) / bandwidth[:, None]
    cdf = 0.5 * (1.0 + jax.lax.erf(z * 0.7071067811865476))
    s = (cdf * m).sum(-1)
    return jnp.where(n > 0, s / jnp.maximum(n, 1.0), 0.0)


def bandit_maintenance_stats(
    lat: jax.Array,          # (rows, R) latency windows
    mask: jax.Array,         # (rows, R) validity (bool)
    rtt: jax.Array,          # (rows,) network RTT per row
    tau: float,
    rho: float,
    min_bandwidth: float = 1e-4,
):
    """Fused Alg-1 window stats per (player, arm) row: Silverman
    bandwidth -> Gaussian-CDF success probability at tau, plus the
    masked rho-quantile of the processing component max(lat - rtt, 0).

    Oracle for ``kernels/kde.py::fused_maintenance``. Mirrors the
    repro/core/kde.py composition op-for-op (bit-identical on CPU);
    kept self-contained because importing repro.core here would close a
    core -> kernels -> core cycle. Returns ``(mu (rows,), q (rows,))``.
    """
    latf = lat.astype(jnp.float32)
    m = mask.astype(jnp.float32)

    # Silverman bandwidth h = 1.06 * sigma * n^(-1/5) (core silverman_bandwidth)
    nc = jnp.maximum(m.sum(-1), 1.0)
    mean = (latf * m).sum(-1) / nc
    var = ((latf - mean[..., None]) ** 2 * m).sum(-1) / nc
    sigma = jnp.sqrt(jnp.maximum(var, 0.0))
    h = jnp.maximum(1.06 * sigma * nc ** (-0.2), min_bandwidth)

    # Gaussian-kernel CDF estimate of P(lat <= tau) (core kde_success_prob)
    n = m.sum(-1)
    z = (tau - latf) / h[..., None]
    cdf = 0.5 * (1.0 + jax.lax.erf(z * 0.7071067811865476))
    contrib = (cdf * m).sum(-1)
    mu = jnp.where(n > 0, contrib / jnp.maximum(n, 1.0), 0.0)

    # masked rho-quantile of processing latency (core masked_quantile)
    proc = jnp.maximum(latf - rtt[..., None], 0.0)
    big = jnp.asarray(jnp.finfo(jnp.float32).max, jnp.float32)
    xs = jnp.sort(jnp.where(mask, proc, big), axis=-1)
    ni = mask.sum(-1)
    idx = jnp.clip((rho * (ni - 1)).astype(jnp.int32), 0, lat.shape[-1] - 1)
    val = jnp.take_along_axis(xs, idx[..., None], axis=-1)[..., 0]
    q = jnp.where(ni > 0, val, big)
    return mu, q
