"""Pallas TPU kernel: causal GQA flash attention (prefill path).

Online-softmax tiling (FlashAttention re-thought for TPU): the grid is
(B, Hq, num_q_blocks, num_kv_blocks) with the kv axis innermost and
sequential; running max / normalizer / accumulator live in VMEM scratch
and persist across kv iterations of one q block. Block shapes keep the
MXU busy ((bq, D) x (D, bk) contractions with D in {64, 128, 256}) and
the working set (q, k, v tiles + f32 accumulator) well inside VMEM.

Supports GQA (Hq a multiple of Hkv — the kv block index map folds the
query head onto its kv group) and an optional sliding window, which is
what bounds gemma3/hymba local layers at 500k context.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params are versioned; absent on CPU-only installs
    from jax.experimental.pallas import tpu as pltpu
    _COMPILER_PARAMS = None  # set lazily when running on real TPU
except ImportError:  # pragma: no cover
    pltpu = None
    _COMPILER_PARAMS = None

_NEG = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale, causal, window, bq, bk, nk, seq_len):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip fully-masked blocks (strictly above the causal diagonal /
    # strictly outside the sliding window)
    q_lo = iq * bq
    q_hi = q_lo + bq - 1
    k_lo = ik * bk
    k_hi = k_lo + bk - 1
    live = jnp.asarray(True)
    if causal:
        live &= k_lo <= q_hi
    if window is not None:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # (bq, bk)

        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols < seq_len
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= rows - cols < window
        s = jnp.where(mask, s, _NEG)

        m_prev = m_scr[...]                                # (bq, 1)
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_scr[...] = l_prev * alpha + p.sum(-1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret"))
def flash_attention(
    q: jax.Array,            # (B, Hq, S, D)
    k: jax.Array,            # (B, Hkv, S, D)
    v: jax.Array,            # (B, Hkv, S, D)
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, S)
    bk = min(block_k, S)
    pad_q = (-S) % bq
    pad_k = (-S) % bk
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq, Sk = S + pad_q, S + pad_k
    nq, nk = Sq // bq, Sk // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk, seq_len=S)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S]
