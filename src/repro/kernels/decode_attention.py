"""Pallas TPU kernel: GQA decode attention (one token vs a long KV cache).

Decode is bandwidth-bound: the whole KV cache streams HBM->VMEM once per
step. The grid is (B, Hkv, num_kv_blocks); each step loads one (bk, D)
K/V tile and updates the online softmax for the G = Hq/Hkv query heads
of that kv group, so every byte of cache is read exactly once. The
`length` scalar masks the tail of partially-filled caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30
DEFAULT_BLOCK_K = 512


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale, bk, nk):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    live = ik * bk < length

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (G, bk)
        cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = cols < length
        s = jnp.where(mask, s, _NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "interpret"))
def decode_attention(
    q: jax.Array,            # (B, Hq, D)
    k: jax.Array,            # (B, Hkv, S, D)
    v: jax.Array,            # (B, Hkv, S, D)
    length: jax.Array,       # (B,) i32 valid cache entries
    scale: float | None = None,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bk = min(block_k, S)
    pad = (-S) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = (S + pad) // bk
    qg = q.reshape(B, Hkv, G, D)

    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ik: (b,)),          # length
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(length.astype(jnp.int32), qg, k, v)
    return out.reshape(B, Hq, D)
