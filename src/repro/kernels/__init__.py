"""Pallas TPU kernels for the serving substrate's compute hot spots.

Layout (per the repo convention): ``<name>.py`` holds the
``pl.pallas_call`` + BlockSpec kernel, ``ops.py`` the jit'd dispatching
wrappers, ``ref.py`` the pure-jnp oracles.

Kernels:
  flash_attention — causal GQA prefill attention (online softmax tiles)
  decode_attention — one-token GQA attention vs long KV caches
  ssd             — Mamba-2 chunked state-space scan
  kde             — the paper's QoS-estimation hot spot, fused CDF-sum
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
