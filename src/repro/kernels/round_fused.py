"""Pallas TPU megakernel: one simulator step's C SWRR rounds, fused.

The per-round hot path (SWRR selection -> shared-queue recursion ->
feedback control -> ring write) is ~a dozen XLA ops whose (K, M) and
(K, M, R) intermediates round-trip HBM every round. This kernel runs
the whole step with the bandit block resident in VMEM:

  grid = (C, nb), ROUND-major (block index fastest): for each round r,
  every player block b executes in sequence. TPU grids are sequential
  and revisited output blocks keep their contents, so a block's
  weights / SWRR credits / error counters / cooldowns / pool bits /
  latency+reward rings live in its VMEM output window across all C
  rounds — they are read from HBM once (the r == 0 copy-in) and
  written once.

  The cross-player coupling — same-round requests from every block
  land on the shared (M,) queues — rides in three (1, M) outputs with
  constant index maps, visible to every grid step: ``arr_round``
  accumulates the current round's arrivals block by block and the LAST
  block of each round applies the queue drain, so round r+1's blocks
  observe exactly the queue state the unfused scan computes.

Gathers and scatters become onehot-masked selects (sum of one value
plus exact zeros; compare-select writes), which is what makes the
kernel bit-identical to the jnp oracle (``ref.round_step_swrr``) —
the ring writes follow the sequential per-round semantics of
``core.bandit.record``, the proven equivalent of the oracle's batch
scatter (tests/test_bandit_batch.py).

What stays OUTSIDE the kernel, by design: the per-step PRNG batch (a
pure (C, K) function of the step key, shared with the oracle), the
per-round (M,) arrival psum under player sharding (a collective cannot
live inside a pallas_call — sharded runs fall back to the unfused
scan), and the MetricAccumulator reduction (cross-player histograms
over the (K, C) outputs this kernel emits; O(K*C) per step, nothing to
win in VMEM). See docs/ARCHITECTURE.md.

Bool blocks (in_pool, active) follow the kde.py precedent: passed
as-is, i1 support caveat documented there. CI locks interpret mode;
the compiled path is auto-gated to TPU backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import RoundStepOut

BLOCK_K = 64    # (bk, M, R) f32 ring blocks, in+out, lane-padded: ~7 MB
                # of VMEM at M=50, R=64 — comfortably under the ~16 MB/core


def _round_kernel(tau, err_thresh, cooldown, nb,
                  # inputs
                  t_ref, nc_ref, z_ref, rtt_ref, sm_ref, served_ref,
                  act_ref, w_in, cw_in, err_in, cd_in, pool_in,
                  lat_in, ts_in, ptr_in, rb_in, rts_in, rp_in, q_in,
                  # outputs
                  w_o, cw_o, err_o, cd_o, pool_o,
                  lat_o, ts_o, ptr_o, rb_o, rts_o, rp_o,
                  q_o, arr_o, arrtot_o, ch_o, latv_o, proc_o):
    r = pl.program_id(0)
    b = pl.program_id(1)

    # --- copy-in: the block's state enters VMEM once, at round 0 ---
    @pl.when(r == 0)
    def _():
        w_o[...] = w_in[...]
        cw_o[...] = cw_in[...]
        err_o[...] = err_in[...]
        cd_o[...] = cd_in[...]
        pool_o[...] = pool_in[...]
        lat_o[...] = lat_in[...]
        ts_o[...] = ts_in[...]
        ptr_o[...] = ptr_in[...]
        rb_o[...] = rb_in[...]
        rts_o[...] = rts_in[...]
        rp_o[...] = rp_in[...]

    @pl.when((r == 0) & (b == 0))
    def _():
        q_o[...] = q_in[...]
        arrtot_o[...] = jnp.zeros_like(arrtot_o)

    @pl.when(b == 0)
    def _():
        arr_o[...] = jnp.zeros_like(arr_o)

    t = t_ref[0]
    w = w_o[...]
    cw = cw_o[...]
    bk, M = w.shape
    R = lat_o.shape[2]
    Rq = rb_o.shape[1]
    mask = r < nc_ref[..., 0]                           # (bk,)
    q = q_o[0, :]                                       # (M,)
    z_r = z_ref[0, :]                                   # (bk,)
    arm = jax.lax.broadcasted_iota(jnp.int32, (bk, M), 1)

    # --- SWRR selection (core.swrr.swrr_select) ---
    total = jnp.sum(w, axis=-1, keepdims=True)
    cw = cw + w
    choice = jnp.argmax(cw, axis=-1)                    # (bk,)
    onehot = choice[:, None] == arm                     # (bk, M) bool
    onehot_f = onehot.astype(cw.dtype)
    cw = cw - onehot_f * total

    # --- latency: gathers as onehot-selects (exact) ---
    q_seen = jnp.sum(jnp.where(onehot, q[None, :], 0.0), axis=-1)
    s_sel = jnp.sum(jnp.where(onehot, sm_ref[...], 0.0), axis=-1)
    proc = (q_seen + 1.0) * s_sel * z_r
    rtt_sel = jnp.sum(jnp.where(onehot, rtt_ref[...], 0.0), axis=-1)
    lat = rtt_sel + proc

    # --- feedback control (core.bandit._record_control) ---
    reward = (lat <= tau).astype(jnp.float32)
    err_b = err_o[...]
    old_err = jnp.sum(jnp.where(onehot, err_b, 0), axis=-1)
    new_err = jnp.where(reward > 0, 0, old_err + 1).astype(jnp.int32)
    trip = mask & (new_err >= err_thresh)
    err_val = jnp.where(mask, jnp.where(trip, 0, new_err), old_err)
    err_o[...] = jnp.where(onehot, err_val[:, None], err_b)
    cd_b = cd_o[...]
    cd_old = jnp.sum(jnp.where(onehot, cd_b, 0.0), axis=-1)
    cd_val = jnp.where(trip, t + cooldown, cd_old)
    cd_o[...] = jnp.where(onehot, cd_val[:, None], cd_b)
    tripped = onehot & trip[:, None]
    pool = pool_o[...] & ~tripped
    pool_o[...] = pool
    act_row = act_ref[...]                              # (1, M)
    w2 = jnp.where(tripped, 0.0, w)
    wsum = jnp.sum(w2, axis=-1, keepdims=True)
    remaining = pool & act_row
    rem_any = jnp.any(remaining, axis=-1, keepdims=True)
    fallback = jnp.where(rem_any, remaining,
                         act_row & ~tripped).astype(jnp.float32)
    fallback = fallback / jnp.maximum(
        jnp.sum(fallback, axis=-1, keepdims=True), 1.0)
    w_o[...] = jnp.where(wsum > 0, w2 / jnp.maximum(wsum, 1e-30), fallback)
    cw_o[...] = jnp.where(tripped, 0.0, cw)

    # --- ring writes, sequential `core.bandit.record` semantics ---
    ptr_b = ptr_o[...]                                  # (bk, M) i32
    p_sel = jnp.sum(jnp.where(onehot, ptr_b, 0), axis=-1)
    slot = jax.lax.broadcasted_iota(jnp.int32, (bk, M, R), 2)
    wr = (onehot & mask[:, None])[:, :, None] & (slot == p_sel[:, None, None])
    lat_o[...] = jnp.where(wr, lat[:, None, None], lat_o[...])
    ts_o[...] = jnp.where(wr, t, ts_o[...])
    ptr_o[...] = jnp.where(onehot & mask[:, None], (ptr_b + 1) % R, ptr_b)
    rp_b = rp_o[..., 0]                                 # (bk,)
    rq_slot = jax.lax.broadcasted_iota(jnp.int32, (bk, Rq), 1)
    wrr = (rq_slot == rp_b[:, None]) & mask[:, None]
    rb_o[...] = jnp.where(wrr, reward[:, None], rb_o[...])
    rts_o[...] = jnp.where(wrr, t, rts_o[...])
    rp_o[...] = jnp.where(mask, (rp_b + 1) % Rq, rp_b)[:, None]

    # --- per-request outputs ---
    ch_o[...] = choice[:, None]
    latv_o[...] = lat[:, None]
    proc_o[...] = proc[:, None]

    # --- shared-queue coupling: accumulate this block's arrivals;
    # the round's LAST block applies the drain so round r+1 reads the
    # exact queue state the unfused scan computes ---
    arr_blk = jnp.sum(
        jnp.where(onehot & mask[:, None], 1.0, 0.0), axis=0)   # (M,)
    arr_o[...] = arr_o[...] + arr_blk[None, :]
    arrtot_o[...] = arrtot_o[...] + arr_blk[None, :]

    @pl.when(b == nb - 1)
    def _():
        q_o[...] = jnp.maximum(
            q_o[...] + arr_o[...] - served_ref[...], 0.0)


@functools.partial(
    jax.jit,
    static_argnames=("tau", "err_thresh", "cooldown", "interpret",
                     "block_k"))
def round_step_swrr(
    weights, cw, err, cooldown_until, in_pool, active,
    lat_buf, ts_buf, ptr, r_buf, rts_buf, rptr,
    q, nc, z, rtt_t, s_m, served_per_round, t,
    tau: float, err_thresh: int, cooldown: float,
    interpret: bool = False, block_k: int = BLOCK_K,
) -> RoundStepOut:
    """Pallas round megakernel; same contract as ``ref.round_step_swrr``.

    Pads the player axis to a block multiple (padded rows carry nc=0 /
    zero weights, so they issue nothing and their state is sliced off).
    """
    K, M, R = lat_buf.shape
    C = z.shape[0]
    Rq = r_buf.shape[1]
    bk = min(block_k, K)
    pad = (-K) % bk
    if pad:
        p2 = ((0, pad), (0, 0))
        weights = jnp.pad(weights, p2)
        cw = jnp.pad(cw, p2)
        err = jnp.pad(err, p2)
        cooldown_until = jnp.pad(cooldown_until, p2)
        in_pool = jnp.pad(in_pool, p2)
        lat_buf = jnp.pad(lat_buf, ((0, pad), (0, 0), (0, 0)))
        ts_buf = jnp.pad(ts_buf, ((0, pad), (0, 0), (0, 0)))
        ptr = jnp.pad(ptr, p2)
        r_buf = jnp.pad(r_buf, p2)
        rts_buf = jnp.pad(rts_buf, p2)
        rptr = jnp.pad(rptr, (0, pad))
        nc = jnp.pad(nc, (0, pad))
        z = jnp.pad(z, ((0, 0), (0, pad)), constant_values=1.0)
        rtt_t = jnp.pad(rtt_t, p2)
    Kp = K + pad
    nb = Kp // bk
    t_arr = jnp.asarray(t, jnp.float32).reshape(1)

    state_spec = pl.BlockSpec((bk, M), lambda r, b: (b, 0))
    ring_spec = pl.BlockSpec((bk, M, R), lambda r, b: (b, 0, 0))
    rring_spec = pl.BlockSpec((bk, Rq), lambda r, b: (b, 0))
    col_spec = pl.BlockSpec((bk, 1), lambda r, b: (b, 0))
    row_spec = pl.BlockSpec((1, M), lambda r, b: (0, 0))
    out_col_spec = pl.BlockSpec((bk, 1), lambda r, b: (b, r))

    outs = pl.pallas_call(
        functools.partial(_round_kernel, float(tau), int(err_thresh),
                          float(cooldown), nb),
        grid=(C, nb),
        in_specs=[
            pl.BlockSpec((1,), lambda r, b: (0,)),               # t
            col_spec,                                            # nc
            pl.BlockSpec((1, bk), lambda r, b: (r, b)),          # z
            state_spec,                                          # rtt
            row_spec,                                            # s_m
            row_spec,                                            # served
            row_spec,                                            # active
            state_spec, state_spec, state_spec, state_spec,      # w cw err cd
            state_spec,                                          # in_pool
            ring_spec, ring_spec, state_spec,                    # lat ts ptr
            rring_spec, rring_spec, col_spec,                    # rb rts rptr
            row_spec,                                            # q
        ],
        out_specs=(
            state_spec, state_spec, state_spec, state_spec, state_spec,
            ring_spec, ring_spec, state_spec,
            rring_spec, rring_spec, col_spec,
            row_spec, row_spec, row_spec,
            out_col_spec, out_col_spec, out_col_spec,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((Kp, M), jnp.float32),          # weights
            jax.ShapeDtypeStruct((Kp, M), jnp.float32),          # cw
            jax.ShapeDtypeStruct((Kp, M), jnp.int32),            # err
            jax.ShapeDtypeStruct((Kp, M), jnp.float32),          # cooldown
            jax.ShapeDtypeStruct((Kp, M), jnp.bool_),            # in_pool
            jax.ShapeDtypeStruct((Kp, M, R), jnp.float32),       # lat_buf
            jax.ShapeDtypeStruct((Kp, M, R), jnp.float32),       # ts_buf
            jax.ShapeDtypeStruct((Kp, M), jnp.int32),            # ptr
            jax.ShapeDtypeStruct((Kp, Rq), jnp.float32),         # r_buf
            jax.ShapeDtypeStruct((Kp, Rq), jnp.float32),         # rts_buf
            jax.ShapeDtypeStruct((Kp, 1), jnp.int32),            # rptr
            jax.ShapeDtypeStruct((1, M), jnp.float32),           # q
            jax.ShapeDtypeStruct((1, M), jnp.float32),           # arr_round
            jax.ShapeDtypeStruct((1, M), jnp.float32),           # arrivals
            jax.ShapeDtypeStruct((Kp, C), jnp.int32),            # choices
            jax.ShapeDtypeStruct((Kp, C), jnp.float32),          # lats
            jax.ShapeDtypeStruct((Kp, C), jnp.float32),          # procs
        ),
        interpret=interpret,
    )(t_arr, nc[:, None], z, rtt_t, s_m[None, :],
      served_per_round[None, :], active[None, :],
      weights, cw, err, cooldown_until, in_pool,
      lat_buf, ts_buf, ptr, r_buf, rts_buf, rptr[:, None], q[None, :])

    (w_o, cw_o, err_o, cd_o, pool_o, lat_o, ts_o, ptr_o, rb_o, rts_o,
     rp_o, q_o, _arr_round, arrtot_o, ch_o, latv_o, proc_o) = outs
    return RoundStepOut(
        w_o[:K], cw_o[:K], err_o[:K], cd_o[:K], pool_o[:K],
        lat_o[:K], ts_o[:K], ptr_o[:K], rb_o[:K], rts_o[:K], rp_o[:K, 0],
        q_o[0], arrtot_o[0], ch_o[:K], latv_o[:K], proc_o[:K])
