"""Elastic re-meshing: rebuild the mesh after device loss/gain and
re-shard live state onto it.

Failure model: a pod (or a data-axis slice) disappears. The runtime
 1. builds a new mesh from the surviving devices (shrinking the data
    axis — the model axis must stay intact since TP shards are not
    recoverable without a checkpoint),
 2. re-device_puts params/optimizer state onto the new mesh (or
    restores from the last checkpoint via Checkpointer.restore with the
    new shardings),
 3. tells the router (paper Alg 4) so traffic stops flowing to the dead
    replicas immediately — ``serving.QEdgeRouter.mesh_resized`` feeds
    ``surviving_replicas`` into the router's active mask — and
 4. resumes; when capacity returns, Alg 3 ramps it back gradually.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.sharding import logical_to_spec, tree_shardings


def build_mesh(devices: Sequence, model_axis: int,
               pod_axis: Optional[int] = None) -> Mesh:
    """Arrange surviving devices into (pod?, data, model)."""
    devs = np.asarray(devices)
    n = devs.size
    if n % model_axis:
        raise ValueError(f"{n} devices not divisible by model={model_axis}")
    rows = n // model_axis
    if pod_axis:
        if rows % pod_axis:
            raise ValueError(f"data rows {rows} not divisible by pod={pod_axis}")
        shape = (pod_axis, rows // pod_axis, model_axis)
        names = ("pod", "data", "model")
    else:
        shape = (rows, model_axis)
        names = ("data", "model")
    return Mesh(devs.reshape(shape), names)


def shrink_mesh(mesh: Mesh, lost_data_rows: int) -> Mesh:
    """Drop the last `lost_data_rows` rows of the data axis."""
    devs = np.asarray(mesh.devices)
    names = mesh.axis_names
    data_idx = names.index("data")
    keep = devs.shape[data_idx] - lost_data_rows
    if keep < 1:
        raise ValueError("cannot shrink data axis below 1")
    sl = [slice(None)] * devs.ndim
    sl[data_idx] = slice(0, keep)
    return Mesh(devs[tuple(sl)], names)


def reshard_state(state, axes_tree, new_mesh: Mesh):
    """device_put every leaf onto the new mesh per its logical axes.

    Works for any pytree whose logical-axes mirror exists (params, opt
    state, bandit state); data on lost devices must already be
    replicated or re-readable (params under DP are; purely data-sharded
    tensors come back from the data pipeline instead).
    """
    shardings = tree_shardings(axes_tree, new_mesh)
    return jax.tree.map(jax.device_put, state, shardings)


def surviving_replicas(old_rows: int, new_rows: int):
    """Replica liveness vector for the router after a shrink (Alg 4)."""
    alive = np.zeros((old_rows,), bool)
    alive[:new_rows] = True
    return alive
