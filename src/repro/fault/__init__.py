"""Fault tolerance: elastic re-meshing + router-driven failover."""
from repro.fault.elastic import (
    build_mesh,
    reshard_state,
    shrink_mesh,
    surviving_replicas,
)

__all__ = ["build_mesh", "shrink_mesh", "reshard_state",
           "surviving_replicas"]
