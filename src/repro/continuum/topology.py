"""CC topology emulation (paper §VII-A1).

The paper samples 30 European cities and uses WonderNetwork RTTs; those
measurements are not redistributable, so we generate RTT matrices with a
distance model calibrated to the same range (≈2–45 ms intra-Europe):
cities are uniform in a 2400×1800 km box, RTT = 3 ms base + 0.014 ms/km
great-circle-ish distance + mild pairwise jitter. Placement uses the
paper's greedy k-center on network distance (§VII-A3).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Topology(NamedTuple):
    rtt: jax.Array          # (N, N) seconds, symmetric, zero diagonal
    instance_nodes: jax.Array  # (M,) node index hosting each instance

    @property
    def num_nodes(self) -> int:
        return self.rtt.shape[0]

    @property
    def num_instances(self) -> int:
        return self.instance_nodes.shape[0]

    def lb_instance_rtt(self) -> jax.Array:
        """(N, M) RTT from every LB (one per node) to every instance."""
        return self.rtt[:, self.instance_nodes]


def european_rtt_matrix(
    key: jax.Array,
    n_nodes: int = 30,
    base_ms: float = 3.0,
    ms_per_km: float = 0.014,
    jitter_ms: float = 2.0,
    box_km=(2400.0, 1800.0),
    n_clusters: int = 6,
    cluster_sigma_km: float = 140.0,
) -> jax.Array:
    """Synthetic but realistically-ranged European RTT matrix [seconds].

    Nodes cluster around metro areas (clusters drawn uniformly in the
    box, per-cluster population Zipf-skewed). Clustering matters: it is
    what makes several nodes share one nearest instance — the overload
    mode the paper's proxy-mity baseline exhibits (§VII-B).
    """
    kp, kj, kc, ka = jax.random.split(key, 4)
    centers = jax.random.uniform(kc, (n_clusters, 2)) * jnp.asarray(box_km)
    # Zipf-ish cluster popularity
    pop = 1.0 / (1.0 + jnp.arange(n_clusters))
    assign = jax.random.categorical(
        ka, jnp.log(pop)[None, :].repeat(n_nodes, 0))   # (n_nodes,)
    pos = centers[assign] + cluster_sigma_km * jax.random.normal(kp, (n_nodes, 2))
    d = jnp.linalg.norm(pos[:, None, :] - pos[None, :, :], axis=-1)
    jit = jax.random.uniform(kj, (n_nodes, n_nodes)) * jitter_ms
    jit = (jit + jit.T) / 2.0
    rtt_ms = base_ms + ms_per_km * d + jit
    rtt_ms = rtt_ms * (1.0 - jnp.eye(n_nodes))      # zero self-RTT
    return rtt_ms / 1e3


def k_center_placement(rtt: np.ndarray, n_instances: int) -> np.ndarray:
    """Greedy k-center (paper §VII-A3): iteratively pick the node
    farthest (in network distance) from the chosen centers."""
    rtt = np.asarray(rtt)
    n = rtt.shape[0]
    centers = [int(np.argmin(rtt.sum(1)))]          # start at the medoid
    while len(centers) < n_instances:
        d = rtt[:, centers].min(axis=1)
        d[centers] = -1.0
        centers.append(int(np.argmax(d)))
    return np.asarray(sorted(centers), dtype=np.int32)


def make_topology(
    key: jax.Array,
    n_nodes: int = 30,
    n_instances: int = 10,
) -> Topology:
    rtt = european_rtt_matrix(key, n_nodes)
    placement = k_center_placement(np.asarray(rtt), n_instances)
    return Topology(rtt=rtt, instance_nodes=jnp.asarray(placement))
