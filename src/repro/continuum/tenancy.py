"""Multi-tenant continuum: S services sharing ONE instance fleet.

The paper's engine is single-service; real edge infrastructures host
coexisting applications competing for the same nodes. ``TenancyConfig``
widens the simulator to S tenants, each with its own QoS target tau_s,
its own client population (a per-tenant ``n_clients`` schedule in the
drivers) and its own bandit fleet riding the scan carry — while the
instance queues, the activity mask and the RTT fabric stay shared.

The queue recursion gains a leading service axis: ``q`` becomes
``(S, M)``, a request's position in line is the TOTAL backlog
``q.sum(0)`` at its instance, and the per-step drain is
work-conserving processor sharing across tenants. Cross-service
interference folds into the effective service-time row::

    s_eff[s, m] = s_m[m] * service_scale[s]
                  * (1 + interference * q_other[s, m] / (1 + q_tot[m]))

so a tenant's requests slow down in proportion to the share of the
instance backlog OTHER tenants hold (cache/NIC contention that queue
positions alone don't capture). ``interference=0`` makes tenants
couple only through queue length and capacity.

Gating is static Python config, exactly like the resilience/control/
recorder layers: ``tenancy=None`` — or a degenerate S=1 config — keeps
the engine on the untouched single-service path, so the pre-tenant
program lowers byte-identically (locked by ``tests/test_tenancy.py``).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TenancyConfig:
    """Static description of the S services sharing the fleet.

    taus           per-tenant QoS deadlines [s]; ``len(taus)`` is S and
                   tenant s succeeds iff latency <= taus[s].
    service_scale  per-tenant demand multiplier on the instance service
                   time (a tenant whose requests are 2x heavier has
                   scale 2.0); ``None`` means all 1.0.
    interference   cross-service coupling coefficient xi >= 0: how much
                   a tenant's effective service time inflates per unit
                   share of *other* tenants' backlog on the instance.
    """
    taus: tuple[float, ...]
    service_scale: tuple[float, ...] | None = None
    interference: float = 0.0

    def __post_init__(self):
        if not self.taus:
            raise ValueError("TenancyConfig needs at least one tenant tau")
        if any(t <= 0.0 for t in self.taus):
            raise ValueError(f"tenant taus must be positive: {self.taus}")
        if self.service_scale is not None:
            if len(self.service_scale) != len(self.taus):
                raise ValueError(
                    f"service_scale has {len(self.service_scale)} entries "
                    f"for {len(self.taus)} tenants")
            if any(s <= 0.0 for s in self.service_scale):
                raise ValueError(
                    f"service_scale must be positive: {self.service_scale}")
        if self.interference < 0.0:
            raise ValueError(
                f"interference must be >= 0: {self.interference}")

    @property
    def S(self) -> int:
        return len(self.taus)

    @property
    def enabled(self) -> bool:
        """S >= 2 turns the tenant engine on; an S=1 config is
        degenerate and stays on the single-service path."""
        return self.S >= 2

    @property
    def scales(self) -> tuple[float, ...]:
        return tuple(float(s) for s in (self.service_scale
                                        or (1.0,) * self.S))


def tenancy_enabled(cfg) -> bool:
    """True iff ``cfg.tenancy`` switches the engine onto the
    multi-tenant path (None and S=1 both stay single-service)."""
    tn = getattr(cfg, "tenancy", None)
    return tn is not None and tn.enabled


def tenancy_size(cfg) -> int:
    """S when the tenant engine is on, else 0 (single-service path)."""
    return cfg.tenancy.S if tenancy_enabled(cfg) else 0
