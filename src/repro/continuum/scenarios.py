"""Declarative non-stationarity: scenarios compile to driver arrays.

The paper's headline claims are about *dynamics* — adapting to load
surges and changes in instance availability (§VII-C/D, Figs 10/11) —
and the related systems (QEdgeProxy's CC testbed, dense-network
offloading studies) stress churn, mobility-driven RTT drift and
heterogeneous server speeds as the regimes where per-client QoS
balancers differentiate. This module makes those regimes *declarative*:

* a :class:`Scenario` is a topology spec (node/instance/client counts)
  plus an ordered tuple of typed timeline events;
* :func:`compile_scenario` lowers the event list into dense per-step
  :class:`Drivers` arrays — the only interface the simulator sees. The
  engine never knows about events; it consumes ``(T, ·)`` schedules, so
  every scenario batches/vmaps/shards exactly like the constant-filled
  arrays did (`build_sim_grid_fn` takes a stacked ``(S, ·)`` batch).

Driver model (per step ``t``):

* ``n_clients[t]  (K,) i32``  — active client slots per LB (clipped to
  ``cfg.max_clients``); shaped by ``LoadSurge`` / ``DiurnalWave`` /
  ``ClientChurn``.
* ``active[t]     (M,) bool`` — instance liveness; shaped by
  ``InstanceKill`` / ``InstanceRestore`` / ``Autoscale``. The compiler
  rejects schedules where every instance is down at once.
* ``rtt_scale[t]  (M,) f32``  — multiplicative per-instance-column RTT
  scale (``RttDrift`` scales all columns — mobility-style drift;
  ``LinkDegrade`` scales selected columns). Effective RTT is
  ``rtt * rtt_scale[t][None, :] + cut``.
* ``rtt_cut_k[t] (K,) / rtt_cut_m[t] (M,) f32`` — the factored
  partition term: ``cut[k, m] = min(rtt_cut_k[k], rtt_cut_m[m])``, so
  a ``Partition`` marks its LB side and instance side with the penalty
  and only the *intersection* pays it (a rank-1 AND without ever
  materializing a (T, K, M) tensor). Temporally overlapping
  partitions with different sides also cut the cross routes between
  them — ``compile_scenario`` warns when a scenario does that (the
  library keeps partitions disjoint in time).
* ``s_m[t]        (M,) f32``  — per-instance service time;
  ``ServiceSlowdown`` throttles subsets (rolling through a window or
  statically heterogeneous hardware).
* ``marks (E,) i32`` — event-onset step indices, ``-1``-padded to
  :data:`MAX_MARKS` so scenario batches stack. The streaming
  accumulator keys its time-to-recover windows off these (see
  ``metrics.MetricAccumulator.ev_succ``).

Compilation is host-side (numpy) and deterministic under a fixed PRNG
key: stochastic events (LB selection, churn walks) derive their
randomness from ``jax.random.fold_in(key, event_index)``, never from
global state.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from dataclasses import dataclass
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# Fixed mark-array width so compiled scenarios stack into grid batches
# regardless of how many events each one carries; -1 is the "no event"
# sentinel the accumulator drops.
MAX_MARKS = 32
# Floor for per-instance service time after all slowdowns compose
# (s_m must stay positive: the queue drains at dt / s_m).
MIN_SERVICE_TIME = 1e-4


class Drivers(NamedTuple):
    """Dense per-step schedules driving one simulation — THE contract
    between the scenario compiler and the engine.

    This pytree is the *only* interface the simulator sees: events
    never reach the scan; ``compile_scenario`` lowers them to these
    arrays, and every driver path (`run_sim`, `run_sim_stream`,
    `run_sim_grid`, `run_sim_players`, chunked scans) consumes one row
    per step. Per step ``t`` the engine computes the effective RTT

        ``rtt_t = rtt * rtt_scale[t][None, :]
                  + min(rtt_cut_k[t][:, None], rtt_cut_m[t][None, :])``

    (the caller's ``rtt`` is the *base* matrix; the ``min`` is the
    factored rank-1 partition AND — only LB-side ∩ instance-side
    routes pay the cut) and threads ``rtt_t`` plus the ``s_m[t]``
    service row through placement events, maintenance, the true-μ
    oracle and the queue recursion. ``n_clients[t]`` bounds the
    request rounds per LB; ``active[t]`` drives Alg 3/4 placement
    events on change. ``marks`` holds event-onset *global* step
    indices (``-1``-padded to :data:`MAX_MARKS`) keying the streaming
    accumulator's recovery windows.

    Invariants the engine trusts blindly and ``compile_scenario``
    enforces: ``0 <= n_clients <= cfg.max_clients``, ``s_m >=
    MIN_SERVICE_TIME``, ``rtt_scale > 0``, cuts ``>= 0``, and at least
    one live instance every step.

    Shapes and layout: all leading axes are T (``marks`` excepted); a
    scenario *batch* is the same pytree with an extra leading (S,)
    lane axis (`stack_drivers`), sharded over the ``data`` mesh axis
    by the evaluation grid; a *player-sharded* run splits the (·, K)
    fields (``n_clients``, ``rtt_cut_k``) over the ``players`` axis
    and replicates the (·, M) fields — see
    ``simulator._stream_specs``. ``neutral_drivers`` produces the
    identity schedules (constant clients, all instances live, scale 1,
    cut 0, constant ``s_m``) that reproduce the pre-scenario engine
    bit-for-bit; ``slice_drivers`` time-slices the per-step fields for
    chunked horizons (marks ride whole — they are global indices,
    like the scan's ``t_idx``).
    """
    n_clients: jax.Array   # (T, K) i32 active client slots per LB
    active: jax.Array      # (T, M) bool instance liveness
    rtt_scale: jax.Array   # (T, M) f32 multiplicative column RTT scale
    rtt_cut_k: jax.Array   # (T, K) f32 partition penalty, LB side [s]
    rtt_cut_m: jax.Array   # (T, M) f32 partition penalty, instance side [s]
    s_m: jax.Array         # (T, M) f32 per-instance service time [s]
    marks: jax.Array       # (E,)  i32 event-onset steps, -1 padded


# Fields with a leading time axis (everything but marks): the chunked
# driver slices exactly these.
STEP_FIELDS = ("n_clients", "active", "rtt_scale", "rtt_cut_k",
               "rtt_cut_m", "s_m")


def slice_drivers(drv: Drivers, lo: int, hi: int) -> Drivers:
    """Time-slice the per-step fields; marks stay whole (they are
    global step indices, like the scan's ``t_idx``)."""
    return drv._replace(**{f: getattr(drv, f)[lo:hi] for f in STEP_FIELDS})


def stack_drivers(drivers: Sequence[Drivers]) -> Drivers:
    """Stack compiled scenarios into an (S, ·) batch for the grid."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *drivers)


def neutral_drivers(cfg, K: int, M: int,
                    n_clients: jax.Array | None = None,
                    active: jax.Array | None = None,
                    base_clients: int = 4,
                    service_time: float | None = None) -> Drivers:
    """Constant-filled drivers — the pre-scenario-engine behaviour.

    ``n_clients``/``active`` override the constant fill (legacy kwarg
    paths); modulation fields are identities (scale 1, cut 0), so the
    engine computes bit-for-bit what it did before drivers existed.
    """
    T = cfg.num_steps
    if n_clients is None:
        n_clients = jnp.full((T, K), base_clients, jnp.int32)
    if active is None:
        active = jnp.ones((T, M), bool)
    s = cfg.service_time if service_time is None else service_time
    return Drivers(
        n_clients=n_clients,
        active=active,
        rtt_scale=jnp.ones((T, M), jnp.float32),
        rtt_cut_k=jnp.zeros((T, K), jnp.float32),
        rtt_cut_m=jnp.zeros((T, M), jnp.float32),
        s_m=jnp.full((T, M), s, jnp.float32),
        marks=jnp.full((MAX_MARKS,), -1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# Events. Each event edits the (numpy) driver arrays over its window
# and reports its onset step(s) as recovery-metric marks. Events apply
# in scenario order, so later events compose on top of earlier ones
# (a ServiceSlowdown over a LinkDegrade multiplies both effects).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Event:
    start: float = 0.0          # event onset [s]

    def marks(self, cfg) -> list[int]:
        return [int(round(self.start / cfg.dt))]

    def apply(self, arrs: dict, cfg, K: int, M: int, key) -> None:
        raise NotImplementedError


def _window(cfg, T: int, start: float, stop: float) -> tuple[int, int]:
    lo = max(0, min(T, int(round(start / cfg.dt))))
    hi = T if math.isinf(stop) else max(lo, min(T, int(round(stop / cfg.dt))))
    return lo, hi


def _pick(key, n: int, count: int, explicit) -> np.ndarray:
    """Explicit index tuple, or a key-deterministic choice of `count`."""
    if explicit is not None:
        return np.asarray(explicit, np.int32)
    count = max(1, min(n, count))
    return np.asarray(jax.random.choice(key, n, (count,), replace=False),
                      np.int32)


@dataclass(frozen=True)
class LoadSurge(Event):
    """Extra clients on a subset of LBs in [start, stop); optional
    linear ramp-in over ``ramp`` seconds (flash crowds ramp, step
    surges don't)."""
    stop: float = math.inf
    extra: int = 2
    lbs: tuple[int, ...] | None = None   # explicit LB ids, else…
    fraction: float = 0.5                # …key-chosen fraction of K
    ramp: float = 0.0

    def apply(self, arrs, cfg, K, M, key):
        lo, hi = _window(cfg, arrs["T"], self.start, self.stop)
        sel = _pick(key, K, int(round(self.fraction * K)), self.lbs)
        t = (np.arange(lo, hi) - lo) * cfg.dt
        f = np.clip(t / self.ramp, 0.0, 1.0) if self.ramp > 0 else np.ones_like(t)
        arrs["n_clients"][lo:hi, sel] += np.rint(
            self.extra * f)[:, None].astype(np.int64)


@dataclass(frozen=True)
class DiurnalWave(Event):
    """Fleet-wide sinusoidal load: ±amplitude clients on every LB."""
    stop: float = math.inf
    period: float = 60.0
    amplitude: float = 2.0
    phase: float = 0.0           # fraction of a period

    def apply(self, arrs, cfg, K, M, key):
        lo, hi = _window(cfg, arrs["T"], self.start, self.stop)
        t = (np.arange(lo, hi) - lo) * cfg.dt
        delta = np.rint(self.amplitude * np.sin(
            2.0 * np.pi * (t / self.period + self.phase))).astype(np.int64)
        arrs["n_clients"][lo:hi] += delta[:, None]


@dataclass(frozen=True)
class ClientChurn(Event):
    """Per-LB clamped random walk: each step a client joins/leaves an
    LB with probability ``rate * dt`` each, clamped to ±max_delta
    around the base level (mobile clients roaming in and out)."""
    stop: float = math.inf
    rate: float = 0.5            # churn events per LB per second
    max_delta: int = 2

    def marks(self, cfg) -> list[int]:
        return []                # continuous churn has no onset to recover from

    def apply(self, arrs, cfg, K, M, key):
        lo, hi = _window(cfg, arrs["T"], self.start, self.stop)
        n = hi - lo
        if n <= 0:
            return
        p = min(0.5, self.rate * cfg.dt)
        u = np.asarray(jax.random.uniform(key, (n, K)))
        step = np.where(u < p, -1, np.where(u > 1.0 - p, 1, 0))
        walk = np.empty((n, K), np.int64)
        acc = np.zeros((K,), np.int64)
        for i in range(n):       # host-side compile: a true clamped walk
            acc = np.clip(acc + step[i], -self.max_delta, self.max_delta)
            walk[i] = acc
        arrs["n_clients"][lo:hi] += walk


@dataclass(frozen=True)
class InstanceKill(Event):
    """Instances go dark in [start, stop) (inf = never restored)."""
    stop: float = math.inf
    instances: tuple[int, ...] = (0,)

    def apply(self, arrs, cfg, K, M, key):
        lo, hi = _window(cfg, arrs["T"], self.start, self.stop)
        arrs["active"][lo:hi, np.asarray(self.instances)] = False


@dataclass(frozen=True)
class InstanceRestore(Event):
    """Instances come (back) online from ``start`` on — composes over
    an earlier open-ended InstanceKill."""
    instances: tuple[int, ...] = (0,)

    def apply(self, arrs, cfg, K, M, key):
        lo, _ = _window(cfg, arrs["T"], self.start, math.inf)
        arrs["active"][lo:, np.asarray(self.instances)] = True


@dataclass(frozen=True)
class Autoscale(Event):
    """Staggered capacity change: the listed instances come online
    ("up") or drain ("down") one at a time, evenly spaced across
    [start, stop]. "up" instances are offline from t=0 until their
    onset — they are the new replicas the autoscaler adds."""
    stop: float = 60.0
    instances: tuple[int, ...] = (0,)
    direction: str = "up"

    def _onsets(self, cfg) -> list[tuple[int, float]]:
        n = len(self.instances)
        span = max(self.stop - self.start, 0.0)
        return [(inst, self.start + span * i / max(n - 1, 1))
                for i, inst in enumerate(self.instances)]

    def marks(self, cfg) -> list[int]:
        return [int(round(t / cfg.dt)) for _, t in self._onsets(cfg)]

    def apply(self, arrs, cfg, K, M, key):
        if self.direction not in ("up", "down"):
            raise ValueError(f"Autoscale direction {self.direction!r}")
        T = arrs["T"]
        for inst, t in self._onsets(cfg):
            at = max(0, min(T, int(round(t / cfg.dt))))
            if self.direction == "up":
                arrs["active"][:at, inst] = False
                arrs["active"][at:, inst] = True
            else:
                arrs["active"][at:, inst] = False


@dataclass(frozen=True)
class RttDrift(Event):
    """Mobility-style global RTT drift: every link ramps linearly from
    1× to ``factor``× across [start, stop], held after (``hold``) or
    snapped back (handover complete)."""
    stop: float = math.inf
    factor: float = 1.5
    hold: bool = True

    def apply(self, arrs, cfg, K, M, key):
        T = arrs["T"]
        lo, hi = _window(cfg, T, self.start, self.stop)
        n = hi - lo
        if n > 0:
            ramp = 1.0 + (self.factor - 1.0) * (np.arange(n) / max(n - 1, 1))
            arrs["rtt_scale"][lo:hi] *= ramp[:, None]
        if self.hold:
            arrs["rtt_scale"][hi:] *= self.factor


@dataclass(frozen=True)
class LinkDegrade(Event):
    """Congestion on the links into specific instances: their RTT
    column scales by ``factor`` for the window."""
    stop: float = math.inf
    instances: tuple[int, ...] = (0,)
    factor: float = 3.0

    def apply(self, arrs, cfg, K, M, key):
        lo, hi = _window(cfg, arrs["T"], self.start, self.stop)
        arrs["rtt_scale"][lo:hi, np.asarray(self.instances)] *= self.factor


@dataclass(frozen=True)
class Partition(Event):
    """Network partition: routes from ``lbs`` to ``instances`` gain
    ``penalty`` seconds (≫ tau: unreachable for QoS purposes) until the
    heal at ``stop``. Without the resilience layer a request routed
    there simply fails; with ``SimConfig.attempt_timeout`` set, the
    attempt is cut at the timeout and retried elsewhere within the
    deadline budget (and breakers eject the unreachable arm). Factored
    as min(cut_k, cut_m) — only the LB∩instance intersection pays."""
    stop: float = math.inf
    lbs: tuple[int, ...] = ()
    instances: tuple[int, ...] = ()
    penalty: float = 10.0

    def apply(self, arrs, cfg, K, M, key):
        lo, hi = _window(cfg, arrs["T"], self.start, self.stop)
        k_idx = np.asarray(self.lbs, np.int32)
        m_idx = np.asarray(self.instances, np.int32)
        arrs["rtt_cut_k"][lo:hi, k_idx] = np.maximum(
            arrs["rtt_cut_k"][lo:hi, k_idx], self.penalty)
        arrs["rtt_cut_m"][lo:hi, m_idx] = np.maximum(
            arrs["rtt_cut_m"][lo:hi, m_idx], self.penalty)


@dataclass(frozen=True)
class ServiceSlowdown(Event):
    """Per-instance throttling: s_m multiplies by ``factor`` for the
    window (noisy neighbour, thermal throttling, or — with
    start=0/stop=inf — statically heterogeneous hardware)."""
    stop: float = math.inf
    instances: tuple[int, ...] = (0,)
    factor: float = 2.0

    def apply(self, arrs, cfg, K, M, key):
        lo, hi = _window(cfg, arrs["T"], self.start, self.stop)
        arrs["s_m"][lo:hi, np.asarray(self.instances)] *= self.factor


# ---------------------------------------------------------------------------
# Scenario + compiler.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Scenario:
    """Topology spec + ordered event timeline. ``n_nodes`` is K (one LB
    per node), ``n_instances`` is M; ``base_clients`` fills
    ``n_clients`` before events edit it."""
    name: str
    events: tuple = ()
    n_nodes: int = 30
    n_instances: int = 10
    base_clients: int = 4
    description: str = ""


def with_standby(scn: Scenario, count: int) -> Scenario:
    """Widen a scenario's fleet by ``count`` standby instances.

    The new instances take the LAST indices of the widened M, so every
    event in the timeline (library events target leading-index
    fractions of the original fleet) keeps hitting exactly the
    instances it did before — the standby pool is untouched capacity.
    This is the closed-loop study's topology helper: a
    ``control.ControlConfig(managed=count, ...)`` makes that trailing
    pool the autoscaler's own deployment, parked at t=0 and spawned
    only when the controller reacts, so open- and closed-loop rows of
    the same scenario face the identical base fleet and timeline.
    """
    if count < 0:
        raise ValueError(f"standby count must be >= 0, got {count}")
    return dataclasses.replace(
        scn, n_instances=scn.n_instances + count,
        description=(scn.description +
                     f" [+{count} standby instances]" if count else
                     scn.description))


def compile_scenario(scn: Scenario, cfg, key) -> Drivers:
    """Lower a scenario to dense driver arrays.

    Deterministic under a fixed ``key`` (event i draws from
    ``fold_in(key, i)``). Post-conditions enforced here, not trusted
    from events: ``0 <= n_clients <= cfg.max_clients``, ``s_m >=
    MIN_SERVICE_TIME``, ``rtt_scale > 0``, cuts ``>= 0``, and at least
    one instance alive at every step (raises ValueError otherwise —
    a dead fleet is a spec bug, not a scenario).
    """
    T, K, M = cfg.num_steps, scn.n_nodes, scn.n_instances
    arrs = {
        "T": T,
        "n_clients": np.full((T, K), scn.base_clients, np.int64),
        "active": np.ones((T, M), bool),
        "rtt_scale": np.ones((T, M), np.float64),
        "rtt_cut_k": np.zeros((T, K), np.float64),
        "rtt_cut_m": np.zeros((T, M), np.float64),
        "s_m": np.full((T, M), cfg.service_time, np.float64),
    }
    marks: list[int] = []
    for i, ev in enumerate(scn.events):
        ev.apply(arrs, cfg, K, M, jax.random.fold_in(key, i))
        marks.extend(m for m in ev.marks(cfg) if 0 <= m < T)

    # The factored partition cut is a rank-1 AND: two partitions that
    # overlap in time with different LB/instance sets also penalize
    # the cross routes between them (LB side of A ∩ instance side of
    # B). That may or may not be the intended topology — never let it
    # happen silently.
    parts = [e for e in scn.events if isinstance(e, Partition)]
    for i, a in enumerate(parts):
        for b in parts[i + 1:]:
            overlap = a.start < b.stop and b.start < a.stop
            aligned = (set(a.lbs) == set(b.lbs)
                       or set(a.instances) == set(b.instances))
            if overlap and not aligned:
                warnings.warn(
                    f"scenario {scn.name!r}: partitions "
                    f"[{a.start:g},{a.stop:g}) and [{b.start:g},{b.stop:g}) "
                    f"overlap with different LB/instance sets — the "
                    f"factored min(cut_k, cut_m) also cuts the cross "
                    f"routes between their sides", stacklevel=2)

    if not arrs["active"].any(axis=1).all():
        dead = int(np.argmin(arrs["active"].any(axis=1)))
        raise ValueError(
            f"scenario {scn.name!r}: no instance alive at step {dead} "
            f"(t={dead * cfg.dt:.1f}s) — fix the kill/restore timeline")
    if (arrs["rtt_scale"] <= 0).any():
        raise ValueError(f"scenario {scn.name!r}: non-positive rtt_scale")

    marks = sorted(set(marks))
    if len(marks) > MAX_MARKS:
        warnings.warn(
            f"scenario {scn.name!r}: {len(marks)} event marks exceed "
            f"MAX_MARKS={MAX_MARKS}; recovery windows only cover the "
            f"first {MAX_MARKS} onsets", stacklevel=2)
        marks = marks[:MAX_MARKS]
    marks_arr = np.full((MAX_MARKS,), -1, np.int64)
    marks_arr[:len(marks)] = marks
    return Drivers(
        n_clients=jnp.asarray(
            np.clip(arrs["n_clients"], 0, cfg.max_clients), jnp.int32),
        active=jnp.asarray(arrs["active"]),
        rtt_scale=jnp.asarray(arrs["rtt_scale"], jnp.float32),
        rtt_cut_k=jnp.asarray(arrs["rtt_cut_k"], jnp.float32),
        rtt_cut_m=jnp.asarray(arrs["rtt_cut_m"], jnp.float32),
        s_m=jnp.asarray(
            np.maximum(arrs["s_m"], MIN_SERVICE_TIME), jnp.float32),
        marks=jnp.asarray(marks_arr, jnp.int32),
    )


# ---------------------------------------------------------------------------
# Multi-tenant scenarios: S per-tenant timelines merged onto ONE fleet.
#
# The tenant engine (simulator._build_tenant_parts) consumes the same
# Drivers pytree with one change: ``n_clients`` gains a tenant axis —
# (T, S, K), one client schedule per service. All shared-infrastructure
# fields stay (T, ·): tenants ride the same instances, links and
# hardware, so each tenant timeline's infra events merge pessimally
# (any tenant's kill/slowdown/partition hits the shared fleet) while
# its load events stay scoped to that tenant's own clients.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TenantScenario:
    """S per-tenant :class:`Scenario` timelines over one shared fleet.

    Every tenant timeline must target the same (n_nodes, n_instances)
    topology; each tenant's ``base_clients`` and load events shape its
    own ``n_clients[:, s, :]`` slice, and infra events from any tenant
    apply fleet-wide (``tenant_drivers`` merge rules).
    """
    name: str
    tenants: tuple[Scenario, ...]
    description: str = ""


def broadcast_tenants(drv: Drivers, S: int) -> Drivers:
    """Give all S tenants one shared (T, K) client schedule: the
    single-tenant drivers with ``n_clients`` broadcast to (T, S, K).
    Shared-infrastructure fields pass through untouched. Note demand
    multiplies by S — size ``base_clients`` accordingly."""
    if drv.n_clients.ndim != 2:
        raise ValueError(
            f"broadcast_tenants expects single-tenant (T, K) n_clients, "
            f"got {drv.n_clients.shape}")
    T, K = drv.n_clients.shape
    return drv._replace(n_clients=jnp.broadcast_to(
        drv.n_clients[:, None, :], (T, S, K)))


def tenant_neutral_drivers(cfg, S: int, K: int, M: int,
                           base_clients: int = 1,
                           service_time: float | None = None) -> Drivers:
    """Neutral multi-tenant drivers: every tenant runs ``base_clients``
    constant clients per LB on an undisturbed fleet (the S-tenant
    analogue of ``neutral_drivers``; note total demand is S x
    base_clients x K x 1/dt req/s)."""
    return broadcast_tenants(
        neutral_drivers(cfg, K, M, base_clients=base_clients,
                        service_time=service_time), S)


def tenant_drivers(per_tenant: Sequence[Drivers]) -> Drivers:
    """Merge S single-tenant driver sets onto one shared fleet.

    * ``n_clients`` stacks into (T, S, K) — load stays tenant-scoped.
    * ``active`` ANDs: an instance any tenant's timeline kills is dead
      for everyone (it is one physical instance).
    * ``rtt_scale`` / ``rtt_cut_k`` / ``rtt_cut_m`` take the
      elementwise max: congestion and partitions are link properties,
      so the worst modulation any timeline applies is what the shared
      fabric exhibits.
    * ``s_m`` takes the elementwise max: a slowdown throttles the
      instance itself.
    * ``marks`` union (sorted, -1-padded to MAX_MARKS) so recovery
      windows key off every tenant's event onsets.

    The pessimal merge keeps per-tenant timelines composable without a
    cross-tenant event algebra; scope infra events to tenant 0's
    timeline when only one copy is intended.
    """
    S = len(per_tenant)
    if S < 1:
        raise ValueError("tenant_drivers needs at least one tenant")
    shapes = {d.n_clients.shape for d in per_tenant}
    if len(shapes) != 1 or per_tenant[0].n_clients.ndim != 2:
        raise ValueError(
            f"per-tenant drivers must share one (T, K) n_clients "
            f"shape, got {sorted(shapes)}")
    if len({d.active.shape for d in per_tenant}) != 1:
        raise ValueError("per-tenant drivers must share one fleet shape")

    def npf(x):
        return np.asarray(x)

    active = np.logical_and.reduce([npf(d.active) for d in per_tenant])
    if not active.any(axis=1).all():
        dead = int(np.argmin(active.any(axis=1)))
        raise ValueError(
            f"merged tenant timelines leave no instance alive at step "
            f"{dead} — fix the kill/restore timelines")
    mk = np.concatenate([npf(d.marks) for d in per_tenant])
    mk = np.unique(mk[mk >= 0])
    if len(mk) > MAX_MARKS:
        warnings.warn(
            f"merged tenant timelines carry {len(mk)} event marks; "
            f"recovery windows only cover the first {MAX_MARKS}",
            stacklevel=2)
        mk = mk[:MAX_MARKS]
    marks_arr = np.full((MAX_MARKS,), -1, np.int64)
    marks_arr[:len(mk)] = mk
    return Drivers(
        n_clients=jnp.stack([d.n_clients for d in per_tenant], axis=1),
        active=jnp.asarray(active),
        rtt_scale=jnp.asarray(np.maximum.reduce(
            [npf(d.rtt_scale) for d in per_tenant]), jnp.float32),
        rtt_cut_k=jnp.asarray(np.maximum.reduce(
            [npf(d.rtt_cut_k) for d in per_tenant]), jnp.float32),
        rtt_cut_m=jnp.asarray(np.maximum.reduce(
            [npf(d.rtt_cut_m) for d in per_tenant]), jnp.float32),
        s_m=jnp.asarray(np.maximum.reduce(
            [npf(d.s_m) for d in per_tenant]), jnp.float32),
        marks=jnp.asarray(marks_arr, jnp.int32),
    )


def compile_tenant_scenario(tscn: TenantScenario, cfg, key) -> Drivers:
    """Compile each tenant's timeline and merge onto the shared fleet.

    Tenant s compiles under ``fold_in(key, s)``, so its stochastic
    events (LB picks, churn walks) are independent across tenants and
    stable when other tenants' timelines change.
    """
    base = tscn.tenants[0]
    for s in tscn.tenants[1:]:
        if (s.n_nodes, s.n_instances) != (base.n_nodes,
                                          base.n_instances):
            raise ValueError(
                f"tenant scenario {tscn.name!r}: every tenant timeline "
                f"must target the same shared fleet "
                f"(got {(s.n_nodes, s.n_instances)} vs "
                f"{(base.n_nodes, base.n_instances)})")
    return tenant_drivers([
        compile_scenario(s, cfg, jax.random.fold_in(key, i))
        for i, s in enumerate(tscn.tenants)])
