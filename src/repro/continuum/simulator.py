"""Discrete-time CC simulator (paper §VII testbed, fully on-device).

Time advances in steps of ``dt`` (default 100 ms = one client period, so
every client issues exactly one request per step, matching the paper's
10 req/s PilotNet clients). Within a step, requests are issued in
*rounds* (round r = client r of every LB) so that same-round requests
from different LBs collide on instance queues — the paper's "implicit
collisions".

Instance model: single-worker queue. A request arriving when the queue
holds q requests observes processing latency ``(q+1) * s_m * Z`` with
``Z ~ LogNormal(0, sigma^2)``; the queue drains at ``dt / s_m`` requests
per step. End-to-end latency is ``rtt[k,m] + proc`` (client↔LB latency
is negligible per §IV-A; RTTs are fixed Istio-style injected delays).

The *true* per-arm success probability used for oracle regret has the
closed form ``mu = Phi(ln((tau - rtt)/((q+1) s_m)) / sigma)``.

The whole horizon runs in one ``lax.scan``; strategies are closures
chosen at trace time (QEdgeProxy / proxy-mity / Dec-SARSA).

Engine structure (streaming-first):

* **Rounds are a ``lax.scan``**, not a Python unroll: the round body is
  traced/compiled once instead of C times, which is most of the old
  compile wall. Selection, the queue recursion and the cheap (K, M)
  feedback control stay interleaved round by round, so an in-step trip
  steers the remaining rounds exactly as before; with the fused request
  path the expensive (K, M, R)/(K, Rq) ring writes are still deferred
  into ONE ``record_rings_batch`` scatter per step (its rank/offset
  arithmetic is round-order-free). Fused and sequential paths remain
  bit-for-bit identical (tests/test_bandit_batch.py).
* **Metrics stream by default-capable mode**: with ``trace=False`` the
  scan carries a ``MetricAccumulator`` (O(K·M) sufficient statistics
  for Figs 3-9 + regret + variation budget) and emits only O(T) scalar
  ``StepSeries`` — memory is O(K·M), independent of the horizon.
  ``trace=True`` is the explicit debug mode that materializes the full
  (T, K, C)/(T, K, M) ``SimOutputs`` trajectories as before.
* **Donated inputs / chunked horizons**: ``run_sim``/``run_sim_batch``
  donate the O(T) input buffers (n_clients, active, key) to XLA, and
  ``run_sim_stream(chunk_steps=...)`` drives the scan in fixed-size
  time chunks with a donated carry, so arbitrarily long horizons run
  in bounded device memory.
* Maintenance runs on a fixed-size player group per step (balanced
  staggered clocks), so the O(K·M·R) estimate is paid for ~K/H_d
  players instead of all K.
* **Scenarios drive every run**: the engine consumes a ``Drivers``
  pytree of dense per-step schedules (client counts, instance
  liveness, factored RTT modulation, per-instance service times)
  compiled from a declarative event timeline
  (``repro.continuum.scenarios``; named library in
  ``repro.continuum.library``). Legacy ``n_clients``/``active``
  kwargs wrap into neutral drivers that reproduce the pre-scenario
  engine bit-for-bit; the streaming accumulator keys time-to-recover
  windows off the scenario's event marks.
* **The evaluation grid shards across devices**: scenario/seed lanes
  are independent simulations (the MP-MAB players never communicate,
  and neither do grid cells), so ``run_sim_grid`` /
  ``build_sim_grid_fn`` ``shard_map`` the vmapped scenario axis of a
  streaming run over the ``data`` mesh axis. Each device scans only
  its shard and carries its own O(K·M) accumulators; the host touches
  nothing until the (tiny) metric pytree is read. One real device
  falls back to the plain vmap — the exact same program ``get_suite``
  always ran.
* **The player axis shards *inside* one simulation**: the bandit state
  factorizes over players; the ONLY cross-player coupling is the
  instance-queue recursion (same-round requests from different LBs
  collide on shared (M,) queues). ``run_sim_players`` /
  ``build_sim_players_fn`` therefore ``shard_map`` a streaming run
  over the ``players`` axis of a 2-D (``data``, ``players``) mesh
  (``launch.mesh.make_continuum_mesh``): each device holds K/D
  players' rings/weights/KDE stats and maintenance groups, rounds
  ``psum`` the (M,) arrival vector before the shared queue drain, and
  the fleet-level accumulator fields are ``psum``-reduced once after
  the scan. Two engine invariants make the sharded schedule decompose
  exactly: every per-player random draw is keyed by global player id
  (``repro.core.prand``), and the staggered maintenance clocks assign
  phases per contiguous player *block* (``_stagger_groups``), so a
  shard's due-players are a static-shape shard-local gather. Sharded
  and unsharded runs match — counting statistics exactly, psum-reduced
  float series to f32 reassociation tolerance
  (tests/test_sharded_players.py); a 1-way player axis falls back to
  the plain streaming program. Composes with the grid axis:
  ``build_sim_grid_fn`` on a 2-D mesh shards lanes over ``data`` and
  every lane's players over ``players``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.continuum import control as qc
from repro.continuum import metrics as qm
from repro.continuum import scenarios as qs
from repro.continuum import tenancy as qt
from repro.continuum.metrics import (MetricAccumulator, StepSeries,
                                     StreamOutputs)
from repro.continuum.scenarios import Drivers
from repro.core import bandit as qb
from repro.core import baselines as bl
from repro.core import prand
from repro.core.kde import normal_cdf
from repro.core.oracle import step_regret
from repro.kernels import ops as kernel_ops
from repro.obs import recorder as obr


@dataclass(frozen=True)
class SimConfig:
    dt: float = 0.1                  # step length [s] = client period
    horizon: float = 300.0           # simulated seconds
    maint_every: int = 10            # QEdgeProxy decision interval H_d [steps]
    max_clients: int = 8             # per-LB client slots (rounds per step)
    service_time: float = 0.0055     # s_m: idle per-request processing [s]
    # 0.0055 keeps the system well-provisioned (paper §IV-A assumption:
    # an oracle allocation satisfying tau exists): 1200 req/s demand vs
    # ~1800 req/s capacity, but any 4-5+ LBs herding on one instance
    # still overload it — the proxy-mity failure mode.
    proc_sigma: float = 0.25         # lognormal sigma of processing noise
    tau: float = 0.080
    rho: float = 0.9
    window: float = 10.0
    ring: int = 64
    reward_ring: int = 512
    # Event-relative recovery windows (scenario engine): the streaming
    # accumulator keeps, per scenario event mark, one pre-event
    # baseline window of ev_pre seconds and ev_buckets consecutive
    # post-event buckets of ev_bucket seconds each (metrics.ev_succ).
    ev_pre: float = 10.0
    ev_bucket: float = 2.0
    ev_buckets: int = 30
    # --- request-lifecycle resilience (all off by default; the neutral
    # config traces the exact pre-resilience program). An attempt
    # exceeding ``attempt_timeout`` seconds is abandoned by the client
    # (the instance still does the work — the arrival is not recalled)
    # and observed only as a censored latency lower bound; with
    # ``max_retries`` > 0 it is retried on a re-selected instance after
    # exponential backoff (``retry_backoff * 2^(a-1)`` before attempt
    # a), as long as the elapsed budget stays inside the request's QoS
    # deadline tau (``retry_deadline=False`` drops that guard — the
    # naive retry policy that amplifies overload). ``breaker_threshold``
    # consecutive timeouts on one (player, arm) open an Envoy-style
    # circuit breaker for ``breaker_cooldown`` seconds; see
    # ``core.bandit.BreakerState``. ---
    attempt_timeout: float = 0.0     # per-attempt client timeout [s]; 0 = off
    max_retries: int = 0             # R: retry attempts after a timeout
    retry_backoff: float = 0.005     # base backoff [s] before attempt a >= 1
    retry_deadline: bool = True      # budget retries against tau (False = naive)
    breaker_threshold: int = 0       # consecutive timeouts to open; 0 = off
    breaker_cooldown: float = 2.0    # open -> half-open probe after this [s]
    # --- closed-loop control plane (reactive autoscaling, admission
    # shedding, capacity migration; ``repro.continuum.control``). None
    # or a neutral ControlConfig (``enabled == False``) traces the
    # byte-identical open-loop program — same parity discipline as the
    # resilience knobs above. ---
    control: "qc.ControlConfig | None" = None
    # --- flight recorder (repro.obs.recorder): a fixed-capacity ring
    # of structured (step, kind, entity, value) events in the scan
    # carry — breaker trips/resets, retry exhaustions, control actions,
    # scenario marks, QoS-miss spikes. None or a disabled
    # RecorderConfig traces the byte-identical program — same parity
    # discipline as the resilience and control knobs. ---
    recorder: "obr.RecorderConfig | None" = None
    # --- fused round megakernel (kernels/ops.round_step): collapse the
    # C-round scan body to one fused call with the bandit block's state
    # resident across rounds (VMEM on the Pallas path, an unrolled
    # XLA fusion on CPU). Bit-identical to the scan by construction
    # (tests/test_round_fused.py); auto-falls-back to the scan whenever
    # a feature needs the per-round structure (resilience attempts,
    # player sharding's per-round arrival psum, sequential strategies).
    fused_round: bool = True
    # --- multi-tenant continuum (repro.continuum.tenancy): S services
    # sharing the one instance fleet, each with its own QoS deadline
    # tau_s, client population and bandit fleet; the queue recursion
    # gains a leading (S,) service axis and cross-service interference
    # folds into the effective service row. ``None`` — or a degenerate
    # S=1 config — keeps the engine on the untouched single-service
    # path (byte-identical HLO; tests/test_tenancy.py). ---
    tenancy: "qt.TenancyConfig | None" = None

    @property
    def num_steps(self) -> int:
        return int(round(self.horizon / self.dt))

    @property
    def tenancy_on(self) -> bool:
        return qt.tenancy_enabled(self)

    @property
    def resilience_on(self) -> bool:
        return self.attempt_timeout > 0.0

    @property
    def control_on(self) -> bool:
        return qc.control_enabled(self)

    @property
    def recorder_on(self) -> bool:
        return obr.recorder_enabled(self)


class PlayerSharding(NamedTuple):
    """Static spec: split the (K,) player axis over mesh axis ``axis``.

    Passed to ``build_sim_parts``/``build_sim_fn`` when the returned
    program will run *inside* a ``shard_map`` whose mesh carries
    ``axis`` with ``shards`` devices. The traced program then works on
    K/shards players, keys randomness and maintenance clocks by global
    player id, and ``psum``s the per-round arrival vector over
    ``axis``. ``build_sim_players_fn``/``build_sim_grid_fn`` construct
    this; it is exposed for harnesses that wrap the run themselves.
    """
    axis: str
    shards: int


class SimOutputs(NamedTuple):
    """Per-step trajectories (leading axis T) — ``trace=True`` only."""
    rewards: jax.Array      # (T, K, C) 1/0 QoS success per client slot
    issued: jax.Array       # (T, K, C) request-issued mask
    choices: jax.Array      # (T, K, C) selected instance
    latency: jax.Array      # (T, K, C) end-to-end latency
    proc_lat: jax.Array     # (T, K, C) processing component
    arrivals: jax.Array     # (T, M) requests per instance
    queue: jax.Array        # (T, M) queue length at step start
    weights: jax.Array      # (T, K, M) routing distribution
    true_mu: jax.Array      # (T, K, M) oracle success probabilities
    regret: jax.Array       # (T, K) per-step oracle regret
    eps: jax.Array          # (T, K) exploration rate (qedgeproxy) or 0
    attempts: jax.Array     # (T, K, C) attempts per request (1 + retries)
    dropped: jax.Array      # (T, K, C) deadline exhausted without completing


def _true_mu_tau(rtt, q, tau, sigma, service_time):
    """Closed-form P(rtt + (q+1) s Z <= tau), Z ~ LogNormal(0, sigma^2).

    Parameterized on the deadline so the multi-tenant engine can score
    each tenant against its own tau_s; ``_true_mu`` is the
    single-service view (identical traced ops)."""
    margin = (tau - rtt) / ((q[None, :] + 1.0) * service_time)
    safe = jnp.maximum(margin, 1e-9)
    mu = normal_cdf(jnp.log(safe) / sigma)
    return jnp.where(margin > 0, mu, 0.0)


def _true_mu(rtt, q, cfg: SimConfig, service_time):
    return _true_mu_tau(rtt, q, cfg.tau, cfg.proc_sigma, service_time)


# ---------------------------------------------------------------------------
# Strategy adapters: dicts of closures with a common signature.
#
# ``init``/``select`` take ``pids`` — the (K,) i32 *global* ids of the
# players this program instance holds (``arange(K)`` unsharded, the
# shard's slice under player sharding). Strategies key every per-player
# random draw off it (repro.core.prand), which is what makes a
# player-sharded run reproduce the unsharded stream bit-for-bit.
# ---------------------------------------------------------------------------

def qedgeproxy_strategy(params: qb.BanditParams, cfg: SimConfig, K: int, M: int):
    def init(rtt, active, key, pids):
        return qb.init_state(K, M, params, cfg.ring, cfg.reward_ring, active,
                             key=key, pids=pids)

    def select(state, key, t, active, pids):
        choice, state, valid = qb.select(state)
        return choice, state

    def record(state, choice, lat, t, mask):
        return qb.record(state, params, choice, lat, t, mask)

    def maintain(state, rtt, t, lb_mask=None):
        return qb.maintenance(state, params, rtt, t, lb_mask)

    def maintain_subset(state, rtt, t, player_idx):
        return qb.maintenance_subset(state, params, rtt, t, player_idx)

    def record_feedback(state, choice, lat, t, mask):
        return qb.record_feedback(state, params, choice, lat, t, mask)

    def record_rings(state, choices, lats, t, mask):
        return qb.record_rings_batch(state, params, choices, lats, t, mask)

    def on_activity(state, new_active, rtt, t):
        return qb.sync_active(state, params, new_active)

    def weights(state):
        return state.weights

    def eps(state):
        return state.eps

    def fused_round(state, q, nc, act, t, rtt_t, s_m, served, k_step, pids):
        # all C rounds in one fused call: the per-round PRNG stream is
        # batched up front (each element is exactly the draw the scan
        # makes — a pure function of (step key, round, player id)), and
        # kernels/ops.round_step replays selection, queue recursion,
        # feedback control and the ring scatter bit-identically.
        C = cfg.max_clients
        ks = jax.vmap(
            lambda r: jax.random.split(jax.random.fold_in(k_step, r))
        )(jnp.arange(C))
        z = jnp.exp(cfg.proc_sigma * jax.vmap(
            lambda kk: prand.player_normal(kk, pids))(ks[:, 1]))
        out = kernel_ops.round_step(
            state.weights, state.cw, state.err, state.cooldown_until,
            state.in_pool, state.active,
            state.lat_buf, state.ts_buf, state.ptr,
            state.r_buf, state.rts_buf, state.rptr,
            q, nc, z, rtt_t, s_m, served, t,
            tau=params.tau, err_thresh=params.err_thresh,
            cooldown=params.cooldown)
        state = state._replace(
            weights=out.weights, cw=out.cw, err=out.err,
            cooldown_until=out.cooldown_until, in_pool=out.in_pool,
            lat_buf=out.lat_buf, ts_buf=out.ts_buf, ptr=out.ptr,
            r_buf=out.r_buf, rts_buf=out.rts_buf, rptr=out.rptr)
        return state, out.q, out.arrivals, out.choices, out.lats, out.procs

    return dict(init=init, select=select, record=record, maintain=maintain,
                maintain_subset=maintain_subset,
                record_feedback=record_feedback, record_rings=record_rings,
                on_activity=on_activity, weights=weights, eps=eps,
                fused_round=fused_round)


def proxy_mity_strategy(alpha: float, cfg: SimConfig, K: int, M: int):
    """Static proximity weights; requests sampled i.i.d. from them
    (proxy-mity randomizes per request; there is no SWRR state).
    Selection keys come from the scan's per-round stream, so the state
    carries no PRNG key of its own."""

    class PMState(NamedTuple):
        weights: jax.Array

    def init(rtt, active, key, pids):
        return PMState(bl.proxy_mity_weights(rtt, alpha, active))

    def select(state, key, t, active, pids):
        # per-player categorical via argmax(logits + Gumbel), with the
        # Gumbel row keyed by global player id (shard-invariant)
        g = prand.player_gumbel(key, pids, M)
        choice = jnp.argmax(jnp.log(state.weights + 1e-30) + g, axis=-1)
        return choice, state

    def record(state, choice, lat, t, mask):
        return state

    def record_feedback(state, choice, lat, t, mask):
        return state                     # stateless per request

    def record_rings(state, choices, lats, t, mask):
        return state

    def maintain(state, rtt, t, lb_mask=None):
        return state                     # fixed at initialization (paper)

    def on_activity(state, new_active, rtt, t):
        return state._replace(weights=bl.proxy_mity_weights(rtt, alpha, new_active))

    def weights(state):
        return state.weights

    def eps(state):
        return jnp.zeros((K,), jnp.float32)

    def fused_round(state, q, nc, act, t, rtt_t, s_m, served, k_step, pids):
        # stateless selection from fixed weights: the batched Gumbel
        # rows reproduce the scan's per-round draws exactly, and the
        # scatter-free jnp path is already the fused form.
        C = cfg.max_clients
        ks = jax.vmap(
            lambda r: jax.random.split(jax.random.fold_in(k_step, r))
        )(jnp.arange(C))
        gum = jax.vmap(
            lambda kk: prand.player_gumbel(kk, pids, M))(ks[:, 0])
        z = jnp.exp(cfg.proc_sigma * jax.vmap(
            lambda kk: prand.player_normal(kk, pids))(ks[:, 1]))
        q, arrivals, choices, lats, procs = kernel_ops.round_step_gumbel(
            state.weights, q, nc, z, gum, rtt_t, s_m, served)
        return state, q, arrivals, choices, lats, procs

    return dict(init=init, select=select, record=record, maintain=maintain,
                record_feedback=record_feedback, record_rings=record_rings,
                on_activity=on_activity, weights=weights, eps=eps,
                fused_round=fused_round)


def dec_sarsa_strategy(params: bl.DecSarsaParams, cfg: SimConfig, K: int,
                       M: int, pshard: "PlayerSharding | None" = None):
    class DSState(NamedTuple):
        inner: bl.DecSarsaState
        active: jax.Array
        pend_s: jax.Array      # state bucket used for the pending action

    def init(rtt, active, key, pids):
        # the proximity-normalized optimistic Q init divides by the
        # GLOBAL rtt max — under player sharding that is a pmax over
        # the shards, the baseline's one cross-player reduction
        rtt_max = rtt.max()
        if pshard is not None:
            rtt_max = jax.lax.pmax(rtt_max, pshard.axis)
        return DSState(bl.decsarsa_init(K, M, rtt, params, rtt_max), active,
                       jnp.zeros((K,), jnp.int32))

    def select(state, key, t, active, pids):
        choice, s = bl.decsarsa_select(state.inner, params, active, key,
                                       pids)
        return choice, state._replace(pend_s=s, active=active)

    def record(state, choice, lat, t, mask):
        reward = (lat <= params.tau).astype(jnp.float32)
        inner = bl.decsarsa_update(
            state.inner, params, state.pend_s, choice, reward, lat, mask)
        return state._replace(inner=inner)

    def maintain(state, rtt, t, lb_mask=None):
        return state

    def on_activity(state, new_active, rtt, t):
        return state._replace(active=new_active)

    def weights(state):
        # effective eps-greedy distribution for regret accounting
        K_, S, M_ = state.inner.q.shape
        s = state.pend_s
        qs = state.inner.q[jnp.arange(K_), s]
        neg = jnp.finfo(qs.dtype).min
        qs = jnp.where(state.active[None, :], qs, neg)
        greedy = jax.nn.one_hot(jnp.argmax(qs, -1), M_)
        actf = state.active.astype(jnp.float32)[None, :]
        uni = actf / jnp.maximum(actf.sum(-1, keepdims=True), 1.0)
        e = state.inner.eps[:, None]
        return (1 - e) * greedy + e * uni

    def eps(state):
        return state.inner.eps

    return dict(init=init, select=select, record=record, maintain=maintain,
                on_activity=on_activity, weights=weights, eps=eps)


def make_strategy(name: str, cfg: SimConfig, K: int, M: int,
                  pshard: "PlayerSharding | None" = None, **kw):
    if name == "qedgeproxy":
        params = kw.get("params") or qb.BanditParams(
            tau=cfg.tau, rho=cfg.rho, window=cfg.window,
            **{k: v for k, v in kw.items() if k in qb.BanditParams._fields})
        return qedgeproxy_strategy(params, cfg, K, M)
    if name.startswith("proxy_mity"):
        return proxy_mity_strategy(kw.get("alpha", 1.0), cfg, K, M)
    if name == "dec_sarsa":
        params = kw.get("params") or bl.DecSarsaParams(tau=cfg.tau)
        return dec_sarsa_strategy(params, cfg, K, M, pshard)
    raise ValueError(f"unknown strategy {name!r}")


# ---------------------------------------------------------------------------
# Main simulation loop.
# ---------------------------------------------------------------------------

def _stagger_groups(k_phase, K_global: int, n_phases: int, width: int,
                    lo, K_local: int) -> jax.Array:
    """Balanced staggered maintenance clocks, shard-decomposable.

    Players tile into contiguous *blocks* of ``n_phases``; block ``b``
    assigns its members one phase each through a random bijection keyed
    by ``fold_in(k_phase, b)`` — a pure function of global player id,
    like every other per-player draw (``repro.core.prand``). Row ``p``
    of the result lists the LOCAL indices (player id − ``lo``) of the
    players due at phase ``p``, padded with the sentinel ``K_local``
    that the maintenance scatter drops. Each phase gets exactly one
    player per block, so per-step maintenance work stays balanced (±1
    for the padded last block) for any K and for any contiguous shard
    [lo, lo + K_local) of the player axis — which is what keeps the
    gathers shard-local with a static (n_phases, width) shape under
    ``shard_map``.
    """
    bids = lo // n_phases + jnp.arange(width)          # global block ids

    def block_slots(b):
        # inv[p] = the within-block slot whose player fires at phase p
        perm = jax.random.permutation(jax.random.fold_in(k_phase, b),
                                      n_phases)
        return jnp.argsort(perm)

    inv = jax.vmap(block_slots)(bids)                  # (W, n_phases)
    gplayer = bids[:, None] * n_phases + inv           # global player ids
    local = gplayer - lo
    ok = (gplayer < K_global) & (local >= 0) & (local < K_local)
    return jnp.where(ok, local, K_local).T.astype(jnp.int32)


def build_sim_parts(
    strategy_name: str,
    cfg: SimConfig,
    K: int,
    M: int,
    fused: bool = True,
    trace: bool = True,
    warmup_steps: int = 0,
    pshard: PlayerSharding | None = None,
    **strategy_kw,
):
    """The engine's two traceable halves, shared by every driver.

    Returns ``(init_fn, step_fn)``:

    * ``init_fn(rtt, active0, key, pids=None) -> (carry0, keys)`` —
      strategy state, empty queue/accumulator, the block-staggered
      maintenance groups, and the full-horizon (T, 2) per-step key
      array (small; chunk drivers slice it so chunking never replays
      or forks the PRNG stream). ``pids`` are the global ids of the
      players this program instance holds (defaulted to ``arange(K)``
      unsharded; required under ``pshard``).
    * ``step_fn(rtt, marks, carry, xs) -> (carry, ys)`` — one simulator
      step. ``xs = (t_idx, n_clients_t, active_t, rtt_scale_t,
      rtt_cut_k_t, rtt_cut_m_t, s_m_t, key_t, group_t)`` — one row of
      the scenario ``Drivers`` plus a *global* ``t_idx`` and the
      maintenance-group row due this step (pre-gathered from the
      stagger table by the horizon driver), so a chunked scan is
      bit-identical to one full-horizon scan. The step first forms
      the effective RTT ``rtt * rtt_scale[None, :] + min(cut_k[:,
      None], cut_m[None, :])`` and the (M,) service-time row, and
      threads them through placement events, maintenance, the true-mu
      oracle and the queue recursion; with neutral drivers (scale 1,
      cut 0, constant s_m) every computed float is unchanged from the
      pre-scenario engine. ``ys`` is a full ``SimOutputs`` row in
      trace mode, a ``StepSeries`` row otherwise. ``marks`` are the
      scenario's event-onset steps for the accumulator's recovery
      windows (ignored in trace mode).

    With ``pshard`` the returned halves are the *per-shard* program of
    a player-sharded run (streaming only): ``K`` is still the global
    player count, but every (K,) shape shrinks to K/shards, randomness
    and maintenance clocks key off the shard's global player ids, the
    round loop ``psum``s its (M,) arrival vector over ``pshard.axis``
    before the shared queue drain, and the accumulator's fleet-level
    fields hold shard-local partial sums (reduced once after the scan
    by ``build_sim_fn``). Both halves must then be traced inside a
    ``shard_map`` carrying that axis, and ``init_fn`` must be given the
    shard's ``pids`` — its global player ids, delivered as a sharded
    *operand* (an ``arange(K)`` split by ``P('players')``), the same
    data path that delivers the shard its ``rtt`` rows. Identity is
    deliberately data, not ``lax.axis_index``: the ids then cannot
    disagree with the rows they describe.

    The carry is ``(state, queue, prev_active, acc, groups, pids,
    breaker, control, recorder)`` with ``acc=None`` in trace mode,
    ``breaker=None`` unless the config enables circuit breakers,
    ``control=None`` unless ``cfg.control`` enables a closed-loop
    mechanism, and ``recorder=None`` unless ``cfg.recorder`` enables
    the flight recorder (``repro.obs.recorder`` — a bounded ring of
    structured events appended at step end from already-computed
    shard-local quantities; fleet-level lanes are recorded only by the
    shard holding global player 0, so the players axis costs no new
    collective).

    **Closed-loop control plane** (``cfg.control`` enabled): a
    ``control.ControlCarry`` rides in the scan next to the breaker
    state. At step start ``control_actuate`` advances the policy state
    machine on the replicated observations (per-arm queue depth, the
    EMAs fed back at the previous step end) and swaps in the
    *effective* drivers: controller-masked instance liveness (reactive
    autoscaler over the managed standby pool, with warm-up +
    hysteresis), admitted client slots (per-player token buckets; the
    shed remainder counts as issued QoS misses but never reaches a
    queue or the routing statistics), and the migration-scaled service
    row. Placement events, maintenance, the true-mu oracle, regret and
    the queue recursion all see only the effective values — a
    controller spawn/kill IS a placement event to the bandit. At step
    end ``control_observe`` folds the fleet QoS/timeout totals into
    the rolling EMAs; under player sharding that (4,) observation is
    ``psum``-reduced — the control plane's ONE new in-loop collective
    (every other decision input is already replicated, and per-player
    controller state is shard-local). Like the resilience layer, the
    whole path is gated on *static* config: a ``None``/neutral
    ``ControlConfig`` traces the byte-identical open-loop program
    (tests/test_control.py).

    **Request-lifecycle resilience** (``cfg.attempt_timeout > 0``): the
    round body unrolls ``1 + cfg.max_retries`` attempts per request.
    Attempt 0 is the bandit's own selection (optionally vetoed by an
    open breaker); a timed-out attempt is observed as a censored
    latency (``core.bandit.censored_latency`` — a point mass past tau,
    so the KDE learns "worse than the threshold", never the true
    value), its instance KEEPS the work (the arrival stays in the queue
    recursion — abandoned work is how retry storms amplify), and the
    retry re-routes via ``core.bandit.retry_pick`` over the current
    weights, excluding the failed arm and any breaker-open arms, after
    an exponential backoff charged against the request's tau budget.
    All of this is gated on *static* config flags: the neutral config
    (timeout 0, R=0, breakers off) traces the byte-identical
    pre-resilience program — bit-identity is structural, not numerical
    luck. Every resilience state is per-player ((K,·) breaker counters,
    per-attempt draws keyed by global player id), so it shards on the
    ``players`` axis with no new in-loop collectives: retry arrivals
    fold into the SAME per-round (M,) arrival psum.

    **Multi-tenant continuum** (``cfg.tenancy`` with S >= 2): the
    engine dispatches to ``_build_tenant_parts`` — the same carry
    layout and scan contract, with the strategy state and accumulator
    slots holding S-tuples and the queue a shared (S, M) backlog.
    ``tenancy=None`` or a degenerate S=1 config never reaches that
    path: this function's single-service body is literally the code
    that runs, so the pre-tenant program lowers byte-identically
    (tests/test_tenancy.py).
    """
    tn = cfg.tenancy
    if tn is not None and not tn.enabled:
        # degenerate S=1 config: stays on the single-service path
        # below, so it must not silently disagree with the scalar
        # knobs that path reads
        if abs(tn.taus[0] - cfg.tau) > 1e-12:
            raise ValueError(
                f"S=1 TenancyConfig tau {tn.taus[0]} != cfg.tau "
                f"{cfg.tau}: the single-tenant path reads cfg.tau")
        if tn.scales[0] != 1.0:
            raise ValueError(
                "S=1 TenancyConfig needs a neutral service_scale: the "
                "single-tenant path reads drivers.s_m unscaled")
    if qt.tenancy_enabled(cfg):
        return _build_tenant_parts(
            strategy_name, cfg, K, M, fused=fused, trace=trace,
            warmup_steps=warmup_steps, pshard=pshard, **strategy_kw)
    if pshard is not None and pshard.shards == 1:
        pshard = None
    if pshard is not None:
        if trace:
            raise ValueError(
                "player sharding is streaming-only: trajectories are "
                "O(T*K*...) — the memory the sharding exists to split")
        if K % pshard.shards:
            raise ValueError(
                f"K={K} players must be a multiple of the "
                f"{pshard.shards}-way '{pshard.axis}' mesh axis")
    res_on = cfg.attempt_timeout > 0.0
    if not res_on and (cfg.max_retries or cfg.breaker_threshold):
        raise ValueError(
            "max_retries/breaker_threshold need attempt_timeout > 0: "
            "the per-attempt timeout is the failure signal both "
            "mechanisms respond to")
    brk_on = res_on and cfg.breaker_threshold > 0
    ctl_on = qc.control_enabled(cfg)
    ccfg = cfg.control
    if ctl_on and trace:
        raise ValueError(
            "the control plane is streaming-only: closed-loop runs are "
            "fleet-scale by construction (set trace=False)")
    rcfg = cfg.recorder
    rec_on = obr.recorder_enabled(cfg)
    if rec_on and trace:
        raise ValueError(
            "the flight recorder is streaming-only: trace=True already "
            "materializes full trajectories (set trace=False)")
    n_attempts = 1 + (cfg.max_retries if res_on else 0)
    censor = (qb.censored_latency(cfg.attempt_timeout, cfg.tau)
              if res_on else 0.0)
    K_glob = K
    K = K if pshard is None else K // pshard.shards   # local width below
    T, C = cfg.num_steps, cfg.max_clients
    strat = make_strategy(strategy_name, cfg, K, M, pshard=pshard,
                          **strategy_kw)
    batched_record = fused and strat.get("record_rings") is not None
    subset_maint = fused and strat.get("maintain_subset") is not None
    # The fused-round megakernel replaces the whole C-round scan body
    # (selection, queue recursion, feedback control, ring scatter) with
    # one kernels/ops.round_step call — statically gated, like every
    # other exactness-sensitive fast path, on the features that need
    # per-round structure being off: resilience unrolls attempts inside
    # the round, player sharding needs the per-round (M,) arrival psum
    # (a collective cannot live inside a pallas_call), and sequential
    # strategies read their own state between rounds.
    fused_round_on = (cfg.fused_round and fused and not res_on
                      and pshard is None and batched_record
                      and strat.get("fused_round") is not None)
    n_phases = max(cfg.maint_every, 1)
    n_blocks = -(-K_glob // n_phases)   # ceil: players per decision tick
    # a contiguous K-wide shard touches at most ceil(K/n_phases)+1
    # global blocks (straddling one at each edge)
    group_width = (n_blocks if pshard is None
                   else min(n_blocks, -(-K // n_phases) + 1))
    ev_pre_steps = max(1, int(round(cfg.ev_pre / cfg.dt)))
    ev_bucket_steps = max(1, int(round(cfg.ev_bucket / cfg.dt)))

    def init_fn(rtt, active0, key, pids=None):
        if pids is None:
            if pshard is not None:
                raise ValueError(
                    "player-sharded init needs the shard's global player "
                    "ids (pids) as a sharded operand")
            pids = jnp.arange(K, dtype=jnp.int32)
        k_init, k_phase, k_scan = jax.random.split(key, 3)
        s0 = strat["init"](rtt, active0, k_init, pids)
        q0 = jnp.zeros((M,), jnp.float32)
        # Staggered H_d clocks (asynchronous DaemonSet timers): each
        # n_phases-player block spreads its members over the phases at
        # random (_stagger_groups). Fixed group width is what lets
        # maintenance gather exactly the rows due now instead of
        # running the O(K*M*R) estimate for all K every step, and the
        # block structure keeps that gather shard-local under player
        # sharding; sentinel K marks padding (dropped on scatter).
        groups = _stagger_groups(k_phase, K_glob, n_phases, group_width,
                                 pids[0], K)
        acc = None if trace else qm.init_accumulator(
            K, M, C, n_marks=qs.MAX_MARKS, ev_buckets=cfg.ev_buckets)
        brk = qb.breaker_init(K, M) if brk_on else None
        # K here is the LOCAL width: controller token buckets and shed
        # counters are per-player and stay shard-local
        ctl = qc.control_init(ccfg, K, M) if ctl_on else None
        # the ring is per-shard state: K here is the local width, and
        # each shard retains its own most-recent `capacity` events
        rec = obr.recorder_init(rcfg, K, M, brk_on) if rec_on else None
        keys = jax.random.split(k_scan, T)
        return (s0, q0, active0, acc, groups, pids, brk, ctl, rec), keys

    def step_fn(rtt, marks, carry, xs):
        state, q, prev_active, acc, groups, pids, brk, ctl, rec = carry
        t_idx, nc, act, rtt_scale, cut_k, cut_m, s_m, k_step, group = xs
        t = t_idx.astype(jnp.float32) * cfg.dt

        # --- closed-loop control plane (statically gated): advance the
        # policy state machine on the replicated step-start
        # observations and swap in the EFFECTIVE drivers. Everything
        # downstream — placement events, maintenance, the true-mu
        # oracle, regret, the queue drain — sees only the effective
        # values, so a controller spawn/kill fires the same Alg 3/4
        # trigger as a scenario liveness flip. ``nc`` becomes the
        # ADMITTED slot count (what the rounds execute); ``nc_sched``
        # keeps the scheduled demand for client-facing accounting. ---
        if ctl_on:
            measf = (t_idx >= warmup_steps).astype(jnp.float32)
            nc_sched = nc
            ctl_cnt_pre = ctl.counters
            ctl, act, nc, s_m, _shed = qc.control_actuate(
                ccfg, cfg.dt, t, ctl, q, act, nc, s_m, measf)
            # control actions for the flight recorder: this step's
            # counter increments (already warmup-gated, replicated
            # across shards — no collective needed to observe them)
            ctl_deltas = (
                ctl.counters.scale_up - ctl_cnt_pre.scale_up,
                ctl.counters.scale_down - ctl_cnt_pre.scale_down,
                ctl.counters.migrations - ctl_cnt_pre.migrations,
            ) if rec_on else None

        # --- scenario modulation: effective RTT and service row for
        # THIS step. The partition term is the factored rank-1 AND
        # (only LB-side ∩ instance-side routes pay the cut); with
        # neutral drivers (*1.0, +0.0) every float is unchanged. ---
        rtt_t = rtt * rtt_scale[None, :] + jnp.minimum(
            cut_k[:, None], cut_m[None, :])

        # --- placement events (paper Alg 3/4 trigger) ---
        changed = jnp.any(act != prev_active)
        if brk_on:
            # liveness flips also clear the affected breaker columns,
            # mirroring how Alg 3/4 purge the arm's bandit data
            state, brk = jax.lax.cond(
                changed,
                lambda sb: (strat["on_activity"](sb[0], act, rtt_t, t),
                            qb.breaker_reset_arms(sb[1],
                                                  act != prev_active)),
                lambda sb: sb,
                (state, brk))
        else:
            state = jax.lax.cond(
                changed,
                lambda s: strat["on_activity"](s, act, rtt_t, t),
                lambda s: s,
                state)

        # --- maintenance: only the player group whose clock fires.
        # The row arrives through xs (sliced by the scan machinery from
        # a (T, W) table built once outside the loop) instead of an
        # in-loop `groups[t_idx % n_phases]` gather: under shard_map at
        # ≥4 host devices, XLA:CPU (jax 0.4.37) mis-fuses that gather
        # of the sort-backed stagger table into the loop and some
        # shards read another phase's row — sharded runs then maintain
        # the wrong players (see ROADMAP; tests/test_sharded_players.py
        # is the regression net). ---
        if subset_maint:
            state = strat["maintain_subset"](state, rtt_t, t, group)
        else:
            lb_mask = jnp.zeros((K,), bool).at[group].set(
                True, mode="drop")
            state = strat["maintain"](state, rtt_t, t, lb_mask)

        mu_true = _true_mu(rtt_t, q, cfg, s_m)       # (K, M) at step start
        w_now = strat["weights"](state)
        reg = step_regret(w_now, mu_true, act)
        q_start = q

        if ctl_on:
            mask_adm = jnp.arange(C)[None, :] < nc[:, None]    # admitted
            mask_all = jnp.arange(C)[None, :] < nc_sched[:, None]
        else:
            mask_all = jnp.arange(C)[None, :] < nc[:, None]    # (K, C)
            mask_adm = mask_all
        # service is continuous: drain dt/C of capacity per round so
        # in-step arrivals and departures interleave (a step-end-only
        # drain would overstate in-step queueing by ~C/2 requests).
        # s_m is an (M,) row, so throttled instances drain slower.
        served_per_round = cfg.dt / (C * s_m)
        kidx = jnp.arange(K)

        # --- request rounds: a scan, traced once instead of C times.
        # Rounds still execute in order — selection, the queue recursion
        # and the cheap (K, M) feedback control are interleaved, so an
        # in-step cooldown trip steers the remaining rounds exactly as
        # with per-round `record`. With the fused path the expensive
        # (K, M, R)/(K, Rq) ring writes are deferred and land in ONE
        # fused scatter per step (`record_rings_batch`); the sequential
        # fallback lets the strategy read its own per-request state
        # between rounds (Dec-SARSA). Bit-for-bit identical paths
        # (tests/test_bandit_batch.py). ---
        if not res_on and fused_round_on:
            state, q, arrivals, choices, lats, procs = strat["fused_round"](
                state, q, nc, act, t, rtt_t, s_m, served_per_round,
                k_step, pids)
            att_kc = mask_adm.astype(jnp.int32)
            dropped_kc = jnp.zeros_like(mask_all)
            brk_open_step = None
        elif not res_on:
            def round_body(rc, r):
                state, q, arrivals = rc
                k_r = jax.random.fold_in(k_step, r)
                k_sel, k_noise = jax.random.split(k_r)
                mask = r < nc                                  # (K,)
                choice, state = strat["select"](state, k_sel, t, act,
                                                pids)
                # processing noise keyed per global player id (prand),
                # so the draw is invariant to how the K axis is sharded
                z = jnp.exp(
                    cfg.proc_sigma * prand.player_normal(k_noise, pids))
                q_seen = q[choice]
                proc = (q_seen + 1.0) * s_m[choice] * z
                lat = rtt_t[kidx, choice] + proc
                if batched_record:
                    state = strat["record_feedback"](state, choice, lat,
                                                     t, mask)
                else:
                    state = strat["record"](state, choice, lat, t, mask)
                arr_r = jax.ops.segment_sum(
                    mask.astype(jnp.float32), choice, num_segments=M)
                # the ONE cross-player coupling: same-round requests
                # from every LB land on the shared queues, so a
                # player-sharded round psums its local (M,) arrivals
                # before the drain (integer-valued f32 — the psum is
                # exact, and the queue stays replicated across shards).
                # `arrivals` keeps the shard-LOCAL sum: it feeds the
                # accumulator's partial arrivals_m, reduced once after
                # the scan.
                arr_all = (arr_r if pshard is None
                           else jax.lax.psum(arr_r, pshard.axis))
                q = jnp.maximum(q + arr_all - served_per_round, 0.0)
                return (state, q, arrivals + arr_r), (choice, lat, proc)

            (state, q, arrivals), (ch_r, lat_r, proc_r) = jax.lax.scan(
                round_body, (state, q, jnp.zeros((M,), jnp.float32)),
                jnp.arange(C))
            choices = ch_r.T                                   # (K, C)
            lats = lat_r.T
            procs = proc_r.T
            if batched_record:
                state = strat["record_rings"](state, choices, lats, t,
                                              mask_adm)
            att_kc = mask_adm.astype(jnp.int32)
            dropped_kc = jnp.zeros_like(mask_all)
            brk_open_step = None
        else:
            # --- resilient request lifecycle: 1 + R attempts, every
            # retry re-routed, backed off, budgeted against tau, and
            # fed back into the SAME per-round arrival psum (retry
            # load is real load). Attempt 0 reuses the exact neutral
            # key derivation; retry draws fold fresh salts off the
            # round key. All attempts of a round observe the
            # round-start queue (sub-round-resolution simplification;
            # their arrivals hit the queue at the round boundary). ---
            A = n_attempts
            brk_open_step = (qb.breaker_is_open(brk, t) if brk_on
                             else None)

            def round_body(rc, r):
                state, q, arrivals, brk_c = rc
                k_r = jax.random.fold_in(k_step, r)
                k_sel, k_noise = jax.random.split(k_r)
                mask = r < nc                                  # (K,)
                choice, state = strat["select"](state, k_sel, t, act,
                                                pids)
                if brk_on:
                    # the bandit's pick stands unless its breaker is
                    # open; the veto re-routes over the closed pool
                    g_veto = prand.player_gumbel(
                        jax.random.fold_in(k_r, 101), pids, M)
                    choice = qb.breaker_veto(
                        choice, brk_c, t, strat["weights"](state), act,
                        g_veto, mask)
                z = jnp.exp(
                    cfg.proc_sigma * prand.player_normal(k_noise, pids))
                proc = (q[choice] + 1.0) * s_m[choice] * z
                lat = rtt_t[kidx, choice] + proc
                timed_out = mask & (lat > cfg.attempt_timeout)
                obs = jnp.where(timed_out, censor, lat)
                # censored samples clip the proc sketch at the timeout
                # (the client never observes past it)
                proc_f = jnp.where(
                    timed_out, jnp.minimum(proc, cfg.attempt_timeout),
                    proc)
                elapsed = jnp.where(
                    mask, jnp.minimum(lat, cfg.attempt_timeout), 0.0)
                if brk_on:
                    brk_c = qb.breaker_update(
                        brk_c, choice, timed_out, mask, t,
                        cfg.breaker_threshold, cfg.breaker_cooldown)
                feed = (strat["record_feedback"] if batched_record
                        else strat["record"])
                state = feed(state, choice, obs, t, mask)
                arr = jax.ops.segment_sum(
                    mask.astype(jnp.float32), choice, num_segments=M)
                att_ch, att_obs, att_m = [choice], [obs], [mask]
                completed = mask & ~timed_out
                choice_f = choice
                pending = timed_out
                for a in range(1, A):
                    p = pending
                    backoff = cfg.retry_backoff * (2.0 ** (a - 1))
                    if cfg.retry_deadline:
                        # bounded policy: no retry that cannot finish
                        # inside the request's QoS deadline
                        p = p & (elapsed + backoff < cfg.tau)
                    k_a = jax.random.fold_in(k_r, 1000 + a)
                    k_pick, k_z = jax.random.split(k_a)
                    g = prand.player_gumbel(k_pick, pids, M)
                    open_now = (qb.breaker_is_open(brk_c, t) if brk_on
                                else None)
                    alt = qb.retry_pick(strat["weights"](state), act,
                                        choice_f, open_now, g)
                    choice_a = jnp.where(p, alt, choice_f)
                    z_a = jnp.exp(cfg.proc_sigma
                                  * prand.player_normal(k_z, pids))
                    proc_a = (q[choice_a] + 1.0) * s_m[choice_a] * z_a
                    lat_a = rtt_t[kidx, choice_a] + proc_a
                    to_a = p & (lat_a > cfg.attempt_timeout)
                    obs_a = jnp.where(to_a, censor, lat_a)
                    elapsed = jnp.where(
                        p,
                        elapsed + backoff
                        + jnp.minimum(lat_a, cfg.attempt_timeout),
                        elapsed)
                    if brk_on:
                        brk_c = qb.breaker_update(
                            brk_c, choice_a, to_a, p, t,
                            cfg.breaker_threshold, cfg.breaker_cooldown)
                    state = feed(state, choice_a, obs_a, t, p)
                    arr = arr + jax.ops.segment_sum(
                        p.astype(jnp.float32), choice_a, num_segments=M)
                    att_ch.append(choice_a)
                    att_obs.append(obs_a)
                    att_m.append(p)
                    choice_f = jnp.where(p, choice_a, choice_f)
                    proc_f = jnp.where(
                        to_a, jnp.minimum(proc_a, cfg.attempt_timeout),
                        jnp.where(p, proc_a, proc_f))
                    completed = completed | (p & ~to_a)
                    pending = to_a

                dropped = mask & ~completed
                # client-perceived latency: total elapsed (attempt
                # costs + backoffs) when the request completed, the
                # censor sentinel (> tau => QoS miss) when it dropped
                lat_out = jnp.where(completed, elapsed, censor)
                att_n = sum(m.astype(jnp.int32) for m in att_m)
                # still ONE psum per round: retries folded in above
                arr_all = (arr if pshard is None
                           else jax.lax.psum(arr, pshard.axis))
                q = jnp.maximum(q + arr_all - served_per_round, 0.0)
                ys = (choice_f, lat_out, proc_f, att_n, dropped,
                      jnp.stack(att_ch), jnp.stack(att_obs),
                      jnp.stack(att_m))
                return (state, q, arrivals + arr, brk_c), ys

            (state, q, arrivals, brk), ys_r = jax.lax.scan(
                round_body,
                (state, q, jnp.zeros((M,), jnp.float32), brk),
                jnp.arange(C))
            (chf_r, lat_r, proc_r, att_r, drop_r,
             ach_r, aobs_r, am_r) = ys_r
            choices = chf_r.T                 # (K, C) final-attempt arm
            lats = lat_r.T
            procs = proc_r.T
            att_kc = att_r.T                  # (K, C) i32 attempts
            dropped_kc = drop_r.T             # (K, C) bool
            if batched_record:
                # all C*A attempts land in the step's ONE fused ring
                # scatter, columns in chronological (round-major,
                # attempt-minor) order — record_rings_batch is generic
                # in its column count
                ch_all = jnp.transpose(ach_r, (2, 0, 1)).reshape(
                    K, C * A)
                obs_all = jnp.transpose(aobs_r, (2, 0, 1)).reshape(
                    K, C * A)
                m_all = jnp.transpose(am_r, (2, 0, 1)).reshape(K, C * A)
                state = strat["record_rings"](state, ch_all, obs_all, t,
                                              m_all)
        # retry exhaustions for the flight recorder: snapshot the
        # deadline-dropped counts BEFORE admission sheds are merged
        # into dropped_kc below (sheds get their own event kind)
        retry_drop_k = (dropped_kc.astype(jnp.float32).sum(-1)
                        if rec_on and res_on else None)
        if ctl_on and ccfg.admit:
            # admission-shed slots: issued from the client's view (a
            # denied client is a failed client — shedding can only win
            # by protecting the admitted majority, never by shrinking
            # the QoS denominator) but never served: censor the
            # latency past tau, mark them dropped with zero attempts,
            # and keep them out of the routing/latency statistics.
            shed_kc = mask_all & ~mask_adm
            lats = jnp.where(shed_kc, jnp.inf, lats)
            dropped_kc = dropped_kc | shed_kc
            served_kc = mask_adm
        else:
            served_kc = None
        # dropped requests carry the censor sentinel (> tau), so the
        # shared reward rule scores them 0 without a special case
        rewards = (lats <= cfg.tau).astype(jnp.float32)
        issued = mask_all

        if trace:
            ys = SimOutputs(
                rewards=rewards, issued=issued, choices=choices,
                latency=lats, proc_lat=procs, arrivals=arrivals,
                queue=q_start, weights=w_now, true_mu=mu_true, regret=reg,
                eps=strat["eps"](state), attempts=att_kc,
                dropped=dropped_kc)
        else:
            acc = qm.update_accumulator(
                acc, rewards=rewards, issued=issued, choices=choices,
                procs=procs, arrivals=arrivals, regret=reg, mu=mu_true,
                t_idx=t_idx, warmup_steps=warmup_steps, marks=marks,
                ev_pre_steps=ev_pre_steps,
                ev_bucket_steps=ev_bucket_steps, attempts=att_kc,
                dropped=dropped_kc, brk_open=brk_open_step,
                served=served_kc)
            issf = issued.astype(jnp.float32)
            ys = StepSeries(succ=(rewards * issf).sum(),
                            issued=issf.sum(), regret=reg.sum(),
                            attempts=att_kc.astype(jnp.float32).sum())
        if ctl_on:
            # step-end feedback: fold the fleet QoS/timeout totals into
            # the rolling EMAs the admission signal reads next step.
            # Under player sharding this (4,) observation must be
            # global or the replicated controller state would diverge
            # across shards — the control plane's ONE new in-loop
            # collective.
            issf_c = issued.astype(jnp.float32)
            attf_c = att_kc.astype(jnp.float32)
            compl_c = issf_c * (1.0 - dropped_kc.astype(jnp.float32))
            obs = jnp.stack([(rewards * issf_c).sum(), issf_c.sum(),
                             (attf_c - compl_c).sum(), attf_c.sum()])
            if pshard is not None:
                obs = jax.lax.psum(obs, pshard.axis)
            ctl = qc.control_observe(ccfg, ctl, obs, cfg.dt)
        if rec_on:
            # flight recorder: append this step's events from
            # quantities the step already computed — per-player lanes
            # are shard-local, fleet lanes (marks, control actions) are
            # recorded only by the shard holding global player 0, so
            # there is no new collective on the players axis.
            issf_r = issued.astype(jnp.float32)
            rec = obr.record_step(
                rcfg, rec, t_idx=t_idx, pids=pids, marks=marks,
                miss_k=((1.0 - rewards) * issf_r).sum(-1),
                iss_k=issf_r.sum(-1),
                retry_drop_k=retry_drop_k,
                shed_k=(shed_kc.astype(jnp.float32).sum(-1)
                        if ctl_on and ccfg.admit else None),
                open_now=(qb.breaker_is_open(brk, t) if brk_on
                          else None),
                ctl_deltas=ctl_deltas if ctl_on else None)
        return (state, q, act, acc, groups, pids, brk, ctl, rec), ys

    return init_fn, step_fn


# PRNG salt separating tenant round-key folds from every other fold the
# engine makes off the round key (resilience uses 101 and 1000+a, but
# never composes with tenancy anyway). Folding per tenant makes tenant
# s's draw stream a pure function of (step key, round, tenant, global
# player id) — invariant to S and to how the player axis is sharded.
_TENANT_SALT = 7001


def _build_tenant_parts(
    strategy_name: str,
    cfg: SimConfig,
    K: int,
    M: int,
    fused: bool = True,
    trace: bool = True,
    warmup_steps: int = 0,
    pshard: PlayerSharding | None = None,
    **strategy_kw,
):
    """The multi-tenant engine: S services on one shared fleet.

    Same ``(init_fn, step_fn)`` contract and 9-slot carry layout as
    ``build_sim_parts`` — the strategy-state and accumulator slots hold
    S-tuples (one independent bandit fleet and one
    ``MetricAccumulator`` per tenant) and the queue is the shared
    (S, M) per-tenant backlog; the chunked/checkpointed drivers index
    the carry positionally and work unchanged.

    Queue model: a request's position in line is the TOTAL instance
    backlog ``q.sum(0)`` (tenants share single-worker queues), its
    service draw uses the tenant's effective row ``s_eff[s]``
    (``tenancy.TenancyConfig``: per-tenant demand scale + cross-service
    interference proportional to the backlog share OTHER tenants hold),
    and the per-round drain is work-conserving processor sharing: the
    round's ``dt/C`` seconds of capacity retire the same fraction of
    every tenant's backlog (``work = sum_s q[s]*s_eff[s]`` seconds
    outstanding; each instance completes ``min(1, (dt/C)/work)`` of
    it). At S=1 this reduces exactly to the single-service drain — but
    S=1 configs never trace this path (``build_sim_parts`` dispatch).

    Sharding: per-tenant bandit state is per-player and shards on the
    ``players`` axis like the single-service engine; the one in-loop
    collective stays one psum per round, now of the stacked (S, M)
    arrival matrix. Tenant draws fold ``_TENANT_SALT + s`` off the
    round key and then key per-player noise by global id, so sharded
    and unsharded multi-tenant runs match on counting statistics
    exactly. The resilience / control / recorder layers do not compose
    with tenancy yet (statically refused); the fused-round megakernel
    is single-service and falls back to the round scan.
    """
    tn = cfg.tenancy
    S = tn.S
    if trace:
        raise ValueError(
            "the multi-tenant engine is streaming-only: per-tenant "
            "trajectories are O(S*T*K*...) (set trace=False)")
    if cfg.resilience_on or cfg.max_retries or cfg.breaker_threshold:
        raise ValueError(
            "tenancy does not compose with the resilience layer yet: "
            "run multi-tenant configs with attempt_timeout=0, "
            "max_retries=0, breaker_threshold=0")
    if qc.control_enabled(cfg):
        raise ValueError(
            "tenancy does not compose with the control plane yet: "
            "run multi-tenant configs with control=None")
    if obr.recorder_enabled(cfg):
        raise ValueError(
            "tenancy does not compose with the flight recorder yet: "
            "run multi-tenant configs with recorder=None")
    if "params" in strategy_kw:
        raise ValueError(
            "explicit params= would share one tau across tenants; "
            "per-tenant params are derived from TenancyConfig.taus")
    if pshard is not None and pshard.shards == 1:
        pshard = None
    if pshard is not None and K % pshard.shards:
        raise ValueError(
            f"K={K} players must be a multiple of the "
            f"{pshard.shards}-way '{pshard.axis}' mesh axis")
    K_glob = K
    K = K if pshard is None else K // pshard.shards
    T, C = cfg.num_steps, cfg.max_clients
    taus = tuple(float(x) for x in tn.taus)
    scales = jnp.asarray(tn.scales, jnp.float32)
    xi = float(tn.interference)
    # one independent strategy instance per tenant, each built against
    # the tenant's own deadline (BanditParams/DecSarsaParams bake tau)
    strats = tuple(
        make_strategy(strategy_name,
                      dataclasses.replace(cfg, tau=taus[s]), K, M,
                      pshard=pshard, **strategy_kw)
        for s in range(S))
    batched_record = fused and strats[0].get("record_rings") is not None
    subset_maint = fused and strats[0].get("maintain_subset") is not None
    n_phases = max(cfg.maint_every, 1)
    n_blocks = -(-K_glob // n_phases)
    group_width = (n_blocks if pshard is None
                   else min(n_blocks, -(-K // n_phases) + 1))
    ev_pre_steps = max(1, int(round(cfg.ev_pre / cfg.dt)))
    ev_bucket_steps = max(1, int(round(cfg.ev_bucket / cfg.dt)))

    def eff_service(q, s_m):
        """(S, M) effective service row at the current (S, M) backlog:
        per-tenant demand scale, plus interference inflating a tenant's
        service time by xi per unit share of backlog held by OTHERS."""
        base = s_m[None, :] * scales[:, None]
        if xi == 0.0:
            return jnp.broadcast_to(base, (S, M))
        q_tot = q.sum(0)
        other = (q_tot[None, :] - q) / (1.0 + q_tot[None, :])
        return base * (1.0 + xi * other)

    def init_fn(rtt, active0, key, pids=None):
        if pids is None:
            if pshard is not None:
                raise ValueError(
                    "player-sharded init needs the shard's global "
                    "player ids (pids) as a sharded operand")
            pids = jnp.arange(K, dtype=jnp.int32)
        k_init, k_phase, k_scan = jax.random.split(key, 3)
        s0 = tuple(
            strats[s]["init"](rtt, active0,
                              jax.random.fold_in(k_init, s), pids)
            for s in range(S))
        q0 = jnp.zeros((S, M), jnp.float32)
        groups = _stagger_groups(k_phase, K_glob, n_phases, group_width,
                                 pids[0], K)
        accs = tuple(
            qm.init_accumulator(K, M, C, n_marks=qs.MAX_MARKS,
                                ev_buckets=cfg.ev_buckets)
            for _ in range(S))
        keys = jax.random.split(k_scan, T)
        return (s0, q0, active0, accs, groups, pids,
                None, None, None), keys

    def step_fn(rtt, marks, carry, xs):
        states, q, prev_active, accs, groups, pids, _b, _c, _r = carry
        t_idx, nc, act, rtt_scale, cut_k, cut_m, s_m, k_step, group = xs
        # nc is the (S, K) per-tenant client schedule for this step
        t = t_idx.astype(jnp.float32) * cfg.dt
        rtt_t = rtt * rtt_scale[None, :] + jnp.minimum(
            cut_k[:, None], cut_m[None, :])

        changed = jnp.any(act != prev_active)
        states = tuple(
            jax.lax.cond(
                changed,
                lambda st, _s=s: strats[_s]["on_activity"](st, act,
                                                           rtt_t, t),
                lambda st: st, states[s])
            for s in range(S))
        if subset_maint:
            states = tuple(
                strats[s]["maintain_subset"](states[s], rtt_t, t, group)
                for s in range(S))
        else:
            lb_mask = jnp.zeros((K,), bool).at[group].set(
                True, mode="drop")
            states = tuple(
                strats[s]["maintain"](states[s], rtt_t, t, lb_mask)
                for s in range(S))

        # oracle + regret per tenant at step start, against the
        # step-start TOTAL backlog and the tenant's effective row
        q_tot0 = q.sum(0)
        s_eff0 = eff_service(q, s_m)
        mu_s = tuple(
            _true_mu_tau(rtt_t, q_tot0, taus[s], cfg.proc_sigma,
                         s_eff0[s])
            for s in range(S))
        reg_s = tuple(
            step_regret(strats[s]["weights"](states[s]), mu_s[s], act)
            for s in range(S))
        mask_s = tuple(jnp.arange(C)[None, :] < nc[s][:, None]
                       for s in range(S))
        kidx = jnp.arange(K)

        def round_body(rc, r):
            states, q, arrivals = rc
            q_tot = q.sum(0)
            s_eff = eff_service(q, s_m)
            k_r = jax.random.fold_in(k_step, r)
            new_states, arr_rows, outs = [], [], []
            for s in range(S):
                k_t = jax.random.fold_in(k_r, _TENANT_SALT + s)
                k_sel, k_noise = jax.random.split(k_t)
                mask = r < nc[s]
                choice, st = strats[s]["select"](states[s], k_sel, t,
                                                 act, pids)
                z = jnp.exp(cfg.proc_sigma
                            * prand.player_normal(k_noise, pids))
                # position in line is the TOTAL backlog: the queue is
                # shared; only the service draw is tenant-specific
                proc = (q_tot[choice] + 1.0) * s_eff[s][choice] * z
                lat = rtt_t[kidx, choice] + proc
                if batched_record:
                    st = strats[s]["record_feedback"](st, choice, lat,
                                                      t, mask)
                else:
                    st = strats[s]["record"](st, choice, lat, t, mask)
                arr_rows.append(jax.ops.segment_sum(
                    mask.astype(jnp.float32), choice, num_segments=M))
                new_states.append(st)
                outs.append((choice, lat, proc))
            arr_sm = jnp.stack(arr_rows)               # (S, M) local
            # still ONE psum per round: the stacked per-tenant arrival
            # matrix crosses the players axis in a single collective
            arr_all = (arr_sm if pshard is None
                       else jax.lax.psum(arr_sm, pshard.axis))
            # work-conserving processor-sharing drain: this round's
            # dt/C seconds retire the same fraction f of every
            # tenant's backlog (work = seconds outstanding per
            # instance at the round-start effective rows)
            b = q + arr_all
            work = (b * s_eff).sum(0)
            f = jnp.minimum(1.0, (cfg.dt / C) / jnp.maximum(work, 1e-9))
            q = b * (1.0 - f[None, :])
            return (tuple(new_states), q, arrivals + arr_sm), \
                tuple(outs)

        (states, q, arr_sm), ys_r = jax.lax.scan(
            round_body, (states, q, jnp.zeros((S, M), jnp.float32)),
            jnp.arange(C))

        new_states, new_accs = [], []
        succ_v, iss_v, reg_v = [], [], []
        for s in range(S):
            ch_r, lat_r, proc_r = ys_r[s]
            choices, lats, procs = ch_r.T, lat_r.T, proc_r.T   # (K, C)
            st = states[s]
            if batched_record:
                st = strats[s]["record_rings"](st, choices, lats, t,
                                               mask_s[s])
            rewards = (lats <= taus[s]).astype(jnp.float32)
            issued = mask_s[s]
            acc = qm.update_accumulator(
                accs[s], rewards=rewards, issued=issued,
                choices=choices, procs=procs, arrivals=arr_sm[s],
                regret=reg_s[s], mu=mu_s[s], t_idx=t_idx,
                warmup_steps=warmup_steps, marks=marks,
                ev_pre_steps=ev_pre_steps,
                ev_bucket_steps=ev_bucket_steps,
                attempts=issued.astype(jnp.int32),
                dropped=jnp.zeros_like(issued), brk_open=None,
                served=None)
            issf = issued.astype(jnp.float32)
            succ_v.append((rewards * issf).sum())
            iss_v.append(issf.sum())
            reg_v.append(reg_s[s].sum())
            new_states.append(st)
            new_accs.append(acc)
        # per-step series carry one scalar PER TENANT: the streamed
        # StepSeries fields come out (T, S)
        ys = StepSeries(succ=jnp.stack(succ_v),
                        issued=jnp.stack(iss_v),
                        regret=jnp.stack(reg_v),
                        attempts=jnp.stack(iss_v))
        return (tuple(new_states), q, act, tuple(new_accs), groups,
                pids, None, None, None), ys

    return init_fn, step_fn


def build_sim_fn(
    strategy_name: str,
    cfg: SimConfig,
    K: int,
    M: int,
    fused: bool = True,
    trace: bool = True,
    warmup_steps: int = 0,
    pshard: PlayerSharding | None = None,
    **strategy_kw,
):
    """Build a traceable ``run(rtt, drivers, key)``.

    ``drivers`` is a compiled-scenario :class:`Drivers` pytree (see
    ``repro.continuum.scenarios``); ``scenarios.neutral_drivers``
    reproduces the pre-scenario-engine constant schedules bit-for-bit.

    Exposed separately from ``run_sim`` so harnesses can transform it:
    the evaluation suite vmaps the scenario axis into one program per
    strategy and shards its lanes across devices
    (``build_sim_grid_fn``; benchmarks/common.py::get_suite), and
    benchmarks/beyond.py vmaps a traced ``service_time`` to sweep the
    utilization axis (``service_time`` overrides ``drivers.s_m`` with a
    broadcast scalar, so it may be a traced vmap axis).

    ``trace=True`` returns full ``SimOutputs`` trajectories (O(T·K·M)
    memory — the debug/inspection mode); ``trace=False`` returns
    ``StreamOutputs`` (``MetricAccumulator`` + O(T) scalar series), the
    fleet-scale mode. ``warmup_steps`` gates the post-warmup
    accumulator fields and is ignored in trace mode.

    With ``pshard`` (see ``build_sim_parts``) the returned ``run`` is
    the per-shard program of a player-sharded streaming simulation and
    must be traced inside a ``shard_map`` over ``pshard.axis`` — its
    inputs/outputs carry local (K/shards,) player slices, and the
    fleet-level accumulator fields and the ``StepSeries`` scalars are
    ``psum``-reduced here, once, after the scan (the per-round arrival
    psum inside the scan is the only in-loop collective).
    ``build_sim_players_fn`` wraps this with the right specs.

    ``fused=False`` forces the pre-refactor step structure (per-round
    ring scatters + full-width maintenance gated only by ``lb_mask``)
    even for strategies that support the fused path — kept as the
    reference point for benchmarks/bandit_scale.py.
    """
    T = cfg.num_steps
    tn_S = qt.tenancy_size(cfg)
    init_fn, step_fn = build_sim_parts(
        strategy_name, cfg, K, M, fused=fused, trace=trace,
        warmup_steps=warmup_steps, pshard=pshard, **strategy_kw)

    def run(rtt, drivers, key, service_time=None, pids=None):
        if tn_S and (drivers.n_clients.ndim != 3
                     or drivers.n_clients.shape[-2] != tn_S):
            raise ValueError(
                f"multi-tenant run needs a (T, S={tn_S}, K) n_clients "
                f"schedule (got {drivers.n_clients.shape}): compile "
                "with scenarios.compile_tenant_scenario / "
                "tenant_neutral_drivers / broadcast_tenants")
        if service_time is not None:
            drivers = drivers._replace(s_m=jnp.broadcast_to(
                jnp.asarray(service_time, jnp.float32), drivers.s_m.shape))
        carry0, keys = init_fn(rtt, drivers.active[0], key, pids)
        t_idx = jnp.arange(T)
        # per-step maintenance rows, gathered from the stagger table
        # ONCE outside the loop and scanned in (see step_fn)
        grows = carry0[4][t_idx % max(cfg.maint_every, 1)]
        xs = (t_idx,
              *(getattr(drivers, f) for f in qs.STEP_FIELDS), keys, grows)
        carry, ys = jax.lax.scan(
            lambda c, x: step_fn(rtt, drivers.marks, c, x), carry0, xs)
        if trace:
            return ys
        acc = carry[3]
        if pshard is not None and pshard.shards > 1:
            # fleet-level fields accumulated shard-local partials all
            # scan long; reduce them once here. Counting fields are
            # integer-valued f32 sums, so the psum is exact; the regret
            # series is the one genuinely-float reduction (f32
            # reassociation tolerance). steps_measured is a pure
            # function of t_idx — already replicated, no reduction.
            def allsum(x):
                return jax.lax.psum(x, pshard.axis)

            def reduce_acc(a):
                return a._replace(arrivals_m=allsum(a.arrivals_m),
                                  proc_hist=allsum(a.proc_hist),
                                  ev_succ=allsum(a.ev_succ),
                                  ev_n=allsum(a.ev_n))

            # the tenant engine carries one accumulator per tenant;
            # each reduces its fleet-level partials independently
            acc = (tuple(reduce_acc(a) for a in acc) if tn_S
                   else reduce_acc(acc))
            ys = StepSeries(*(allsum(y) for y in ys))
        # control counters ride out with the stream: fleet-level fields
        # are replicated across player shards by construction (every
        # decision input is replicated), shed_k is per-player and
        # concatenates like the other (K,) accumulator fields
        ctl = carry[7]
        # the recorder ring is per-shard state and stays shard-local:
        # under player sharding the out-spec concatenates the rings
        # ((cap,) -> (D*cap,)) and pointers ((1,) -> (D,));
        # obs.recorder.recorder_events decodes either layout.
        return StreamOutputs(acc=acc, series=ys,
                             ctrl=ctl.counters if ctl is not None else None,
                             rec=carry[8])

    return run


def build_sim_chunks(
    strategy_name: str,
    cfg: SimConfig,
    K: int,
    M: int,
    fused: bool = True,
    warmup_steps: int = 0,
    **strategy_kw,
):
    """Chunked-horizon streaming: ``(init_fn, chunk_fn)``.

    ``chunk_fn(rtt, carry, t_idx, drivers, keys)`` scans the given time
    slice — ``drivers`` is a ``scenarios.slice_drivers`` slice whose
    per-step fields span the chunk (marks ride along whole, they are
    global step indices) — and returns ``(carry, StepSeries)``. Jit it
    with ``donate_argnums=(1,)`` (and the slice args) so the carry
    buffers are reused in place and peak device memory stays O(K·M) +
    one chunk of O(T) scalars regardless of the horizon.
    ``run_sim_stream`` is the reference driver.
    """
    init_fn, step_fn = build_sim_parts(
        strategy_name, cfg, K, M, fused=fused, trace=False,
        warmup_steps=warmup_steps, **strategy_kw)

    def chunk_fn(rtt, carry, t_idx, drivers, keys, service_time=None):
        if service_time is not None:
            drivers = drivers._replace(s_m=jnp.broadcast_to(
                jnp.asarray(service_time, jnp.float32), drivers.s_m.shape))
        grows = carry[4][t_idx % max(cfg.maint_every, 1)]
        xs = (t_idx, *(getattr(drivers, f) for f in qs.STEP_FIELDS), keys,
              grows)
        return jax.lax.scan(
            lambda c, x: step_fn(rtt, drivers.marks, c, x), carry, xs)

    return init_fn, chunk_fn


# The O(T) driver buffers are donated, but ONLY when this module
# constructed every leaf itself (caller passed neither drivers nor
# n_clients/active): donating a caller-supplied array would invalidate
# it under the caller's feet on backends that implement donation, and
# callers routinely reuse one Drivers batch across strategies. rtt and
# key are never donated (rtt is shared across strategies; key is
# 8 bytes). Donated buffers XLA cannot alias to an output draw a
# UserWarning per call; that is the expected case here (they are
# freed, not aliased), so the dispatch silences exactly that message.

@contextlib.contextmanager
def _quiet_donation():
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


def _resolve_drivers(cfg, K, M, drivers, n_clients, active):
    """One Drivers pytree from whichever input style the caller used:
    a compiled scenario (``drivers``), legacy ``n_clients``/``active``
    schedules wrapped in neutral modulation, or the constant defaults.
    Donation (argnum 1 in every driver signature below) only when every
    leaf is module-created."""
    if drivers is not None:
        if n_clients is not None or active is not None:
            raise ValueError("pass either drivers= or n_clients=/active=, "
                             "not both")
        return drivers, ()
    fresh = n_clients is None and active is None
    drv = qs.neutral_drivers(cfg, K, M, n_clients=n_clients, active=active)
    if qt.tenancy_enabled(cfg):
        # module-built single-tenant schedules broadcast to every
        # tenant; explicitly-passed Drivers (above) must already carry
        # the (T, S, K) tenant axis — run() checks and says how
        drv = qs.broadcast_tenants(drv, cfg.tenancy.S)
    return drv, ((1,) if fresh else ())


def run_sim(
    strategy_name: str,
    rtt: jax.Array,              # (K, M) base LB->instance RTT [s]
    cfg: SimConfig,
    key: jax.Array,
    n_clients: jax.Array | None = None,   # (T, K) i32 active clients per LB
    active: jax.Array | None = None,      # (T, M) bool instance liveness
    drivers: Drivers | None = None,       # compiled scenario (wins over kwargs)
    **strategy_kw,
) -> SimOutputs:
    """Run one topology × strategy for the full horizon. jit-compiled.

    Full-trajectory (trace) mode. ``drivers`` takes a compiled
    scenario; the legacy ``n_clients``/``active`` kwargs wrap into
    neutral drivers. Default-constructed driver buffers are donated to
    the computation; caller-supplied arrays are left untouched.
    """
    K, M = rtt.shape
    drv, donate = _resolve_drivers(cfg, K, M, drivers, n_clients, active)
    run = build_sim_fn(strategy_name, cfg, K, M, **strategy_kw)
    with _quiet_donation():
        return jax.jit(run, donate_argnums=donate)(rtt, drv, key)


def run_sim_batch(
    strategy_name: str,
    rtts: jax.Array,             # (S, K, M) one base RTT matrix per lane
    cfg: SimConfig,
    keys: jax.Array,             # (S, 2) one PRNG key per scenario
    n_clients: jax.Array | None = None,   # (T, K), shared across scenarios
    active: jax.Array | None = None,      # (T, M), shared across scenarios
    drivers: Drivers | None = None,       # shared OR (S, ·) batched pytree
    **strategy_kw,
) -> SimOutputs:
    """Vmap the scenario axis: one compiled program for all S seeds.

    Returns SimOutputs with a leading (S,) axis on every field. The
    evaluation grid's per-strategy seeds share every static shape, so
    batching them removes S-1 compilations and lets XLA overlap the
    scenario lanes. A ``drivers`` batch from ``stack_drivers`` gives
    every lane its own compiled scenario; a plain ``Drivers`` (or the
    legacy kwargs) is shared across lanes. Defaulted driver buffers are
    donated. This is the trace-mode batch driver; the streaming,
    device-sharded grid is ``run_sim_grid``.
    """
    S, K, M = rtts.shape
    drv, donate = _resolve_drivers(cfg, K, M, drivers, n_clients, active)
    # lane-batched detection keys off `active` ((T, M) unbatched,
    # (S, T, M) batched): the tenant engine's UNBATCHED n_clients is
    # already (T, S_tenants, K) = ndim 3, so n_clients can't tell
    batched = drv.active.ndim == 3
    run = build_sim_fn(strategy_name, cfg, K, M, **strategy_kw)
    with _quiet_donation():
        return jax.jit(jax.vmap(run, in_axes=(0, 0 if batched else None, 0)),
                       donate_argnums=donate)(rtts, drv, keys)


def _mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _stream_specs(mesh, lead: tuple = (), ctrl_on: bool = False,
                  rec_on: bool = False, tn_S: int = 0):
    """``shard_map`` specs for a (possibly vmapped) streaming run.

    Resolved per field through the logical rule table
    (``repro.sharding.partitioning``): the player-dimension of ``rtt``,
    the (T, K) driver rows and the per-player accumulator fields carry
    the logical ``players`` axis; everything instance- or fleet-wide
    (queues-adjacent (M,) schedules, the reduced accumulator fields,
    the ``StepSeries`` scalars) is replicated across player shards.
    ``lead`` prepends logical axes for a leading batch dimension —
    ``("grid",)`` for the lane axis of the evaluation grid. Mesh axes
    absent from ``mesh`` drop out, so the same specs serve the 1-D grid
    mesh and the 2-D (``data``, ``players``) continuum mesh.

    ``tn_S`` > 0 is the multi-tenant layout: ``n_clients`` carries a
    replicated (S,) tenant axis between T and K, the accumulator slot
    is an S-tuple of per-tenant accumulator specs, and the series
    scalars gain a trailing replicated tenant axis. The tenant axis is
    NEVER sharded — tenants share the fleet, so every device simulates
    all S services for its players/lanes.
    """
    from repro.sharding import logical_to_spec

    def spec(*axes):
        return logical_to_spec(tuple(lead) + axes, mesh)

    in_specs = (
        spec("players", None),                    # rtt (K, M)
        Drivers(
            n_clients=(spec(None, None, "players") if tn_S
                       else spec(None, "players")),   # (T[, S], K)
            active=spec(None, None),              # (T, M) — replicated
            rtt_scale=spec(None, None),
            rtt_cut_k=spec(None, "players"),      # (T, K)
            rtt_cut_m=spec(None, None),
            s_m=spec(None, None),
            marks=spec(None)),
        spec(None),                               # key (2,)
    )
    acc_spec = qm.MetricAccumulator(
        succ_kc=spec("players", None),
        n_kc=spec("players", None),
        arrivals_m=spec(None),                    # psum-reduced
        choice_counts=spec("players", None),
        proc_hist=spec(None, None),               # psum-reduced
        regret_k=spec("players"),
        vb_k=spec("players"),
        prev_mu=spec("players", None),
        steps_measured=spec(),                    # replicated by design
        ev_succ=spec(None, None),                 # psum-reduced
        ev_n=spec(None, None),                    # psum-reduced
        att_k=spec("players"),
        timeout_k=spec("players"),
        drop_k=spec("players"),
        open_km=spec("players", None))
    series_spec = (
        StepSeries(succ=spec(None, None), issued=spec(None, None),
                   regret=spec(None, None), attempts=spec(None, None))
        if tn_S else
        StepSeries(succ=spec(None), issued=spec(None),
                   regret=spec(None), attempts=spec(None)))
    out_specs = StreamOutputs(
        acc=(tuple(acc_spec for _ in range(tn_S)) if tn_S
             else acc_spec),
        series=series_spec,
        ctrl=(None if not ctrl_on else qc.ControlCounters(
            shed_k=spec("players"),               # per-player, shard-local
            admit_frac_sum=spec(),                # replicated by design
            scale_up=spec(),
            scale_down=spec(),
            migrations=spec(),
            ctrl_up_m=spec(None),                 # fleet-level, replicated
            steps=spec())),
        rec=(None if not rec_on else obr.RecorderState(
            # each shard keeps its own ring; the out-spec concatenates
            # them along the players axis ((cap,) -> (D*cap,)) and the
            # (1,) pointers to (D,) — recorder_events splits them back
            step=spec("players"),
            kind=spec("players"),
            entity=spec("players"),
            value=spec("players"),
            ptr=spec("players"),
            prev_open=spec("players", None))))
    return in_specs, out_specs


def build_sim_grid_fn(
    strategy_name: str,
    cfg: SimConfig,
    K: int,
    M: int,
    mesh=None,
    warmup_steps: int = 0,
    fused: bool = True,
    **strategy_kw,
):
    """Traceable sharded evaluation grid: ``(run_grid, mesh)``.

    ``run_grid(rtts, drivers, keys)`` is the vmapped streaming run
    (``run_sim_batch`` shape, ``trace=False``) with the scenario/seed
    axis ``shard_map``-ed over the ``data`` axis of ``mesh`` — a 1-D
    mesh from ``launch.mesh.make_grid_mesh()`` by default. ``drivers``
    is an (S, ·)-batched ``Drivers`` pytree (``scenarios.stack_drivers``
    of compiled scenarios), so scenario *diversity* — surges, failures,
    drift, per-instance slowdowns — spreads across devices exactly
    like seeds do. Grid lanes are independent, so each device scans
    its own S/D scenarios with per-device ``MetricAccumulator``/
    ``StepSeries`` carries; outputs stay device-sharded along the
    scenario axis until the caller reads them. When the mesh has a
    single device the plain ``jax.vmap`` body is returned unwrapped —
    bit-for-bit the pre-sharding grid program.

    A 2-D (``data``, ``players``) mesh (``make_continuum_mesh``) adds
    the second scaling axis: lanes still spread over ``data``, and
    *inside* every lane the K players split over ``players``
    (``PlayerSharding`` program: per-round arrival psum, shard-local
    maintenance, reduced fleet metrics — see ``build_sim_parts``). K
    must then divide the ``players`` axis size; lane results are
    unchanged (counting stats exact, psum-reduced floats to f32
    tolerance, tests/test_sharded_players.py).

    S not divisible by the data-axis size is handled inside the traced
    function by padding with copies of the last scenario lane and
    slicing the pad back off — wasted lanes, never wrong results. On a
    2-D mesh the traced pad is refused (an XLA sharding-propagation
    bug mis-distributes a concat feeding the 2-axis ``shard_map``);
    ``run_sim_grid`` pads eagerly instead. Sharded and unsharded grids
    run the same per-lane program, so results match the single-device
    vmap exactly on every accumulator field
    (tests/test_sharded_grid.py, tests/test_sharded_players.py).

    Exposed AOT-style (like ``build_sim_fn``) so harnesses can
    ``jit(...).lower()`` it and measure compile time apart from run
    time (benchmarks/common.py::get_suite).
    """
    from jax.experimental.shard_map import shard_map

    from repro.launch.mesh import make_grid_mesh

    mesh = make_grid_mesh() if mesh is None else mesh
    sizes = _mesh_axis_sizes(mesh)
    Dp = sizes.get("players", 1)
    Dd = int(mesh.devices.size) // Dp
    pshard = None
    if Dp > 1:
        if K % Dp:
            raise ValueError(
                f"K={K} players must be a multiple of the {Dp}-way "
                f"'players' axis of the grid mesh (pad K or reshape "
                f"the mesh)")
        pshard = PlayerSharding("players", Dp)
    run = build_sim_fn(strategy_name, cfg, K, M, fused=fused, trace=False,
                       warmup_steps=warmup_steps, pshard=pshard,
                       **strategy_kw)
    vrun = jax.vmap(run, in_axes=(0, 0, 0))
    if int(mesh.devices.size) == 1:
        return vrun, mesh

    in_specs, out_specs = _stream_specs(mesh, lead=("grid",),
                                        ctrl_on=qc.control_enabled(cfg),
                                        rec_on=obr.recorder_enabled(cfg),
                                        tn_S=qt.tenancy_size(cfg))
    if pshard is None:
        inner = shard_map(vrun, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    else:
        # the per-shard program needs its global player ids as a
        # SHARDED OPERAND (see build_sim_parts) — one arange(K) split
        # over the players axis, broadcast over lanes by the vmap
        from repro.sharding import logical_to_spec
        vrun_p = jax.vmap(
            lambda rtts_, drv_, keys_, pids_: run(rtts_, drv_, keys_,
                                                  pids=pids_),
            in_axes=(0, 0, 0, None))
        inner_p = shard_map(
            vrun_p, mesh=mesh,
            in_specs=(*in_specs, logical_to_spec(("players",), mesh)),
            out_specs=out_specs, check_rep=False)

        def inner(rtts_, drv_, keys_):
            return inner_p(rtts_, drv_, keys_,
                           jnp.arange(K, dtype=jnp.int32))

    def _pad_lanes(x, pad):
        return jnp.concatenate([x, jnp.repeat(x[-1:], pad, 0)])

    def run_grid(rtts, drivers, keys):
        S = rtts.shape[0]
        pad = (-S) % Dd
        if pad and pshard is not None:
            # In-trace padding feeds a concat into the 2-axis
            # shard_map, and XLA's sharding propagation through it
            # mis-distributes the operands across (data, players) —
            # lanes then simulate with other lanes' data (observed on
            # jax 0.4.37 CPU; neither sharding constraints nor
            # optimization barriers stop it). Pad eagerly instead:
            # run_sim_grid does this automatically.
            raise ValueError(
                f"S={S} lanes must be a multiple of the {Dd}-way data "
                f"axis when the mesh also shards players; pre-pad the "
                f"lane axis (run_sim_grid does) or reshape the mesh")
        if pad:
            rtts = _pad_lanes(rtts, pad)
            keys = _pad_lanes(keys, pad)
            drivers = jax.tree.map(lambda x: _pad_lanes(x, pad), drivers)
        out = inner(rtts, drivers, keys)
        if pad:
            out = jax.tree.map(lambda x: x[:S], out)
        return out

    # drivers that must pre-pad eagerly (run_sim_grid on 2-D meshes)
    # read the lane-axis shard count from here instead of re-deriving
    # the mesh split — one source of truth for the S-divisibility rule
    run_grid.lane_shards = Dd if pshard is not None else 1
    return run_grid, mesh


def run_sim_grid(
    strategy_name: str,
    rtts: jax.Array,             # (S, K, M) one base RTT matrix per lane
    cfg: SimConfig,
    keys: jax.Array,             # (S, 2) one PRNG key per scenario
    n_clients: jax.Array | None = None,   # (T, K), shared across scenarios
    active: jax.Array | None = None,      # (T, M), shared across scenarios
    drivers: Drivers | None = None,       # shared OR (S, ·) batched pytree
    warmup_steps: int = 0,
    mesh=None,
    **strategy_kw,
) -> StreamOutputs:
    """Sharded evaluation grid driver: ``run_sim_batch`` semantics,
    streaming outputs, scenario lanes spread over every device.

    Returns ``StreamOutputs`` with a leading (S,) axis on every field.
    An un-batched ``drivers`` (or the legacy kwargs/defaults) is
    broadcast to every lane; a ``stack_drivers`` batch drives each lane
    with its own scenario. Single-device meshes degrade to the plain
    vmapped streaming grid. Defaulted driver buffers are donated.

    On a 2-D (``data``, ``players``) mesh, lanes not dividing the data
    axis are padded *eagerly* here (copies of the last lane, sliced
    back off the outputs) — the 1-D grid pads inside the traced
    program, but a traced pad feeding the 2-axis ``shard_map``
    trips an XLA sharding-propagation bug (see ``build_sim_grid_fn``).
    """
    S, K, M = rtts.shape
    drv, donate = _resolve_drivers(cfg, K, M, drivers, n_clients, active)
    run_grid, mesh = build_sim_grid_fn(
        strategy_name, cfg, K, M, mesh=mesh, warmup_steps=warmup_steps,
        **strategy_kw)
    pad = (-S) % getattr(run_grid, "lane_shards", 1)
    if pad:
        def _pad(x):
            return jnp.concatenate([x, jnp.repeat(x[-1:], pad, 0)])
        rtts = _pad(rtts)
        keys = _pad(keys)
        if drv.active.ndim == 3:        # lane-batched (see run_sim_batch)
            drv = jax.tree.map(_pad, drv)
    S_run = S + pad
    fn = run_grid
    if drv.active.ndim == 2:
        # shared schedule -> one lane per scenario; broadcast INSIDE
        # the traced program so the host never materializes S copies
        # of identical (T, ·) buffers
        def fn(rtts_, drv_, keys_):
            drv_b = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (S_run,) + x.shape),
                drv_)
            return run_grid(rtts_, drv_b, keys_)
    with _quiet_donation():
        out = jax.jit(fn, donate_argnums=donate)(rtts, drv, keys)
    if pad:
        out = jax.tree.map(lambda x: x[:S], out)
    return out


def build_sim_players_fn(
    strategy_name: str,
    cfg: SimConfig,
    K: int,
    M: int,
    mesh=None,
    warmup_steps: int = 0,
    fused: bool = True,
    **strategy_kw,
):
    """Traceable player-sharded single simulation: ``(run, mesh)``.

    ``run(rtt, drivers, key)`` is ONE streaming simulation whose player
    axis K is ``shard_map``-ed over the ``players`` axis of ``mesh`` —
    by default ``launch.mesh.make_continuum_mesh()``, which puts every
    device on the player axis. Each device holds K/D players' bandit
    state (rings, weights, KDE stats — the O(K·M·R) memory), scans
    only its shard's selection/feedback/maintenance, and the round
    loop ``psum``s the (M,) per-round arrival vector before the shared
    queue drain (the only in-loop collective; the queues themselves
    stay replicated). Outputs are a full-K ``StreamOutputs``: the
    per-player accumulator fields concatenate across shards, the
    fleet-level fields are psum-reduced. Matches the unsharded engine
    — counting statistics exactly, the psum-reduced regret series to
    f32 reassociation tolerance (tests/test_sharded_players.py).

    The ``players`` axis size must divide K. A mesh whose ``players``
    axis is 1 (or absent) falls back to the plain streaming program —
    bit-for-bit what ``run_sim_stream`` runs.
    """
    from jax.experimental.shard_map import shard_map

    from repro.launch.mesh import make_continuum_mesh

    mesh = make_continuum_mesh() if mesh is None else mesh
    Dp = _mesh_axis_sizes(mesh).get("players", 1)
    if Dp == 1:
        run = build_sim_fn(strategy_name, cfg, K, M, fused=fused,
                           trace=False, warmup_steps=warmup_steps,
                           **strategy_kw)
        return run, mesh
    if K % Dp:
        raise ValueError(
            f"K={K} players must be a multiple of the {Dp}-way "
            f"'players' mesh axis")
    from repro.sharding import logical_to_spec

    run = build_sim_fn(strategy_name, cfg, K, M, fused=fused, trace=False,
                       warmup_steps=warmup_steps,
                       pshard=PlayerSharding("players", Dp), **strategy_kw)
    in_specs, out_specs = _stream_specs(mesh,
                                        ctrl_on=qc.control_enabled(cfg),
                                        rec_on=obr.recorder_enabled(cfg),
                                        tn_S=qt.tenancy_size(cfg))
    # global player ids ride in as a sharded operand (see
    # build_sim_parts): the shard's identity arrives on the same data
    # path as its rtt rows
    inner = shard_map(
        lambda rtt, drv, key, pids: run(rtt, drv, key, pids=pids),
        mesh=mesh, in_specs=(*in_specs, logical_to_spec(("players",), mesh)),
        out_specs=out_specs, check_rep=False)

    def sharded_run(rtt, drivers, key):
        return inner(rtt, drivers, key, jnp.arange(K, dtype=jnp.int32))

    return sharded_run, mesh


def run_sim_players(
    strategy_name: str,
    rtt: jax.Array,              # (K, M)
    cfg: SimConfig,
    key: jax.Array,
    n_clients: jax.Array | None = None,   # (T, K)
    active: jax.Array | None = None,      # (T, M)
    drivers: Drivers | None = None,       # compiled scenario
    warmup_steps: int = 0,
    mesh=None,
    **strategy_kw,
) -> StreamOutputs:
    """Player-sharded streaming driver: ``run_sim_stream`` semantics,
    the K load balancers of ONE simulation split across devices.

    This is the giant-fleet mode: the K=1000 × M=50 cell's ~37 MB of
    bandit state splits D ways, opening K ≫ 10⁴ fleets whose state
    would not fit (or not fit comfortably) on one device — see
    docs/SCALING.md for choosing between this and the grid axis, and
    ``make_continuum_mesh(players=...)`` for splitting devices between
    the two. Defaulted driver buffers are donated; a 1-way player mesh
    degrades to the plain streaming program.
    """
    K, M = rtt.shape
    drv, donate = _resolve_drivers(cfg, K, M, drivers, n_clients, active)
    run, mesh = build_sim_players_fn(
        strategy_name, cfg, K, M, mesh=mesh, warmup_steps=warmup_steps,
        **strategy_kw)
    with _quiet_donation():
        return jax.jit(run, donate_argnums=donate)(rtt, drv, key)


def run_sim_stream(
    strategy_name: str,
    rtt: jax.Array,              # (K, M)
    cfg: SimConfig,
    key: jax.Array,
    n_clients: jax.Array | None = None,   # (T, K)
    active: jax.Array | None = None,      # (T, M)
    drivers: Drivers | None = None,       # compiled scenario
    warmup_steps: int = 0,
    chunk_steps: int | None = None,
    mesh=None,
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 1,
    resume: bool = False,
    stop_at_step: int | None = None,
    **strategy_kw,
) -> StreamOutputs:
    """Streaming run: O(K·M) device memory, O(T) scalar series on host.

    ``chunk_steps`` bounds the compiled scan length: the horizon is
    driven in fixed-size chunks whose carry (strategy state + queue +
    accumulator) is donated back to the next chunk, so device memory is
    independent of ``cfg.horizon``. A trailing remainder chunk compiles
    one extra program; pick ``chunk_steps`` dividing ``num_steps`` to
    avoid it. Chunked and unchunked runs follow the identical per-step
    program on the identical PRNG stream.

    ``mesh`` with a >1 ``players`` axis routes to ``run_sim_players``
    (the player-sharded program); that path does not compose with
    ``chunk_steps`` yet — the sharded scan's memory is already O(K·M/D)
    + O(T) scalars, so chunking only matters for extreme horizons.

    ``checkpoint_dir`` makes the chunked loop fault-tolerant: every
    ``checkpoint_every`` chunks the donated carry plus the series
    drained so far are committed atomically via
    ``checkpoint.Checkpointer`` (snapshot on the caller thread, write
    async). ``resume=True`` restarts from the latest checkpoint in the
    directory — the per-step PRNG stream makes the resumed run equal
    the uninterrupted one *exactly*, for any ``chunk_steps``, and an
    empty directory degrades to a cold start. ``stop_at_step`` halts
    the loop at a chunk boundary >= that step and returns the partial
    result — the hook the kill/resume test (and any external
    orchestrator draining a budget) uses. All three require
    ``chunk_steps``.
    """
    K, M = rtt.shape
    T = cfg.num_steps
    if mesh is not None and _mesh_axis_sizes(mesh).get("players", 1) > 1:
        if chunk_steps is not None:
            raise ValueError(
                "player sharding and chunk_steps do not compose yet: "
                "the donated carry holds shard-local maintenance groups "
                "that cannot round-trip the shard_map boundary")
        return run_sim_players(
            strategy_name, rtt, cfg, key, n_clients=n_clients,
            active=active, drivers=drivers, warmup_steps=warmup_steps,
            mesh=mesh, **strategy_kw)
    drv, donate = _resolve_drivers(cfg, K, M, drivers, n_clients, active)
    if chunk_steps is None or chunk_steps >= T:
        if checkpoint_dir is not None or stop_at_step is not None:
            raise ValueError(
                "checkpoint_dir/resume/stop_at_step need the chunked "
                "loop: pass chunk_steps < num_steps")
        run = build_sim_fn(strategy_name, cfg, K, M, trace=False,
                           warmup_steps=warmup_steps, **strategy_kw)
        with _quiet_donation():
            return jax.jit(run, donate_argnums=donate)(rtt, drv, key)

    init_fn, chunk_fn = build_sim_chunks(
        strategy_name, cfg, K, M, warmup_steps=warmup_steps, **strategy_kw)
    carry, keys = jax.jit(init_fn)(rtt, drv.active[0], key)

    ckpt = None
    start = 0
    parts: list = []          # on-device chunk outputs not yet drained
    done: StepSeries | None = None    # host-side series drained so far
    if checkpoint_dir is not None:
        from repro.checkpoint import Checkpointer
        from repro.obs import provenance as obs_provenance
        ckpt = Checkpointer(checkpoint_dir)
        ckpt_meta = {"config_hash": obs_provenance.config_hash(cfg),
                     "horizon_steps": int(T)}
        if resume and ckpt.latest_step() is not None:
            # the carry from init_fn is only a structure template here:
            # leaf shapes/dtypes come from the npz, so the restored
            # series keeps its true (start,) length
            template = {"carry": carry,
                        "series": StepSeries(*(np.zeros(0, np.float32)
                                               for _ in StepSeries._fields))}
            restored, start = ckpt.restore(template)
            carry = restored["carry"]
            done = jax.device_get(restored["series"])

    def drain() -> StepSeries | None:
        """Fold pending device chunks into the host-side series."""
        nonlocal parts, done
        if parts:
            host = jax.device_get(parts)
            prev = [done] if done is not None else []
            done = StepSeries(*(np.concatenate(
                [np.asarray(getattr(p, f)) for p in prev + host])
                for f in StepSeries._fields))
            parts = []
        return done

    # the carry aliases 1:1 to the chunk's output carry, so donation
    # reuses the state/accumulator buffers in place every chunk
    run_chunk = jax.jit(chunk_fn, donate_argnums=(1,))
    chunks_done = 0
    for lo in range(start, T, chunk_steps):
        if stop_at_step is not None and lo >= stop_at_step:
            break
        hi = min(lo + chunk_steps, T)
        carry, ys = run_chunk(
            rtt, carry, jnp.arange(lo, hi), qs.slice_drivers(drv, lo, hi),
            keys[lo:hi])
        parts.append(ys)    # on-device O(chunk) scalars; the loop only
        # depends on the donated carry, so dispatch runs ahead and the
        # single device_get below drains everything at once
        chunks_done += 1
        if ckpt is not None and hi < T and chunks_done % checkpoint_every == 0:
            # save() snapshots to numpy before returning, so the async
            # write never races the next chunk's donation; the manifest
            # meta identifies the run (restore ignores it)
            ckpt.save(hi, {"carry": carry, "series": drain()},
                      blocking=False, meta=ckpt_meta)
    series = drain()
    if ckpt is not None:
        ckpt.wait()
    ctl = carry[7]
    # the recorder ring rides the chunked carry (and therefore the
    # checkpoint template above) like any other state — chunked,
    # checkpointed and resumed runs end with the bit-identical ring
    return StreamOutputs(acc=carry[3], series=series,
                         ctrl=ctl.counters if ctl is not None else None,
                         rec=carry[8])
