"""Computing-Continuum emulation substrate (paper §VII testbed in JAX)."""
from repro.continuum.metrics import (
    client_qos_satisfaction,
    cumulative_regret,
    jain_fairness,
    p90_proc_latency,
    per_client_success,
    per_lb_request_distribution,
    per_lb_rolling_qos,
    request_rate_per_instance,
    rolling_qos,
    variation_budget_emp,
)
from repro.continuum.simulator import (
    SimConfig,
    SimOutputs,
    build_sim_fn,
    run_sim,
    run_sim_batch,
)
from repro.continuum.topology import (
    Topology,
    european_rtt_matrix,
    k_center_placement,
    make_topology,
)

__all__ = [
    "SimConfig", "SimOutputs", "run_sim", "run_sim_batch", "build_sim_fn",
    "Topology", "european_rtt_matrix", "k_center_placement", "make_topology",
    "client_qos_satisfaction", "jain_fairness", "rolling_qos",
    "per_lb_rolling_qos", "per_client_success", "request_rate_per_instance",
    "p90_proc_latency", "per_lb_request_distribution", "cumulative_regret",
    "variation_budget_emp",
]
