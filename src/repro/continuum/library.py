"""The named scenario library: every dynamic regime the paper (and its
related work) argues about, as one `Scenario` each.

Event times are *fractions of the horizon*, so the same library runs
at the full 180 s evaluation horizon and at the seconds-level smoke
horizon; instance/LB subsets are fractions of M/K, so the same entries
drive the 30×10 paper testbed and the fleet-scale bandit_scale cells.

The library is the sharded-grid axis: `compile_scenario` each entry,
`stack_drivers` the results, and scenario diversity spreads over
devices exactly like seeds do (`benchmarks/scenario_suite.py`,
`build_sim_grid_fn`).

Capacity framing (defaults, 30×10, s_m=5.5 ms): demand 1200 req/s vs
~1818 req/s capacity. `surge` stays under capacity (adaptation without
overload); `flash_crowd` and `cascade_failure` push through it
(recovery after genuine QoS loss); the rest stress the estimate
(drift, partition, slowdown) rather than raw capacity.
"""
from __future__ import annotations

from repro.continuum.scenarios import (Autoscale, ClientChurn, DiurnalWave,
                                       InstanceKill, InstanceRestore,
                                       LinkDegrade, LoadSurge, Partition,
                                       RttDrift, Scenario, ServiceSlowdown,
                                       TenantScenario)


def _frac(n: int, frac: float, lo: int = 1) -> tuple[int, ...]:
    """First max(lo, frac*n) indices — deterministic subset helper."""
    return tuple(range(max(lo, int(round(frac * n)))))


def get_library(horizon: float, n_nodes: int = 30, n_instances: int = 10,
                base_clients: int = 4) -> dict[str, Scenario]:
    """~13 named scenarios sized to ``horizon`` seconds and a K×M fleet."""
    hz, K, M = horizon, n_nodes, n_instances
    kw = dict(n_nodes=K, n_instances=M, base_clients=base_clients)
    third_m = _frac(M, 1 / 3)
    third_k = _frac(K, 1 / 3)

    lib = [
        Scenario("baseline", (), description="stationary reference", **kw),
        Scenario(
            "surge",
            (LoadSurge(start=0.5 * hz, extra=2, fraction=0.5),),
            description="step surge on half the LBs (Fig. 10 regime)", **kw),
        Scenario(
            "flash_crowd",
            (LoadSurge(start=0.4 * hz, stop=0.6 * hz, extra=4,
                       fraction=0.8, ramp=0.05 * hz),),
            description="ramped over-capacity crowd, then gone", **kw),
        Scenario(
            "cascade_failure",
            (InstanceKill(start=0.35 * hz, instances=third_m[:max(1, len(third_m) // 2)]),
             InstanceKill(start=0.5 * hz, instances=third_m[max(1, len(third_m) // 2):] or third_m[:1]),
             InstanceRestore(start=0.75 * hz, instances=third_m)),
            description="two failure waves, one mass restore", **kw),
        Scenario(
            "rolling_restart",
            tuple(InstanceKill(start=(0.3 + 0.5 * i / M) * hz,
                               stop=(0.3 + 0.5 * i / M) * hz + 0.04 * hz,
                               instances=(i,))
                  for i in range(M)),
            description="every instance drains briefly, staggered", **kw),
        Scenario(
            "diurnal",
            (DiurnalWave(start=0.0, period=0.5 * hz, amplitude=2.0),),
            description="fleet-wide sinusoidal load", **kw),
        Scenario(
            "rtt_drift",
            (RttDrift(start=0.3 * hz, stop=0.7 * hz, factor=2.0),),
            description="mobility-style global RTT ramp, held", **kw),
        Scenario(
            "partition_heal",
            (Partition(start=0.4 * hz, stop=0.7 * hz,
                       lbs=third_k, instances=third_m),),
            description="a third of the LBs lose a third of the fleet,"
                        " then heal", **kw),
        Scenario(
            "hetero_slowdown",
            (ServiceSlowdown(start=0.0, instances=tuple(range(0, M, 2)),
                             factor=1.4),
             ServiceSlowdown(start=0.45 * hz, stop=0.75 * hz,
                             instances=(M - 1,), factor=3.0)),
            description="heterogeneous hardware + a mid-run throttle", **kw),
        Scenario(
            "churn",
            (ClientChurn(start=0.0, rate=0.5, max_delta=2),),
            description="per-LB clamped random-walk client churn", **kw),
        Scenario(
            "autoscale_up",
            (InstanceKill(start=0.0, instances=third_m),
             Autoscale(start=0.4 * hz, stop=0.7 * hz, instances=third_m,
                       direction="up")),
            description="start short-handed, autoscaler staggers in"
                        " replicas", **kw),
        Scenario(
            "retry_storm",
            (ServiceSlowdown(start=0.35 * hz, stop=0.65 * hz,
                             instances=_frac(M, 1 / 10), factor=6.0),),
            description="gray failure: one instance throttles 6x — slow"
                        " enough that its requests trip the attempt"
                        " timeout, alive enough that liveness masking"
                        " never fires. The healthy fleet has headroom,"
                        " so the resilience layer decides the outcome:"
                        " retries rescue the sick instance's requests"
                        " while breakers eject it faster than the KDE"
                        " window learns", **kw),
        Scenario(
            "metastable_overload",
            (LoadSurge(start=0.4 * hz, stop=0.5 * hz, extra=4,
                       fraction=0.8, ramp=0.02 * hz),),
            description="brief over-capacity trigger, then load returns"
                        " to normal: the fleet recovers iff retry"
                        " amplification stays below spare capacity —"
                        " the metastable-overload probe", **kw),
        Scenario(
            "sustained_overload",
            (LoadSurge(start=0.45 * hz, extra=4, fraction=0.8,
                       ramp=0.02 * hz),),
            description="over-capacity surge that never ends: no"
                        " scheduling policy can restore QoS — only"
                        " added capacity (closed-loop autoscaling of a"
                        " standby pool) or admission shedding can, the"
                        " control-plane discriminator", **kw),
        Scenario(
            "everything",
            (ClientChurn(start=0.0, rate=0.3, max_delta=1),
             LoadSurge(start=0.3 * hz, extra=2, fraction=0.5),
             InstanceKill(start=0.45 * hz, stop=0.75 * hz,
                          instances=third_m[:max(1, len(third_m) // 2)]),
             RttDrift(start=0.5 * hz, stop=0.8 * hz, factor=1.5),
             ServiceSlowdown(start=0.6 * hz, stop=0.85 * hz,
                             instances=(M - 1,), factor=2.0)),
            description="surge + failure + drift + throttle + churn,"
                        " overlapping", **kw),
    ]
    return {s.name: s for s in lib}


def get_tenant_library(horizon: float, n_nodes: int = 30,
                       n_instances: int = 10, n_tenants: int = 4,
                       base_clients: int = 1) -> dict[str, TenantScenario]:
    """Named multi-tenant scenarios: S per-tenant event schedules over
    ONE shared fleet (``compile_tenant_scenario`` merges them into
    tenant-axis drivers).

    Tenant 0 is by convention the latency-sensitive foreground service
    (give it the tightest tau in the run's ``TenancyConfig``); the last
    tenant is the batch/background hog. ``base_clients`` is PER TENANT:
    the default 4 tenants x 30 LBs x 1 client x 10 req/s = 1200 req/s
    keeps aggregate demand identical to the single-service library's
    baseline (~66%% of fleet capacity at s_m = 5.5 ms).
    """
    hz, K, M, S = horizon, n_nodes, n_instances, n_tenants
    if S < 2:
        raise ValueError(f"tenant library needs >= 2 tenants, got {S}")
    kw = dict(n_nodes=K, n_instances=M, base_clients=base_clients)

    def quiet(s: int) -> Scenario:
        return Scenario(f"tenant{s}_quiet", (), description="steady", **kw)

    lib = [
        TenantScenario(
            "mt_baseline",
            tuple(quiet(s) for s in range(S)),
            description="S steady tenants sharing the fleet — do the"
                        " independent bandit fleets co-exist without"
                        " starving anyone?"),
        TenantScenario(
            "mt_tenant_surge",
            (Scenario("tenant0_surge",
                      (LoadSurge(start=0.45 * hz, stop=0.75 * hz, extra=3,
                                 fraction=0.6, ramp=0.03 * hz),),
                      description="foreground surge", **kw),)
            + tuple(quiet(s) for s in range(1, S)),
            description="one tenant surges 4x mid-run while the others"
                        " stay steady: does the surge degrade the quiet"
                        " tenants' QoS (fairness under surge)?"),
        TenantScenario(
            "mt_noisy_neighbor",
            tuple(quiet(s) for s in range(S - 1))
            + (Scenario(
                f"tenant{S - 1}_hog",
                (LoadSurge(start=0.35 * hz, extra=4, fraction=0.8,
                           ramp=0.02 * hz),
                 ServiceSlowdown(start=0.35 * hz, stop=0.8 * hz,
                                 instances=_frac(M, 1 / 5), factor=2.5)),
                description="background hog + the slowdown it causes",
                **kw),),
            description="the last tenant floods the fleet AND throttles"
                        " a fifth of the instances (cache/IO pressure):"
                        " can the foreground tenants route around the"
                        " noisy neighbor?"),
        TenantScenario(
            "mt_priority_inversion",
            (quiet(0),)
            + tuple(Scenario(
                f"tenant{s}_batch",
                (LoadSurge(start=0.4 * hz, extra=3, fraction=1.0,
                           ramp=0.05 * hz),),
                description="batch wave", **kw)
                for s in range(1, S)),
            description="every background tenant surges past capacity"
                        " at once while the tight-deadline tenant 0"
                        " stays quiet: the priority-inversion probe —"
                        " does tenant 0's QoS survive load it did not"
                        " create?"),
    ]
    return {t.name: t for t in lib}
