"""Metric extraction from simulator trajectories (paper §VII figures)."""
from __future__ import annotations

import numpy as np

from repro.continuum.simulator import SimOutputs


def per_client_success(outs: SimOutputs, warmup_steps: int = 0) -> np.ndarray:
    """(K, C) fraction of each client's requests meeting QoS (Fig. 5)."""
    r = np.asarray(outs.rewards)[warmup_steps:]
    m = np.asarray(outs.issued)[warmup_steps:]
    n = np.maximum(m.sum(0), 1)
    return (r * m).sum(0) / n, m.sum(0) > 0


def client_qos_satisfaction(outs: SimOutputs, rho: float,
                            warmup_steps: int = 0) -> float:
    """% of clients whose success ratio >= rho (Fig. 3)."""
    ratio, present = per_client_success(outs, warmup_steps)
    ok = (ratio >= rho) & present
    return 100.0 * ok.sum() / max(present.sum(), 1)


def jain_fairness(outs: SimOutputs, reachable: np.ndarray | None = None,
                  warmup_steps: int = 0) -> float:
    """Jain's index over per-instance request totals (Fig. 4).

    ``reachable`` optionally restricts to instances inside anyone's QoS
    reach (the paper's i2 sits outside every node's reach and pins at
    its host's constant rate).
    """
    x = np.asarray(outs.arrivals)[warmup_steps:].sum(0)
    if reachable is not None:
        x = x[reachable]
    s = x.sum()
    if s <= 0:
        return 0.0
    return float(s * s / (len(x) * (x * x).sum()))


def rolling_qos(outs: SimOutputs, window_steps: int) -> np.ndarray:
    """(T,) rolling overall QoS success rate (Fig. 6)."""
    r = (np.asarray(outs.rewards) * np.asarray(outs.issued)).sum((1, 2))
    n = np.asarray(outs.issued).sum((1, 2)).astype(np.float64)
    T = len(r)
    out = np.zeros(T)
    cs_r = np.concatenate([[0.0], np.cumsum(r)])
    cs_n = np.concatenate([[0.0], np.cumsum(n)])
    for t in range(T):
        lo = max(0, t - window_steps + 1)
        num = cs_r[t + 1] - cs_r[lo]
        den = cs_n[t + 1] - cs_n[lo]
        out[t] = num / max(den, 1.0)
    return out


def per_lb_rolling_qos(outs: SimOutputs, window_steps: int) -> np.ndarray:
    """(T, K) rolling per-LB QoS success rate."""
    r = (np.asarray(outs.rewards) * np.asarray(outs.issued)).sum(2)   # (T,K)
    n = np.asarray(outs.issued).sum(2).astype(np.float64)
    T, K = r.shape
    out = np.zeros((T, K))
    cs_r = np.concatenate([np.zeros((1, K)), np.cumsum(r, 0)])
    cs_n = np.concatenate([np.zeros((1, K)), np.cumsum(n, 0)])
    for t in range(T):
        lo = max(0, t - window_steps + 1)
        num = cs_r[t + 1] - cs_r[lo]
        den = np.maximum(cs_n[t + 1] - cs_n[lo], 1.0)
        out[t] = num / den
    return out


def request_rate_per_instance(outs: SimOutputs, dt: float,
                              warmup_steps: int = 0) -> np.ndarray:
    """(M,) average req/s per instance (Fig. 7)."""
    a = np.asarray(outs.arrivals)[warmup_steps:]
    return a.sum(0) / (a.shape[0] * dt)


def p90_proc_latency(outs: SimOutputs, warmup_steps: int = 0) -> np.ndarray:
    """(M,) p90 of processing latency per instance (Fig. 8)."""
    proc = np.asarray(outs.proc_lat)[warmup_steps:]
    m = np.asarray(outs.issued)[warmup_steps:]
    ch = np.asarray(outs.choices)[warmup_steps:]
    M = outs.arrivals.shape[1]
    out = np.zeros(M)
    for i in range(M):
        sel = m & (ch == i)
        vals = proc[sel]
        out[i] = np.percentile(vals, 90) if vals.size else 0.0
    return out


def per_lb_request_distribution(outs: SimOutputs, lb: int,
                                warmup_steps: int = 0) -> np.ndarray:
    """(M,) share of LB `lb`'s requests per instance (Fig. 9)."""
    m = np.asarray(outs.issued)[warmup_steps:, lb]
    ch = np.asarray(outs.choices)[warmup_steps:, lb]
    M = outs.arrivals.shape[1]
    counts = np.bincount(ch[m], minlength=M).astype(np.float64)
    return counts / max(counts.sum(), 1.0)


def cumulative_regret(outs: SimOutputs) -> np.ndarray:
    """(T,) system regret sum_k R_k(t) (Eq. 9)."""
    return np.cumsum(np.asarray(outs.regret).sum(1))


def variation_budget_emp(outs: SimOutputs) -> np.ndarray:
    """(K,) empirical V_k(T) from the true-mu trajectory (Def. 1)."""
    mu = np.asarray(outs.true_mu)
    return np.abs(np.diff(mu, axis=0)).max(-1).sum(0)
