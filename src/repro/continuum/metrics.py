"""Metric extraction for the paper's §VII figures.

Two consumption modes:

* **Trace mode** (`SimOutputs`, per-step trajectories with a leading T
  axis): the original post-hoc functions below slice/reduce the full
  trajectory. Memory is O(T·K·M) — fine for the testbed scale, the cap
  at fleet scale.
* **Streaming mode** (`MetricAccumulator` + `StepSeries`): the
  simulator's ``lax.scan`` carries the accumulator and updates it
  on-device every step, so nothing with a T axis wider than a scalar
  ever materializes. Every figure's statistic is recoverable from the
  O(K·M) accumulator plus the O(T)-scalars series; the `_stream`
  functions mirror the trace-mode functions one-for-one
  (tests/test_streaming.py locks the parity).

The only estimate that is *approximate* in streaming mode is the
per-instance latency quantile (Fig. 8): exact percentiles need all
samples, so the accumulator keeps a fixed geometric histogram sketch
per instance and the readout interpolates within a bin (~half a bin
width of relative error, inside the figure's plotting resolution).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Streaming accumulator (carried through the simulator scan).
# ---------------------------------------------------------------------------

# Geometric bins for the processing-latency sketch: 1e-4 s .. 10 s covers
# everything the queue model can produce (idle service ~5.5 ms, deep
# overload ~seconds); 128 bins => ~9.5% spacing, so a within-bin readout
# is well inside Fig. 8's resolution.
PROC_HIST_BINS = 128
_PROC_EDGES = np.geomspace(1e-4, 10.0, PROC_HIST_BINS - 1).astype(np.float32)


class MetricAccumulator(NamedTuple):
    """O(K·M) on-device sufficient statistics for Figs 3-9 + regret.

    "Post-warmup" fields only accumulate once ``t_idx >= warmup_steps``
    (the warmup is baked in at trace time, matching how the figure
    harness always discards the same warmup prefix). Regret and the
    variation budget accumulate over the full horizon, like their
    trace-mode counterparts.

    Under player sharding (``run_sim_players``) the per-player fields
    (leading K axis) live sharded on the ``players`` mesh axis and
    concatenate to full width when read; the fleet-level fields
    (``arrivals_m``, ``proc_hist``, ``ev_succ``/``ev_n``) accumulate
    shard-local partials that one end-of-scan psum reduces — all
    integer-valued f32 sums, so sharding never changes their values.
    ``steps_measured`` is a pure function of the step index and stays
    replicated.

    ``ev_succ``/``ev_n`` are the *event-relative* recovery windows:
    for each scenario event mark e (a step index from
    ``Drivers.marks``), slot 0 holds the fleet QoS sums over the
    pre-event baseline window [e - pre, e) and slots 1..B the
    consecutive post-event buckets [e, e+w), [e+w, e+2w), … — enough
    to read dip depth and time-to-recover for *any* scenario without a
    trajectory (Fig 9/10-style adaptation metrics; see
    ``event_recovery``). They accumulate regardless of warmup (events
    carry their own local baseline) and stay zero when no marks are
    set (every legacy driver path).
    """
    succ_kc: jax.Array        # (K, C) post-warmup QoS successes per client slot
    n_kc: jax.Array           # (K, C) post-warmup issued requests per client slot
    arrivals_m: jax.Array     # (M,)  post-warmup arrivals per instance
    choice_counts: jax.Array  # (K, M) post-warmup issued requests per (LB, instance)
    proc_hist: jax.Array      # (M, B) post-warmup processing-latency sketch
    regret_k: jax.Array       # (K,)  full-horizon oracle regret partial sum
    vb_k: jax.Array           # (K,)  empirical variation budget partial sum
    prev_mu: jax.Array        # (K, M) previous step's true mu (variation carry)
    steps_measured: jax.Array  # ()   f32 count of post-warmup steps
    ev_succ: jax.Array        # (E, 1+B) QoS successes per event window
    ev_n: jax.Array           # (E, 1+B) issued requests per event window
    # --- request-lifecycle resilience counters (per-player: shard
    # without any reduction). With resilience off, att_k == issued and
    # timeout_k/drop_k/open_km stay zero. ---
    att_k: jax.Array          # (K,)  post-warmup attempts (incl. retries)
    timeout_k: jax.Array      # (K,)  post-warmup timed-out attempts
    drop_k: jax.Array         # (K,)  post-warmup dropped requests
    open_km: jax.Array        # (K, M) post-warmup breaker-open step counts


class StepSeries(NamedTuple):
    """Per-step scalar streams (leading axis T): the only O(T) output of
    a streaming run. Enough for every time-resolved figure (rolling QoS
    Fig. 6/10/11, cumulative regret §V-E)."""
    succ: jax.Array     # (T,) fleet-wide QoS successes this step
    issued: jax.Array   # (T,) fleet-wide issued requests this step
    regret: jax.Array   # (T,) system regret this step
    attempts: jax.Array  # (T,) fleet-wide attempts (offered load incl. retries)


class StreamOutputs(NamedTuple):
    """``ctrl`` carries the control plane's ``ControlCounters`` when a
    closed-loop config is enabled (``repro.continuum.control``) and is
    ``None`` — an empty pytree subtree — on every open-loop run, so
    existing consumers and tree maps are untouched. ``rec`` likewise
    carries the flight recorder's ``RecorderState`` ring
    (``repro.obs.recorder``) when ``SimConfig.recorder`` is enabled,
    else ``None``; decode it with ``repro.obs.recorder_events``."""
    acc: MetricAccumulator
    series: StepSeries
    ctrl: object = None
    rec: object = None


def init_accumulator(K: int, M: int, C: int,
                     bins: int = PROC_HIST_BINS,
                     *,
                     n_marks: int,
                     ev_buckets: int) -> MetricAccumulator:
    """``n_marks``/``ev_buckets`` size the event-recovery windows and
    must match the driver compiler (``scenarios.MAX_MARKS``) and the
    run's ``SimConfig.ev_buckets`` — ``build_sim_parts`` passes both,
    so there are deliberately no defaults to drift."""
    return MetricAccumulator(
        succ_kc=jnp.zeros((K, C), jnp.float32),
        n_kc=jnp.zeros((K, C), jnp.float32),
        arrivals_m=jnp.zeros((M,), jnp.float32),
        choice_counts=jnp.zeros((K, M), jnp.float32),
        proc_hist=jnp.zeros((M, bins), jnp.float32),
        regret_k=jnp.zeros((K,), jnp.float32),
        vb_k=jnp.zeros((K,), jnp.float32),
        prev_mu=jnp.zeros((K, M), jnp.float32),
        steps_measured=jnp.zeros((), jnp.float32),
        ev_succ=jnp.zeros((n_marks, 1 + ev_buckets), jnp.float32),
        ev_n=jnp.zeros((n_marks, 1 + ev_buckets), jnp.float32),
        att_k=jnp.zeros((K,), jnp.float32),
        timeout_k=jnp.zeros((K,), jnp.float32),
        drop_k=jnp.zeros((K,), jnp.float32),
        open_km=jnp.zeros((K, M), jnp.float32),
    )


def update_accumulator(
    acc: MetricAccumulator,
    *,
    rewards: jax.Array,      # (K, C) 1/0 QoS outcome (unmasked)
    issued: jax.Array,       # (K, C) bool request-issued mask
    choices: jax.Array,      # (K, C) selected instance
    procs: jax.Array,        # (K, C) processing-latency component
    arrivals: jax.Array,     # (M,)  arrivals this step
    regret: jax.Array,       # (K,)  per-player oracle regret this step
    mu: jax.Array,           # (K, M) true success probabilities this step
    t_idx: jax.Array,        # scalar i32 global step index
    warmup_steps: int,
    marks: jax.Array | None = None,   # (E,) event-onset steps, -1 padded
    ev_pre_steps: int = 1,
    ev_bucket_steps: int = 1,
    attempts: jax.Array | None = None,   # (K, C) attempts per request slot
    dropped: jax.Array | None = None,    # (K, C) bool: deadline exhausted
    brk_open: jax.Array | None = None,   # (K, M) bool: breaker open now
    served: jax.Array | None = None,     # (K, C) bool: reached an instance
) -> MetricAccumulator:
    """One on-device accumulator update; everything here is O(K·M).

    ``attempts``/``dropped`` default to the non-resilient identities
    (one attempt per issued request, nothing dropped); per-slot
    timeouts are the derived quantity ``attempts - completed`` — every
    attempt either times out or completes, and at most one attempt of
    a request completes.

    ``served`` defaults to ``issued``. The control plane's admission
    shedding passes the admitted subset instead: shed slots are issued
    from the client's view (QoS misses in ``n_kc`` and the event
    windows) but never reached an instance, so they must stay out of
    the routing histogram and the latency sketch.
    """
    K, C = rewards.shape
    M, B = acc.proc_hist.shape
    issf = issued.astype(jnp.float32)
    servf = issf if served is None else served.astype(jnp.float32)
    meas = (t_idx >= warmup_steps).astype(jnp.float32)

    # per-instance latency sketch + per-(LB, instance) routing histogram:
    # one flat segment-sum each, indices composed as row * width + col
    pbin = jnp.clip(jnp.searchsorted(jnp.asarray(_PROC_EDGES), procs),
                    0, B - 1).astype(jnp.int32)
    hist_upd = jax.ops.segment_sum(
        servf.ravel(), (choices * B + pbin).ravel(),
        num_segments=M * B).reshape(M, B)
    kidx = jnp.arange(K, dtype=jnp.int32)[:, None]
    choice_upd = jax.ops.segment_sum(
        servf.ravel(), (kidx * M + choices).ravel(),
        num_segments=K * M).reshape(K, M)

    # event-relative recovery windows: route this step's fleet-wide
    # (succ, issued) scalars into each mark's pre slot or post bucket;
    # steps outside every window (or sentinel marks) scatter out of
    # bounds and are dropped. O(E) per step.
    ev_succ, ev_n = acc.ev_succ, acc.ev_n
    if marks is not None:
        E, B1 = ev_succ.shape
        rel = t_idx.astype(jnp.int32) - marks                # (E,)
        pre = (rel >= -ev_pre_steps) & (rel < 0)
        pb = jnp.where(rel >= 0, rel // ev_bucket_steps, B1)
        slot = jnp.where(pre, 0, 1 + pb)                     # (E,)
        valid = (marks >= 0) & (pre | ((rel >= 0) & (pb < B1 - 1)))
        slot = jnp.where(valid, slot, B1)                    # OOB -> dropped
        eidx = jnp.arange(E)
        ev_succ = ev_succ.at[eidx, slot].add(
            (rewards * issf).sum(), mode="drop")
        ev_n = ev_n.at[eidx, slot].add(issf.sum(), mode="drop")

    att = issf if attempts is None else attempts.astype(jnp.float32)
    dropf = (jnp.zeros_like(issf) if dropped is None
             else dropped.astype(jnp.float32))
    completed = issf * (1.0 - dropf)
    open_upd = (acc.open_km if brk_open is None
                else acc.open_km + meas * brk_open.astype(jnp.float32))

    vb_step = jnp.where(t_idx > 0, jnp.abs(mu - acc.prev_mu).max(-1), 0.0)
    return MetricAccumulator(
        succ_kc=acc.succ_kc + meas * rewards * issf,
        n_kc=acc.n_kc + meas * issf,
        arrivals_m=acc.arrivals_m + meas * arrivals,
        choice_counts=acc.choice_counts + meas * choice_upd,
        proc_hist=acc.proc_hist + meas * hist_upd,
        regret_k=acc.regret_k + regret,
        vb_k=acc.vb_k + vb_step,
        prev_mu=mu,
        steps_measured=acc.steps_measured + meas,
        ev_succ=ev_succ,
        ev_n=ev_n,
        att_k=acc.att_k + meas * att.sum(-1),
        timeout_k=acc.timeout_k + meas * (att - completed).sum(-1),
        drop_k=acc.drop_k + meas * dropf.sum(-1),
        open_km=open_upd,
    )


# ---------------------------------------------------------------------------
# Trace-mode extraction (full SimOutputs trajectories).
# ---------------------------------------------------------------------------

def per_client_success(outs, warmup_steps: int = 0) -> np.ndarray:
    """(K, C) fraction of each client's requests meeting QoS (Fig. 5)."""
    r = np.asarray(outs.rewards)[warmup_steps:]
    m = np.asarray(outs.issued)[warmup_steps:]
    n = np.maximum(m.sum(0), 1)
    return (r * m).sum(0) / n, m.sum(0) > 0


def client_qos_satisfaction(outs, rho: float,
                            warmup_steps: int = 0) -> float:
    """% of clients whose success ratio >= rho (Fig. 3)."""
    ratio, present = per_client_success(outs, warmup_steps)
    return _qos_satisfaction(ratio, present, rho)


def _qos_satisfaction(ratio, present, rho) -> float:
    ok = (ratio >= rho) & present
    return 100.0 * ok.sum() / max(present.sum(), 1)


def jain_fairness(outs, reachable: np.ndarray | None = None,
                  warmup_steps: int = 0) -> float:
    """Jain's index over per-instance request totals (Fig. 4).

    ``reachable`` optionally restricts to instances inside anyone's QoS
    reach (the paper's i2 sits outside every node's reach and pins at
    its host's constant rate).
    """
    x = np.asarray(outs.arrivals)[warmup_steps:].sum(0)
    return _jain(x, reachable)


def _jain(x: np.ndarray, reachable: np.ndarray | None) -> float:
    if reachable is not None:
        x = x[reachable]
    s = x.sum()
    if s <= 0:
        return 0.0
    return float(s * s / (len(x) * (x * x).sum()))


def _rolling_ratio(r: np.ndarray, n: np.ndarray,
                   window_steps: int) -> np.ndarray:
    """(T,) windowed sum(r)/sum(n) with a growing left edge."""
    T = len(r)
    out = np.zeros(T)
    cs_r = np.concatenate([[0.0], np.cumsum(r, dtype=np.float64)])
    cs_n = np.concatenate([[0.0], np.cumsum(n, dtype=np.float64)])
    for t in range(T):
        lo = max(0, t - window_steps + 1)
        num = cs_r[t + 1] - cs_r[lo]
        den = cs_n[t + 1] - cs_n[lo]
        out[t] = num / max(den, 1.0)
    return out


def rolling_qos(outs, window_steps: int) -> np.ndarray:
    """(T,) rolling overall QoS success rate (Fig. 6)."""
    r = (np.asarray(outs.rewards) * np.asarray(outs.issued)).sum((1, 2))
    n = np.asarray(outs.issued).sum((1, 2)).astype(np.float64)
    return _rolling_ratio(r, n, window_steps)


def per_lb_rolling_qos(outs, window_steps: int) -> np.ndarray:
    """(T, K) rolling per-LB QoS success rate."""
    r = (np.asarray(outs.rewards) * np.asarray(outs.issued)).sum(2)   # (T,K)
    n = np.asarray(outs.issued).sum(2).astype(np.float64)
    T, K = r.shape
    out = np.zeros((T, K))
    cs_r = np.concatenate([np.zeros((1, K)), np.cumsum(r, 0)])
    cs_n = np.concatenate([np.zeros((1, K)), np.cumsum(n, 0)])
    for t in range(T):
        lo = max(0, t - window_steps + 1)
        num = cs_r[t + 1] - cs_r[lo]
        den = np.maximum(cs_n[t + 1] - cs_n[lo], 1.0)
        out[t] = num / den
    return out


def request_rate_per_instance(outs, dt: float,
                              warmup_steps: int = 0) -> np.ndarray:
    """(M,) average req/s per instance (Fig. 7)."""
    a = np.asarray(outs.arrivals)[warmup_steps:]
    return a.sum(0) / (a.shape[0] * dt)


def p90_proc_latency(outs, warmup_steps: int = 0) -> np.ndarray:
    """(M,) p90 of processing latency per instance (Fig. 8)."""
    proc = np.asarray(outs.proc_lat)[warmup_steps:]
    m = np.asarray(outs.issued)[warmup_steps:]
    ch = np.asarray(outs.choices)[warmup_steps:]
    M = outs.arrivals.shape[1]
    out = np.zeros(M)
    for i in range(M):
        sel = m & (ch == i)
        vals = proc[sel]
        out[i] = np.percentile(vals, 90) if vals.size else 0.0
    return out


def per_lb_request_distribution(outs, lb: int,
                                warmup_steps: int = 0) -> np.ndarray:
    """(M,) share of LB `lb`'s requests per instance (Fig. 9)."""
    m = np.asarray(outs.issued)[warmup_steps:, lb]
    ch = np.asarray(outs.choices)[warmup_steps:, lb]
    M = outs.arrivals.shape[1]
    counts = np.bincount(ch[m], minlength=M).astype(np.float64)
    return counts / max(counts.sum(), 1.0)


def cumulative_regret(outs) -> np.ndarray:
    """(T,) system regret sum_k R_k(t) (Eq. 9)."""
    return np.cumsum(np.asarray(outs.regret).sum(1))


def variation_budget_emp(outs) -> np.ndarray:
    """(K,) empirical V_k(T) from the true-mu trajectory (Def. 1)."""
    mu = np.asarray(outs.true_mu)
    return np.abs(np.diff(mu, axis=0)).max(-1).sum(0)


def resilience_stats(outs, warmup_steps: int = 0) -> dict:
    """Request-lifecycle counters from a trace — the post-hoc
    counterpart of ``resilience_stats_stream`` (parity-locked in
    tests/test_streaming.py). Timeouts are derived per slot as
    ``attempts - completed``."""
    att = np.asarray(outs.attempts, np.float64)[warmup_steps:]
    drop = np.asarray(outs.dropped)[warmup_steps:]
    m = np.asarray(outs.issued)[warmup_steps:]
    return _resilience_dict(
        requests=m.sum(), attempts=att.sum(),
        timeouts=(att - (m & ~drop)).sum(), drops=(drop & m).sum())


def _resilience_dict(*, requests, attempts, timeouts, drops) -> dict:
    requests, attempts = float(requests), float(attempts)
    timeouts, drops = float(timeouts), float(drops)
    return {
        "requests": requests,
        "attempts": attempts,
        "retries": attempts - requests,
        "timeouts": timeouts,
        "drops": drops,
        "retry_rate": (attempts - requests) / max(requests, 1.0),
        "timeout_rate": timeouts / max(attempts, 1.0),
        "drop_rate": drops / max(requests, 1.0),
    }


# ---------------------------------------------------------------------------
# Streaming extraction (MetricAccumulator / StepSeries).
# ---------------------------------------------------------------------------

def per_client_success_stream(acc: MetricAccumulator):
    """(K, C) per-client success ratio + presence mask (Fig. 5)."""
    s = np.asarray(acc.succ_kc)
    n = np.asarray(acc.n_kc)
    return s / np.maximum(n, 1), n > 0


def client_qos_satisfaction_stream(acc: MetricAccumulator,
                                   rho: float) -> float:
    ratio, present = per_client_success_stream(acc)
    return _qos_satisfaction(ratio, present, rho)


def jain_fairness_stream(acc: MetricAccumulator,
                         reachable: np.ndarray | None = None) -> float:
    return _jain(np.asarray(acc.arrivals_m), reachable)


def request_rate_per_instance_stream(acc: MetricAccumulator,
                                     dt: float) -> np.ndarray:
    steps = max(float(acc.steps_measured), 1.0)
    return np.asarray(acc.arrivals_m) / (steps * dt)


def proc_latency_quantile_stream(acc: MetricAccumulator,
                                 q: float = 0.9) -> np.ndarray:
    """(M,) q-quantile of processing latency from the histogram sketch
    (Fig. 8). Bin-resolution approximation of ``p90_proc_latency``."""
    hist = np.asarray(acc.proc_hist, np.float64)      # (M, B)
    M, B = hist.shape
    centers = np.empty(B)
    centers[0] = _PROC_EDGES[0]
    centers[1:-1] = np.sqrt(_PROC_EDGES[:-1] * _PROC_EDGES[1:])
    centers[-1] = _PROC_EDGES[-1]
    n = hist.sum(1)
    rank = q * np.maximum(n - 1.0, 0.0)
    cum = hist.cumsum(1)
    idx = np.argmax(cum > rank[:, None], axis=1)
    return np.where(n > 0, centers[idx], 0.0)


def per_lb_request_distribution_stream(acc: MetricAccumulator,
                                       lb: int) -> np.ndarray:
    counts = np.asarray(acc.choice_counts, np.float64)[lb]
    return counts / max(counts.sum(), 1.0)


def rolling_qos_series(series: StepSeries, window_steps: int) -> np.ndarray:
    """(T,) rolling overall QoS success rate from the per-step streams —
    the exact streaming counterpart of ``rolling_qos`` (Fig. 6)."""
    return _rolling_ratio(np.asarray(series.succ),
                          np.asarray(series.issued).astype(np.float64),
                          window_steps)


def cumulative_regret_series(series: StepSeries) -> np.ndarray:
    """(T,) cumulative system regret from the per-step stream."""
    return np.cumsum(np.asarray(series.regret, np.float64))


def variation_budget_stream(acc: MetricAccumulator) -> np.ndarray:
    """(K,) empirical V_k(T) partial sum (Def. 1)."""
    return np.asarray(acc.vb_k)


def resilience_stats_stream(acc: MetricAccumulator) -> dict:
    """Post-warmup attempt/retry/timeout/drop counters and rates."""
    return _resilience_dict(
        requests=np.asarray(acc.n_kc, np.float64).sum(),
        attempts=np.asarray(acc.att_k, np.float64).sum(),
        timeouts=np.asarray(acc.timeout_k, np.float64).sum(),
        drops=np.asarray(acc.drop_k, np.float64).sum())


def breaker_open_fraction_stream(acc: MetricAccumulator) -> np.ndarray:
    """(K, M) fraction of post-warmup steps each (player, arm) breaker
    spent open — the outlier-ejection occupancy."""
    steps = max(float(acc.steps_measured), 1.0)
    return np.asarray(acc.open_km, np.float64) / steps


def goodput_offered_series(series: StepSeries, dt: float,
                           window_steps: int) -> tuple[np.ndarray, np.ndarray]:
    """(goodput, offered) rolling req/s from the per-step streams.

    Goodput counts requests that met their QoS deadline; offered load
    counts every attempt put on the wire (retries included). Their gap
    is the work the fleet performed without satisfying anyone — the
    retry-amplification signature."""
    succ = np.asarray(series.succ, np.float64)
    att = np.asarray(series.attempts, np.float64)
    T = len(succ)
    cs_s = np.concatenate([[0.0], np.cumsum(succ)])
    cs_a = np.concatenate([[0.0], np.cumsum(att)])
    good = np.zeros(T)
    offered = np.zeros(T)
    for t in range(T):
        lo = max(0, t - window_steps + 1)
        span = (t + 1 - lo) * dt
        good[t] = (cs_s[t + 1] - cs_s[lo]) / span
        offered[t] = (cs_a[t + 1] - cs_a[lo]) / span
    return good, offered


# ---------------------------------------------------------------------------
# Multi-tenant fairness (S services on one fleet).
# ---------------------------------------------------------------------------
# A tenant run (SimConfig.tenancy with S >= 2) returns a TUPLE of S
# MetricAccumulators in StreamOutputs.acc — one independent accumulator
# per service — and (T, S) StepSeries columns. The readouts below take
# that tuple and answer the multi-tenant questions: per-tenant QoS, how
# (un)evenly the shared fleet serves the tenants (Gini / Jain /
# Herfindahl over per-tenant outcomes), and whether the S bandit fleets
# self-partitioned the instances (pairwise routing overlap).

def gini_index(x) -> float:
    """Gini coefficient of a non-negative allocation vector.

    0 = perfectly equal, -> 1 = maximally concentrated. Computed via
    the sorted-rank identity ``2*sum(i*x_(i))/(n*sum(x)) - (n+1)/n``
    (O(S log S)); ``tests/test_tenancy.py`` locks agreement with the
    O(S^2) mean-absolute-difference definition. An empty or all-zero
    vector reads as perfectly equal (0.0)."""
    x = np.asarray(x, np.float64)
    n = x.size
    if n == 0:
        return 0.0
    s = x.sum()
    if s <= 0.0:
        return 0.0
    xs = np.sort(x)
    i = np.arange(1, n + 1, dtype=np.float64)
    return float(2.0 * (i * xs).sum() / (n * s) - (n + 1.0) / n)


def jain_index(x) -> float:
    """Jain's fairness index ``(sum x)^2 / (n * sum x^2)``.

    1 = perfectly equal, 1/n = one-hot. An empty or all-zero vector
    reads as perfectly fair (1.0) — nobody is disadvantaged when
    nobody receives anything."""
    x = np.asarray(x, np.float64)
    n = x.size
    if n == 0:
        return 1.0
    s = x.sum()
    if s <= 0.0:
        return 1.0
    return float(s * s / (n * (x * x).sum()))


def herfindahl_index(x) -> float:
    """Herfindahl-Hirschman concentration ``sum (x_i / sum x)^2``.

    1/n = perfectly spread, 1 = one-hot. Related to Jain's index by
    ``jain = 1 / (n * hhi)`` on any non-degenerate vector. An empty
    vector reads 0.0; an all-zero vector reads the uniform value
    1/n."""
    x = np.asarray(x, np.float64)
    n = x.size
    if n == 0:
        return 0.0
    s = x.sum()
    if s <= 0.0:
        return 1.0 / n
    p = x / s
    return float((p * p).sum())


def tenant_qos_stream(accs) -> np.ndarray:
    """(S,) overall post-warmup QoS success ratio per tenant."""
    return np.array([
        np.asarray(a.succ_kc, np.float64).sum()
        / max(np.asarray(a.n_kc, np.float64).sum(), 1.0)
        for a in accs])


def tenant_qos_satisfaction_stream(accs, rho: float) -> np.ndarray:
    """(S,) per-tenant % of clients with success ratio >= rho (the
    Fig. 3 statistic, computed within each tenant's client population)."""
    return np.array([client_qos_satisfaction_stream(a, rho) for a in accs])


def tenant_served_stream(accs) -> np.ndarray:
    """(S,) post-warmup issued-request totals per tenant — the load
    share the fleet actually carried for each service."""
    return np.array([np.asarray(a.n_kc, np.float64).sum() for a in accs])


def tenant_fairness_stream(accs) -> dict:
    """Cross-tenant fairness indices over the two allocations that
    matter: the QoS *outcome* each tenant got (success ratios) and the
    load *share* each tenant placed. Keys:

    ``gini_qos``/``jain_qos``/``hhi_qos`` over per-tenant QoS ratios;
    ``gini_load``/``jain_load``/``hhi_load`` over per-tenant served
    totals."""
    qos = tenant_qos_stream(accs)
    load = tenant_served_stream(accs)
    return {
        "gini_qos": gini_index(qos),
        "jain_qos": jain_index(qos),
        "hhi_qos": herfindahl_index(qos),
        "gini_load": gini_index(load),
        "jain_load": jain_index(load),
        "hhi_load": herfindahl_index(load),
    }


def tenant_partition_stream(accs) -> dict:
    """Did the S bandit fleets self-partition the shared instances?

    Each tenant's routing profile is its per-instance share of issued
    requests (``choice_counts`` summed over players, normalized). The
    pairwise overlap ``sum_m min(P_i[m], P_j[m])`` is 1.0 when two
    tenants spread identically and 0.0 when they use disjoint
    instances. Returns ``mean_overlap`` (mean over tenant pairs; 1.0
    for S < 2) and ``partition_index = 1 - mean_overlap``."""
    profiles = []
    for a in accs:
        c = np.asarray(a.choice_counts, np.float64).sum(0)   # (M,)
        profiles.append(c / max(c.sum(), 1.0))
    S = len(profiles)
    if S < 2:
        return {"mean_overlap": 1.0, "partition_index": 0.0}
    overlaps = [np.minimum(profiles[i], profiles[j]).sum()
                for i in range(S) for j in range(i + 1, S)]
    mean_overlap = float(np.mean(overlaps))
    return {"mean_overlap": mean_overlap,
            "partition_index": 1.0 - mean_overlap}


# ---------------------------------------------------------------------------
# Event-relative recovery (scenario engine).
# ---------------------------------------------------------------------------

def event_windows_from_series(succ: np.ndarray, issued: np.ndarray,
                              marks: np.ndarray, ev_pre_steps: int,
                              ev_bucket_steps: int,
                              ev_buckets: int) -> tuple[np.ndarray, np.ndarray]:
    """Reference (post-hoc) computation of the accumulator's
    ``ev_succ``/``ev_n`` windows from per-step scalar series — the
    trace-mode counterpart used for stream==trace parity and for
    reading recovery off a ``trace=True`` run."""
    marks = np.asarray(marks)
    E = marks.shape[0]
    ev_s = np.zeros((E, 1 + ev_buckets), np.float64)
    ev_n = np.zeros((E, 1 + ev_buckets), np.float64)
    T = len(succ)
    for e, m in enumerate(marks):
        if m < 0:
            continue
        lo = max(0, m - ev_pre_steps)
        ev_s[e, 0] = succ[lo:m].sum()
        ev_n[e, 0] = issued[lo:m].sum()
        for b in range(ev_buckets):
            blo, bhi = m + b * ev_bucket_steps, m + (b + 1) * ev_bucket_steps
            if blo >= T:
                break
            ev_s[e, 1 + b] = succ[blo:bhi].sum()
            ev_n[e, 1 + b] = issued[blo:bhi].sum()
    return ev_s, ev_n


def event_recovery(acc_or_windows, bucket_s: float,
                   threshold: float = 0.95) -> list[dict]:
    """Per-event adaptation statistics from the recovery windows.

    Returns one dict per real (non-sentinel) event: ``pre`` (baseline
    QoS ratio in the pre-window), ``dip`` (worst post-bucket ratio, and
    its time as ``dip_s``), ``steady`` (mean of the last ≤3
    data-bearing post buckets), ``recovered`` (whether QoS came back
    within the observed windows), and ``recovery_s`` — the left edge of
    the first post bucket at or after the dip with ratio ≥ ``threshold
    * steady`` (``None`` when it never does), i.e. the Fig 10/11-style
    time-to-recover, now available for any scenario for free. Ramped
    events (flash crowds) dip several buckets after their onset mark,
    which is why recovery is measured from the dip, not from bucket 0.

    Degenerate windows are NaN-explicit rather than silently absent or
    spuriously "recovered":

    * an event with *no* data-bearing post bucket (e.g. every
      post-event request shed, or the event at the horizon edge) still
      yields a record — ``pre`` from the pre-window (itself ``nan``
      when the pre-window had no requests), ``dip``/``dip_s``/
      ``steady`` as ``nan``, ``recovered=False``, ``recovery_s=None``;
    * a non-positive or non-finite ``steady`` (all-shed tail: every
      request in the last buckets missed) makes the recovery threshold
      meaningless — ``ratio >= threshold * 0`` holds vacuously — so the
      event reports ``recovered=False``/``recovery_s=None`` instead of
      an instant recovery at the dip.

    Sentinel rows (mark = -1: all-zero windows, no pre *and* no post
    data) are skipped as before.
    """
    if isinstance(acc_or_windows, MetricAccumulator):
        ev_s = np.asarray(acc_or_windows.ev_succ, np.float64)
        ev_n = np.asarray(acc_or_windows.ev_n, np.float64)
    else:
        ev_s, ev_n = (np.asarray(x, np.float64) for x in acc_or_windows)
    out = []
    for e in range(ev_s.shape[0]):
        post_n = ev_n[e, 1:]
        has = post_n > 0
        pre = (ev_s[e, 0] / ev_n[e, 0]) if ev_n[e, 0] > 0 else float("nan")
        if not has.any():
            if ev_n[e, 0] <= 0:
                continue            # sentinel row: no data anywhere
            out.append({
                "pre": float(pre),
                "dip": float("nan"),
                "dip_s": float("nan"),
                "steady": float("nan"),
                "recovered": False,
                "recovery_s": None,
            })
            continue
        ratio = ev_s[e, 1:][has] / post_n[has]
        steady = float(ratio[-3:].mean())
        dip_idx = int(np.argmin(ratio))
        bucket_left = np.flatnonzero(has)
        if not np.isfinite(steady) or steady <= 0.0:
            recovery_s = None       # no meaningful recovery level
        else:
            rec_mask = ratio[dip_idx:] >= threshold * steady
            if rec_mask.any():
                rec_idx = dip_idx + int(np.argmax(rec_mask))
                recovery_s = float(bucket_left[rec_idx] * bucket_s)
            else:                   # still degrading at the window edge
                recovery_s = None
        out.append({
            "pre": float(pre),
            "dip": float(ratio.min()),
            "dip_s": float(bucket_left[dip_idx] * bucket_s),
            "steady": steady,
            "recovered": recovery_s is not None,
            "recovery_s": recovery_s,
        })
    return out
