"""Closed-loop control plane: reactive autoscaling, admission control,
capacity migration — the orchestrator that *answers* overload instead of
scheduling around it.

The scenario engine is open-loop by design: every schedule is fixed at
compile time, so the infrastructure never fights back and the repro
cannot study the control interaction a real continuum always has — K
bandit balancers adapting *while* an orchestrator reshapes the arm set
(the continuous re-orchestration loop of Bisicchia et al., PAPERS.md).
This module closes the loop. A small policy state machine rides in the
simulator's ``lax.scan`` carry (next to the PR 6 breaker state),
observes per-step aggregates the engine already computes — per-arm
queue depth, fleet QoS / timeout rates, drop counts — and feeds back
into the *effective* drivers each step:

* **Reactive autoscaler** (``managed`` > 0): the last ``managed``
  instances of the fleet are the controller's own deployment — a
  standby pool it spawns and kills on aggregate backlog. Spawned
  instances serve only after a ``warmup`` delay (container cold start);
  decisions pass a dwell (``hold``) + hysteresis (``up_queue`` >
  ``down_queue``) + ``action_cooldown`` filter, the classic guard rails
  against control-loop thrash. Scenario liveness always wins: the
  controller cannot resurrect an instance the scenario killed
  (``act_eff = act & up``), and if its mask would darken the whole
  fleet the veto is waived (fail-open, like the breaker).
* **Admission control** (``admit``): per-player token buckets at the
  balancer edge. A fleet-level AIMD admitted-fraction (multiplicative
  decrease while the backlog/QoS signal is hot, additive increase when
  healthy, floored at ``admit_floor``) sets each bucket's refill rate;
  requests beyond the bucket are *shed* — they never reach a queue,
  but they count as issued QoS misses (a denied client is a failed
  client; shedding can only win by protecting the admitted majority,
  never by shrinking the denominator).
* **Capacity migration** (``regions`` > 1): instances partition into
  contiguous regions; when one region's backlog-per-instance leads the
  coldest by ``mig_threshold``, a ``mig_step`` share of service
  capacity moves hot-ward (``s_m`` scales by the inverse share, total
  capacity conserved) — Nezami et al.'s decentralized placement loop
  reduced to its capacity term.

Sharding & parity contract (the engine invariants this composes with):

* Every decision input is *replicated* across player shards: the (M,)
  queue is already psum-replicated by the round loop, scenario drivers
  are replicated, and the per-step fleet QoS/timeout observation is
  psum-reduced once per step (``simulator.step_fn``) — the control
  plane's ONE new in-loop collective. Per-player state (token buckets,
  shed counters) is driven only by shard-local inputs. Replicated
  state therefore evolves identically on every shard with no further
  communication.
* The whole layer is gated on *static* config: a ``None`` or neutral
  :class:`ControlConfig` (``enabled == False``) traces the
  byte-identical open-loop program — parity is structural, not
  numerical luck (tests/test_control.py).
* The carry is an ordinary pytree: it streams through chunked
  ``run_sim_stream``, checkpoints and resumes bit-exactly, and needs
  no randomness (decisions are deterministic functions of replicated
  observations — no ``prand`` keys).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ControlConfig:
    """Static knobs of the closed-loop controller (all mechanisms off
    by default: the default instance is *neutral* and traces the
    byte-identical open-loop program).

    Autoscaler (active when ``managed`` > 0): the LAST ``managed``
    instance indices form the standby pool. ``start_up=False`` parks
    them at t=0 (the usual study: base fleet + spare capacity the
    controller may buy). ``up_queue``/``down_queue`` are hysteresis
    thresholds on fleet backlog per live instance; the signal must
    hold for ``hold`` seconds, actions are ``action_cooldown`` seconds
    apart and move ``batch`` instances; spawns serve after ``warmup``
    seconds.

    Admission (active when ``admit``): shed when backlog per live
    instance exceeds ``target_queue``, rolling QoS falls below
    ``qos_floor``, or the fleet timeout rate exceeds
    ``timeout_ceiling`` (signals EMA-smoothed over ``qos_window``
    seconds). AIMD: ×``admit_md`` per hot step, +``admit_ai``/s when
    healthy, clamped to [``admit_floor``, 1]. Buckets hold at most
    ``burst`` tokens.

    Migration (active when ``regions`` > 1): see module docstring.
    """
    # --- reactive autoscaler ---
    managed: int = 0
    start_up: bool = False
    warmup: float = 2.0
    up_queue: float = 8.0
    down_queue: float = 1.0
    hold: float = 1.0
    action_cooldown: float = 5.0
    batch: int = 1
    # --- admission control (token-bucket load shedding) ---
    admit: bool = False
    target_queue: float = 6.0
    qos_floor: float = 0.0
    timeout_ceiling: float = math.inf
    admit_md: float = 0.9
    admit_ai: float = 0.25
    admit_floor: float = 0.2
    burst: float = 16.0
    qos_window: float = 2.0
    # --- capacity migration between regions ---
    regions: int = 0
    mig_threshold: float = 4.0
    mig_step: float = 0.1
    mig_cooldown: float = 5.0
    share_min: float = 0.25
    share_max: float = 4.0

    @property
    def enabled(self) -> bool:
        """False == neutral: no mechanism active, no carry state, the
        open-loop program byte-for-byte."""
        return self.managed > 0 or self.admit or self.regions > 1


def control_enabled(cfg) -> bool:
    """Static gate ``simulator.build_sim_parts`` keys the whole control
    path on (``cfg`` is a ``SimConfig``)."""
    ctl = getattr(cfg, "control", None)
    return ctl is not None and ctl.enabled


class ControlState(NamedTuple):
    """Controller dynamics carried through the scan. Fleet-level fields
    ((M,)/(R,)/scalars) are replicated across player shards; ``tokens``
    is the only per-player field and stays shard-local."""
    ctrl_on: jax.Array     # (M,) bool desired on/off for managed instances
    ready_at: jax.Array    # (M,) f32 spawn warm-up deadline [s]
    up_dwell: jax.Array    # ()  f32 seconds the scale-up signal has held
    down_dwell: jax.Array  # ()  f32 seconds the scale-down signal has held
    cool_until: jax.Array  # ()  f32 no scale action before this time
    admit_frac: jax.Array  # ()  f32 AIMD admitted fraction in [floor, 1]
    tokens: jax.Array      # (K,) f32 per-player admission token buckets
    ema_qos: jax.Array     # ()  f32 rolling fleet QoS success ratio
    ema_timeout: jax.Array  # () f32 rolling fleet timeout-per-attempt ratio
    share: jax.Array       # (R,) f32 per-region capacity shares (mean 1)
    mig_cool: jax.Array    # ()  f32 no migration before this time


class ControlCounters(NamedTuple):
    """Control-action accounting (post-warmup, like the accumulator's
    measured fields) — the thrash/shed readouts ride on these."""
    shed_k: jax.Array          # (K,) requests shed at admission per player
    admit_frac_sum: jax.Array  # ()  sum of admit_frac per measured step
    scale_up: jax.Array        # ()  scale-up actions
    scale_down: jax.Array      # ()  scale-down actions
    migrations: jax.Array      # ()  capacity-migration actions
    ctrl_up_m: jax.Array       # (M,) steps each managed instance served
    steps: jax.Array           # ()  measured steps


class ControlCarry(NamedTuple):
    state: ControlState
    counters: ControlCounters


def _managed_mask(ccfg: ControlConfig, M: int) -> np.ndarray:
    return np.arange(M) >= M - min(ccfg.managed, M)


def _region_ids(ccfg: ControlConfig, M: int) -> np.ndarray:
    R = max(ccfg.regions, 1)
    return (np.arange(M) * R) // M


def control_init(ccfg: ControlConfig, K: int, M: int) -> ControlCarry:
    """Fresh carry. ``K`` is the LOCAL player width under player
    sharding (buckets/shed are shard-local); (M,)/(R,) fields replicate."""
    managed = jnp.asarray(_managed_mask(ccfg, M))
    R = max(ccfg.regions, 1)
    state = ControlState(
        ctrl_on=managed & bool(ccfg.start_up),
        ready_at=jnp.full((M,), -jnp.inf, jnp.float32),
        up_dwell=jnp.zeros((), jnp.float32),
        down_dwell=jnp.zeros((), jnp.float32),
        cool_until=jnp.full((), -jnp.inf, jnp.float32),
        admit_frac=jnp.ones((), jnp.float32),
        tokens=jnp.full((K,), ccfg.burst, jnp.float32),
        ema_qos=jnp.ones((), jnp.float32),
        ema_timeout=jnp.zeros((), jnp.float32),
        share=jnp.ones((R,), jnp.float32),
        mig_cool=jnp.full((), -jnp.inf, jnp.float32),
    )
    counters = ControlCounters(
        shed_k=jnp.zeros((K,), jnp.float32),
        admit_frac_sum=jnp.zeros((), jnp.float32),
        scale_up=jnp.zeros((), jnp.float32),
        scale_down=jnp.zeros((), jnp.float32),
        migrations=jnp.zeros((), jnp.float32),
        ctrl_up_m=jnp.zeros((M,), jnp.float32),
        steps=jnp.zeros((), jnp.float32),
    )
    return ControlCarry(state, counters)


def control_actuate(
    ccfg: ControlConfig,
    dt: float,
    t: jax.Array,            # scalar f32 sim time
    carry: ControlCarry,
    q: jax.Array,            # (M,) queue depth at step start (replicated)
    act: jax.Array,          # (M,) scenario liveness this step
    nc: jax.Array,           # (K,) scheduled client slots per LB (local)
    s_m: jax.Array,          # (M,) scheduled service-time row
    measf: jax.Array,        # scalar f32 1.0 once past warmup_steps
):
    """Step-start control pass: advance the policy state machine on the
    replicated observations, return the *effective* drivers.

    Returns ``(carry, act_eff, nc_adm, s_m_eff, shed_k)``: the
    controller-masked liveness, the admitted client slots (``nc_adm <=
    nc``; the gap is shed at the balancer edge), the migration-scaled
    service row, and the (K,) f32 shed count this step. Every branch is
    statically gated on the config, so a policy with e.g. admission off
    pays nothing for it.
    """
    st, cnt = carry
    M = act.shape[0]
    managed = jnp.asarray(_managed_mask(ccfg, M))
    tf = jnp.asarray(t, jnp.float32)

    # effective liveness BEFORE this step's decisions: newly spawned
    # capacity only serves once its warm-up has elapsed
    def eff_active(state: ControlState) -> jax.Array:
        if ccfg.managed <= 0:
            return act
        up = jnp.where(managed, state.ctrl_on & (tf >= state.ready_at),
                       True)
        eff = act & up
        # fail-open: never let the controller darken the whole fleet
        return jnp.where(eff.any(), eff, act)

    act0 = eff_active(st)
    live_n = jnp.maximum(act0.sum(), 1).astype(jnp.float32)
    qbar = q.sum() / live_n          # fleet backlog per live instance

    # --- reactive autoscaler: dwell + hysteresis + cooldown ---
    if ccfg.managed > 0:
        up_cond = qbar > ccfg.up_queue
        down_cond = qbar < ccfg.down_queue
        up_dwell = jnp.where(up_cond, st.up_dwell + dt, 0.0)
        down_dwell = jnp.where(down_cond, st.down_dwell + dt, 0.0)
        can_act = tf >= st.cool_until
        parked = managed & ~st.ctrl_on & act   # dead standby can't spawn
        on = managed & st.ctrl_on
        do_up = (up_cond & (up_dwell >= ccfg.hold) & can_act
                 & parked.any())
        do_down = (down_cond & (down_dwell >= ccfg.hold) & can_act
                   & on.any())
        spawn = parked & (jnp.cumsum(parked) <= ccfg.batch)
        kill = on & (jnp.cumsum(on[::-1])[::-1] <= ccfg.batch)
        ctrl_on = jnp.where(do_up, st.ctrl_on | spawn, st.ctrl_on)
        ctrl_on = jnp.where(do_down, ctrl_on & ~kill, ctrl_on)
        ready_at = jnp.where(do_up & spawn, tf + ccfg.warmup, st.ready_at)
        acted = do_up | do_down
        st = st._replace(
            ctrl_on=ctrl_on, ready_at=ready_at,
            up_dwell=jnp.where(acted, 0.0, up_dwell),
            down_dwell=jnp.where(acted, 0.0, down_dwell),
            cool_until=jnp.where(acted, tf + ccfg.action_cooldown,
                                 st.cool_until))
        cnt = cnt._replace(
            scale_up=cnt.scale_up + measf * do_up,
            scale_down=cnt.scale_down + measf * do_down)
    act_eff = eff_active(st)

    # --- capacity migration: hottest region borrows from the coldest ---
    if ccfg.regions > 1:
        rid = jnp.asarray(_region_ids(ccfg, M))
        counts = jnp.asarray(np.bincount(_region_ids(ccfg, M),
                                         minlength=max(ccfg.regions, 1)),
                             jnp.float32)
        rq = jax.ops.segment_sum(q, rid,
                                 num_segments=max(ccfg.regions, 1)) / counts
        hot, cold = jnp.argmax(rq), jnp.argmin(rq)
        gap = rq[hot] - rq[cold]
        do_mig = (gap > ccfg.mig_threshold) & (tf >= st.mig_cool)
        delta = jnp.minimum(jnp.minimum(
            ccfg.mig_step, st.share[cold] - ccfg.share_min),
            ccfg.share_max - st.share[hot])
        delta = jnp.maximum(delta, 0.0) * do_mig
        share = (st.share.at[hot].add(delta).at[cold].add(-delta))
        st = st._replace(
            share=share,
            mig_cool=jnp.where(do_mig, tf + ccfg.mig_cooldown,
                               st.mig_cool))
        cnt = cnt._replace(migrations=cnt.migrations + measf * do_mig)
        s_m_eff = s_m / share[rid]
    else:
        s_m_eff = s_m

    # --- admission: AIMD fraction drives per-player token buckets ---
    if ccfg.admit:
        hot = qbar > ccfg.target_queue
        if ccfg.qos_floor > 0.0:
            hot = hot | (st.ema_qos < ccfg.qos_floor)
        if math.isfinite(ccfg.timeout_ceiling):
            hot = hot | (st.ema_timeout > ccfg.timeout_ceiling)
        frac = jnp.where(hot, st.admit_frac * ccfg.admit_md,
                         jnp.minimum(1.0, st.admit_frac + ccfg.admit_ai * dt))
        frac = jnp.clip(frac, ccfg.admit_floor, 1.0)
        ncf = nc.astype(jnp.float32)
        tokens = jnp.minimum(st.tokens + frac * ncf, ccfg.burst)
        adm = jnp.minimum(ncf, jnp.floor(tokens)).astype(jnp.int32)
        tokens = tokens - adm.astype(jnp.float32)
        shed = ncf - adm.astype(jnp.float32)
        st = st._replace(admit_frac=frac, tokens=tokens)
        cnt = cnt._replace(shed_k=cnt.shed_k + measf * shed)
        nc_adm = adm
    else:
        shed = jnp.zeros_like(nc, jnp.float32)
        nc_adm = nc

    cnt = cnt._replace(
        admit_frac_sum=cnt.admit_frac_sum + measf * st.admit_frac,
        ctrl_up_m=cnt.ctrl_up_m + measf * (managed & act_eff),
        steps=cnt.steps + measf)
    return ControlCarry(st, cnt), act_eff, nc_adm, s_m_eff, shed


def control_observe(ccfg: ControlConfig, carry: ControlCarry,
                    obs: jax.Array, dt: float) -> ControlCarry:
    """Step-end observation pass: fold the fleet-total ``obs = [succ,
    issued, timeouts, attempts]`` (psum-reduced under player sharding —
    the layer's one new collective) into the rolling EMAs the admission
    signal reads next step."""
    st, cnt = carry
    a = dt / max(ccfg.qos_window, dt)
    succ, iss, to, att = obs[0], obs[1], obs[2], obs[3]
    qos = succ / jnp.maximum(iss, 1.0)
    tor = to / jnp.maximum(att, 1.0)
    st = st._replace(
        ema_qos=(1.0 - a) * st.ema_qos + a * qos,
        ema_timeout=(1.0 - a) * st.ema_timeout + a * tor)
    return ControlCarry(st, cnt)


# ---------------------------------------------------------------------------
# Readouts.
# ---------------------------------------------------------------------------

def control_stats_stream(acc, ctrl: ControlCounters) -> dict:
    """Control-action accounting from a streaming run: thrash
    (scale actions per 1k steps), admission-drop fraction (shed over
    *scheduled* requests — ``acc.n_kc`` counts shed requests as issued
    QoS misses, so the two denominators agree), mean admitted fraction
    and standby occupancy."""
    steps = max(float(np.asarray(ctrl.steps)), 1.0)
    shed = float(np.asarray(ctrl.shed_k, np.float64).sum())
    requests = float(np.asarray(acc.n_kc, np.float64).sum())
    up = float(np.asarray(ctrl.scale_up))
    down = float(np.asarray(ctrl.scale_down))
    occ = np.asarray(ctrl.ctrl_up_m, np.float64)
    return {
        "scale_up": up,
        "scale_down": down,
        "scale_actions_per_1k_steps": (up + down) / steps * 1e3,
        "migrations": float(np.asarray(ctrl.migrations)),
        "shed": shed,
        "admission_drop_frac": shed / max(requests, 1.0),
        "mean_admit_frac": float(np.asarray(ctrl.admit_frac_sum)) / steps,
        "standby_up_mean": float(occ.sum()) / steps,
    }


def per_tenant_qos_spread(acc) -> dict:
    """Per-player (tenant) QoS dispersion — the fairness cost of
    admission shedding and autoscaler churn. Players with no issued
    requests are excluded."""
    s = np.asarray(acc.succ_kc, np.float64).sum(-1)
    n = np.asarray(acc.n_kc, np.float64).sum(-1)
    has = n > 0
    if not has.any():
        return {"min": 0.0, "max": 0.0, "mean": 0.0, "std": 0.0,
                "spread": 0.0}
    r = s[has] / n[has]
    return {"min": float(r.min()), "max": float(r.max()),
            "mean": float(r.mean()), "std": float(r.std()),
            "spread": float(r.max() - r.min())}
