"""Synthetic sharded LM data pipeline.

Deterministic per-step batches (hash of step -> PRNG), built directly on
the target sharding with ``jax.make_array_from_callback`` so each host
materializes only its addressable shard — the multi-host pattern, which
degrades gracefully to single-host here. A background thread prefetches
the next batch while the step runs.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.sharding import logical_to_spec


def synthetic_batch(cfg: ModelConfig, shape: ShapeConfig, step: int,
                    mesh: Optional[Mesh] = None):
    """One deterministic batch matching ``model.input_specs`` layouts."""
    B, S = shape.global_batch, shape.seq_len
    rng = np.random.default_rng(np.uint64(0x9E3779B9) * np.uint64(step + 1))

    def lm_pair(b, s):
        """Learnable stream: an LCG next-token function (so example
        training shows real convergence, unlike pure-noise targets)."""
        v = min(cfg.vocab_size, 4093)
        toks = np.empty((b, s + 1), np.int64)
        toks[:, 0] = rng.integers(0, v, b)
        for i in range(s):
            toks[:, i + 1] = (toks[:, i] * 5 + 7) % v
        return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)

    def make(shape_, dtype, vocab=None):
        if np.issubdtype(dtype, np.integer):
            arr = rng.integers(0, vocab or cfg.vocab_size, shape_,
                               dtype=np.int32)
        else:
            arr = rng.standard_normal(shape_, dtype=np.float32)
        return arr

    if cfg.family == "vlm":
        St = S - cfg.num_patches
        toks, tgts = lm_pair(B, St)
        batch = {
            "patches": make((B, cfg.num_patches, cfg.d_model), np.float32),
            "tokens": toks, "targets": tgts,
        }
        axes = {"patches": ("batch", None, None), "tokens": ("batch", None),
                "targets": ("batch", None)}
    elif cfg.family == "audio":
        Sd = min(cfg.max_decode_len, S)
        toks, tgts = lm_pair(B, Sd)
        batch = {
            "frames": make((B, S // 2, cfg.d_model), np.float32),
            "tokens": toks, "targets": tgts,
        }
        axes = {"frames": ("batch", None, None), "tokens": ("batch", None),
                "targets": ("batch", None)}
    else:
        toks, tgts = lm_pair(B, S)
        batch = {"tokens": toks, "targets": tgts}
        axes = {"tokens": ("batch", None), "targets": ("batch", None)}

    if mesh is None:
        return jax.tree.map(jnp.asarray, batch)

    def put(name, arr):
        spec = logical_to_spec(axes[name], mesh)
        sharding = NamedSharding(mesh, spec)
        return jax.make_array_from_callback(
            arr.shape, sharding, lambda idx: arr[idx])

    return {k: put(k, v) for k, v in batch.items()}


def prefetch_iterator(cfg: ModelConfig, shape: ShapeConfig,
                      mesh: Optional[Mesh] = None,
                      depth: int = 2) -> Iterator:
    """Background-thread prefetch of synthetic batches."""
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def worker():
        step = 0
        while not stop.is_set():
            try:
                q.put(synthetic_batch(cfg, shape, step, mesh), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
