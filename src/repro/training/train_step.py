"""Training step factory: loss -> grads -> (optional compression) ->
AdamW, with microbatch gradient accumulation and donated buffers.

Distributed-optimization features:
  * remat (activation checkpointing) inside the layer scan (models).
  * microbatch accumulation (`accum_steps`): splits the per-replica
    batch and lax.scan's the grads — the standard way to fit train_4k
    global batches while the collective schedule overlaps per-microbatch.
  * int8 gradient compression (`compress_grads`): quantize/dequantize
    per-leaf with a per-tensor scale. On a multi-pod mesh the cross-pod
    ("pod"-axis) all-reduce is the DCN bottleneck; compression emulates
    the wire format end-to-end so convergence impact is testable.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.training.optimizer import Optimizer


def int8_compress(tree):
    """Per-leaf symmetric int8 quantize -> dequantize (lossy)."""
    def q(g):
        gf = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        qi = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        return qi.astype(jnp.float32) * scale
    return jax.tree.map(q, tree)


def make_train_step(
    model,
    optimizer: Optimizer,
    accum_steps: int = 1,
    compress_grads: bool = False,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=True)

    def grads_of(params, batch):
        if accum_steps == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def split(x):
            B = x.shape[0]
            mb = B // accum_steps
            return x.reshape(accum_steps, mb, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def step(carry, mb):
            loss_sum, grad_sum = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            grad_sum = jax.tree.map(jnp.add, grad_sum, g)
            return (loss_sum + l, grad_sum), ()

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grad_sum), _ = jax.lax.scan(
            step, (jnp.zeros((), jnp.float32), zeros), micro)
        scale = 1.0 / accum_steps
        return loss_sum * scale, jax.tree.map(lambda g: g * scale, grad_sum)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        if compress_grads:
            grads = int8_compress(grads)
        params, opt_state = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss}
        return params, opt_state, metrics

    return train_step
