"""Training runtime: optimizer, train step, synthetic data pipeline."""
from repro.training.data import prefetch_iterator, synthetic_batch
from repro.training.optimizer import (
    AdamWState,
    Optimizer,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
)
from repro.training.train_step import int8_compress, make_train_step

__all__ = [
    "adamw", "cosine_schedule", "global_norm", "clip_by_global_norm",
    "AdamWState", "Optimizer", "make_train_step", "int8_compress",
    "synthetic_batch", "prefetch_iterator",
]
