"""AdamW + schedules in pure JAX (no optax in this environment).

Optimizer state is a pytree mirroring the params, so the same logical
axes shard both (moments live wherever their parameter lives — the
ZeRO-style layout falls out of FSDP param sharding for free).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: object
    v: object


class Optimizer(NamedTuple):
    init: Callable
    update: Callable            # (grads, state, params) -> (params, state)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), norm


def adamw(
    lr: Callable | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(grads, state, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        stepf = step.astype(jnp.float32)
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** stepf
        bc2 = 1.0 - b2 ** stepf

        def upd(p, g, m, v):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step=step, m=new_m, v=new_v)

    return Optimizer(init=init, update=update)
