"""End-to-end training driver.

Defaults run a ~100M-param qwen3-family model for a few hundred steps on
whatever devices exist (CPU here; the same code path drives the
production mesh). Features exercised: sharded synthetic data pipeline,
remat, microbatch accumulation, optional int8 grad compression, async
checkpointing with restart, and elastic recovery hooks.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import Checkpointer
from repro.configs import ARCH_NAMES, get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.sharding import rule_overrides, tree_shardings
from repro.training import (adamw, cosine_schedule, make_train_step,
                            synthetic_batch)
from repro.training.optimizer import AdamWState


def small_mesh():
    devs = np.asarray(jax.devices())
    n = devs.size
    model_ways = 1
    for cand in (4, 2, 1):
        if n % cand == 0 and n >= cand:
            model_ways = cand
            break
    return Mesh(devs.reshape(n // model_ways, model_ways), ("data", "model"))


def train_100m_config(base: str = "qwen3-4b"):
    """~100M-param member of the qwen3 family (train_100m example)."""
    cfg = get_config(base)
    return dataclasses.replace(
        cfg, name=base + "-100m", num_layers=8, d_model=640, num_heads=8,
        num_kv_heads=4, head_dim=80, d_ff=1536, vocab_size=32768,
        fsdp=False)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--train-100m", action="store_true",
                    help="~100M-param example config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.train_100m:
        cfg = train_100m_config(args.arch)
    else:
        cfg = get_config(args.arch, reduced=args.smoke)
    shape = ShapeConfig("cli", "train", args.seq_len, args.batch)
    mesh = small_mesh()
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, np.asarray(mesh.devices).shape))}")

    model = build_model(cfg)
    opt = adamw(cosine_schedule(args.lr, 20, args.steps))
    step_fn = make_train_step(model, opt, accum_steps=args.accum_steps,
                              compress_grads=args.compress_grads)

    with mesh:
        p_axes = model.param_axes()
        p_shard = tree_shardings(p_axes, mesh)
        params = jax.jit(
            lambda k: model.init(k), out_shardings=p_shard
        )(jax.random.PRNGKey(0))
        opt_state = jax.jit(opt.init, out_shardings=tree_shardings(
            AdamWState(step=(), m=p_axes, v=p_axes), mesh))(params)

        start = 0
        ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
        if ckpt and args.resume and ckpt.latest_step() is not None:
            (params, opt_state), start = ckpt.restore(
                (params, opt_state),
                shardings=(p_shard, tree_shardings(
                    AdamWState(step=(), m=p_axes, v=p_axes), mesh)))
            print(f"resumed from step {start}")

        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        t0 = time.time()
        losses = []
        for step in range(start, args.steps):
            batch = synthetic_batch(cfg, shape, step, mesh)
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                losses.append(loss)
                dt = time.time() - t0
                tok_s = (step - start + 1) * shape.global_batch \
                    * shape.seq_len / max(dt, 1e-9)
                print(f"step {step:5d} loss {loss:8.4f} tok/s {tok_s:9.0f}")
            if ckpt and step and step % args.ckpt_every == 0:
                ckpt.save(step, (params, opt_state), blocking=False)
        if ckpt:
            ckpt.save(args.steps, (params, opt_state), blocking=True)
        print(f"done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
        return losses


if __name__ == "__main__":
    main()
