"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers.

NOTE: do not import ``repro.launch.dryrun`` from library code — it sets
XLA_FLAGS at import time (by design: it must run before jax init).
"""
from repro.launch.mesh import (make_grid_mesh, make_production_mesh,
                               make_test_mesh)

__all__ = ["make_grid_mesh", "make_production_mesh", "make_test_mesh"]
