"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int | None = None):
    """Small mesh over however many (host) devices tests have."""
    devs = np.asarray(jax.devices())
    if pod:
        need = pod * data * model
        return Mesh(devs[:need].reshape(pod, data, model),
                    ("pod", "data", "model"))
    need = data * model
    return Mesh(devs[:need].reshape(data, model), ("data", "model"))


def make_grid_mesh(devices=None):
    """1-D mesh over every available device: the evaluation-grid mesh.

    The axis is named ``data`` so the standard partitioning rules apply
    (the logical ``grid`` axis maps to it; see
    repro/sharding/partitioning.py). Scenario/seed lanes of the grid
    are independent programs, so a flat data-parallel mesh is the whole
    story — no model axis. On CPU CI, force a multi-device mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before
    the first jax call).
    """
    devs = np.asarray(devices if devices is not None else jax.devices())
    return Mesh(devs.reshape(-1), ("data",))


def make_continuum_mesh(players: int | None = None, devices=None):
    """2-D (``data``, ``players``) mesh: the continuum-simulation mesh.

    ``data`` carries independent grid lanes (scenario × seed — the
    logical ``grid`` axis), ``players`` splits the K load balancers
    *inside* each simulation (the logical ``players`` axis: bandit
    rings, weights, KDE stats shard; only the per-round (M,) arrival
    ``psum`` crosses it — see repro/continuum/simulator.py and
    docs/SCALING.md for choosing the split).

    ``players=None`` puts every device on the player axis (the
    single-simulation, giant-fleet shape); ``players=1`` degrades to a
    pure grid mesh; anything between splits devices ``(D // players,
    players)``. On CPU, force fake devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the
    first jax call.
    """
    devs = np.asarray(devices if devices is not None else jax.devices())
    n = devs.size
    p = n if players is None else players
    if p <= 0 or n % p:
        raise ValueError(
            f"players={p} must positively divide the device count {n}")
    return Mesh(devs.reshape(n // p, p), ("data", "players"))
