"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell
with 512 placeholder host devices, and extract roofline inputs.

MUST set XLA_FLAGS before any jax import (jax locks the device count on
first init) — hence the first two lines.

Usage:
  python -m repro.launch.dryrun --arch mistral-nemo-12b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json`` immediately
(idempotent: existing results are skipped unless --force).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402

from repro.configs import (ARCH_NAMES, SHAPES, get_config, get_shape,  # noqa: E402
                           shape_applicable)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.roofline import collective_bytes, model_flops, roofline_terms  # noqa: E402
from repro.sharding import logical_to_spec, rule_overrides, tree_shardings  # noqa: E402
from repro.training import adamw, cosine_schedule, make_train_step  # noqa: E402


def _fit_spec(shape, spec, mesh):
    """Drop mesh axes that do not divide their dimension (e.g. kv_heads=8
    cannot shard 16-way TP; the cache seq axis picks up the slack)."""
    from jax.sharding import PartitionSpec as P
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is None:
            out.append(None)
            continue
        axes = list(entry) if isinstance(entry, tuple) else [entry]
        while axes:
            ways = 1
            for a in axes:
                ways *= mesh.shape[a]
            if dim % ways == 0:
                break
            axes.pop()            # drop the innermost axis and retry
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def _sharded_sds(tree, axes, mesh, dtype_map=None):
    """ShapeDtypeStructs with shardings from logical axes."""
    def mk(sds, ax):
        dt = sds.dtype
        if dtype_map:
            dt = dtype_map(dt)
        spec = _fit_spec(sds.shape, logical_to_spec(ax, mesh), mesh)
        return jax.ShapeDtypeStruct(
            sds.shape, dt, sharding=NamedSharding(mesh, spec))
    from repro.sharding.partitioning import is_axes_leaf
    return jax.tree.map(mk, tree, axes, is_leaf=is_axes_leaf)


def _tree_sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _device_bytes(tree, mesh):
    """Per-device bytes of a sharded SDS tree (static)."""
    n = mesh.size
    total = 0
    for leaf in jax.tree.leaves(tree):
        size = leaf.dtype.itemsize
        for d in leaf.shape:
            size *= d
        shard = leaf.sharding.shard_shape(leaf.shape) \
            if getattr(leaf, "sharding", None) is not None else leaf.shape
        ssize = leaf.dtype.itemsize
        for d in shard:
            ssize *= d
        total += ssize
    return total


def _rules_for(cfg, shape, mesh):
    """Logical-axis rule overrides for this cell."""
    over = {}
    over["embed_fsdp"] = ("data",) if cfg.fsdp else ()
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_ways = 1
    for a in batch_axes:
        batch_ways *= mesh.shape[a]
    if shape.global_batch % batch_ways == 0 and shape.global_batch >= batch_ways:
        layout = os.environ.get("REPRO_DECODE_LAYOUT", "hybrid")
        if shape.kind == "decode" and layout == "batchmodel":
            # inverted decode layout: batch on the TP axis, cache seq on
            # the data axis — attention contracts fully sharded with no
            # cache repartitioning, but MLP weights get all-gathered
            over["batch"] = ("model",)
            over["ctx"] = batch_axes
            over["kv_batch"] = ("model",)
        elif shape.kind == "decode" and layout == "hybrid":
            # hybrid: MLP/projections stay TP (batch on data); only the
            # attention inner block runs in the cache's inverted layout
            # (cache batch on model, seq on data) — per-layer layout
            # transitions move ~MB activations, never the cache
            over["batch"] = batch_axes
            over["kv_batch"] = ("model",)
            over["ctx"] = batch_axes
        else:
            over["batch"] = batch_axes
            over["kv_batch"] = batch_axes
            # decode: KV-cache seq picks up the model axis (kv_heads
            # rarely divide a 16-way TP; sequence sharding is the
            # JetStream-style fix)
            over["ctx"] = ("model",) if shape.kind == "decode" else ()
    else:
        # long-context mode: batch unshardable -> full context parallelism
        over["batch"] = ()
        over["ctx"] = batch_axes + ("model",)
    return over


def _layer_variants(cfg):
    """Two reduced-depth full-width variants for secant cost accounting.

    XLA's cost_analysis counts a while-loop body ONCE, so the scanned
    compile under-reports FLOPs/bytes/collectives by ~L. Every cost
    component is affine in depth (scan body xL, stacked-param optimizer
    ops xL, embed/unembed constant), so compiling *unrolled* variants at
    depths (a, b) and extrapolating linearly to the real depth
    reproduces the unrolled counts at a fraction of the compile time
    (verified against a full unroll of qwen3-4b train_4k: <2% error).
    """
    import dataclasses as _dc
    if cfg.local_global_pattern is not None:
        nl, ng = cfg.local_global_pattern
        period = nl + ng
        a, b = period, 2 * period            # 1 group vs 2 groups
        eq_layers = cfg.num_layers           # extrapolate in layer units
        va = _dc.replace(cfg, num_layers=a)
        vb = _dc.replace(cfg, num_layers=b)
        return (a, va), (b, vb), eq_layers
    if cfg.encoder_layers:
        a, b = 2, 4          # whisper-tiny real depth == 4: b is exact
        return ((a, _dc.replace(cfg, num_layers=a, encoder_layers=a)),
                (b, _dc.replace(cfg, num_layers=b, encoder_layers=b)),
                cfg.num_layers)
    # deeper pair: per-layer cost slopes converge with depth (XLA fusion
    # is not depth-affine at very shallow unrolls; see EXPERIMENTS.md)
    a, b = 4, 12
    return ((a, _dc.replace(cfg, num_layers=a)),
            (b, _dc.replace(cfg, num_layers=b)), cfg.num_layers)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             accum_steps: int = 1, extra_tag: str = "",
             rule_extra=None, cfg=None, unroll=False):
    cfg = cfg or get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "tag": extra_tag,
        "params_B": cfg.param_count() / 1e9,
        "active_params_B": cfg.active_param_count() / 1e9,
    }
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        result.update(status="skipped", reason=why)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    over = _rules_for(cfg, shape, mesh)
    if rule_extra:
        over.update(rule_extra)

    if unroll:
        os.environ["REPRO_SCAN_UNROLL"] = "1"
    else:
        os.environ.pop("REPRO_SCAN_UNROLL", None)

    t0 = time.time()
    with rule_overrides(**over), mesh:
        params_shapes = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0)))
        p_axes = model.param_axes()
        serve_dt = (lambda dt: jnp.bfloat16 if dt == jnp.float32 else dt) \
            if shape.kind != "train" else None
        params_sds = _sharded_sds(params_shapes, p_axes, mesh,
                                  dtype_map=serve_dt)
        batch_specs, batch_axes = model.input_specs(shape)
        batch_sds = _sharded_sds(batch_specs, batch_axes, mesh)

        if shape.kind == "train":
            opt = adamw(cosine_schedule(3e-4, 100, 10_000))
            opt_shapes = jax.eval_shape(opt.init, params_sds)
            from repro.training.optimizer import AdamWState
            opt_axes = AdamWState(step=(), m=p_axes, v=p_axes)
            opt_sds = _sharded_sds(opt_shapes, opt_axes, mesh)
            step_fn = make_train_step(model, opt, accum_steps=accum_steps)
            fn = jax.jit(step_fn, donate_argnums=(0, 1))
            args = (params_sds, opt_sds, batch_sds)
            tokens = shape.global_batch * shape.seq_len
        elif shape.kind == "prefill":
            fn = jax.jit(lambda p, b: model.prefill(p, b,
                                                    max_len=shape.seq_len))
            args = (params_sds, batch_sds)
            tokens = shape.global_batch * shape.seq_len
        else:  # decode: one token vs a seq_len cache
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            c_axes = model.cache_axes()
            cache_sds = _sharded_sds(cache_shapes, c_axes, mesh,
                                     dtype_map=serve_dt)
            # pin outputs: new cache keeps the input layout (otherwise
            # XLA may pick a different output sharding and repartition
            # the whole cache through collectives every step), logits
            # batch x vocab sharded.
            logits_spec = jax.ShapeDtypeStruct(
                (shape.global_batch, 1, cfg.vocab_size), jnp.bfloat16)
            lax_ = ("batch", None, None) \
                if os.environ.get("REPRO_DECODE_LAYOUT") == "batchmodel" \
                else ("batch", None, "vocab")
            logits_sh = _sharded_sds(logits_spec, lax_, mesh).sharding
            cache_sh = jax.tree.map(lambda s: s.sharding, cache_sds)
            if os.environ.get("REPRO_DECODE_PIN_OUT", "1") == "1":
                fn = jax.jit(model.decode, donate_argnums=(1,),
                             out_shardings=(logits_sh, cache_sh))
            else:
                fn = jax.jit(model.decode, donate_argnums=(1,))
            args = (params_sds, cache_sds, batch_sds)
            tokens = shape.global_batch          # one new token per seq

        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        # ---- memory ----
        mem = {}
        try:
            ma = compiled.memory_analysis()
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes"):
                v = getattr(ma, attr, None)
                if v is not None:
                    mem[attr] = int(v)
        except Exception as e:      # CPU backend may not implement it
            mem["error"] = repr(e)
        mem["static_arg_bytes_per_device"] = _device_bytes(
            jax.tree.leaves(args), mesh)
        result["memory"] = mem

        # ---- cost ----
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        result["cost"] = {"flops": flops, "bytes_accessed": bytes_acc,
                          "raw_keys": sorted(cost)[:40]}

        # ---- collectives ----
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        counts = coll.pop("_counts")
        coll_total = sum(coll.values())
        result["collectives"] = {"bytes_weighted": coll, "counts": counts,
                                 "total_bytes": coll_total}

        # ---- roofline ----
        terms = roofline_terms(flops, bytes_acc, coll_total)
        mf = model_flops(cfg.param_count(), cfg.active_param_count(),
                         tokens, shape.kind)
        mf_per_dev = mf / mesh.size
        terms["model_flops_per_device"] = mf_per_dev
        terms["useful_flops_ratio"] = (mf_per_dev / flops) if flops else 0.0
        result["roofline"] = terms
        result["timing"] = {"lower_s": t_lower, "compile_s": t_compile}
        result["status"] = "ok"
    return result


def run_cell_secant(arch: str, shape_name: str, multi_pod: bool,
                    accum_steps: int = 1, extra_tag: str = ""):
    """Roofline-accurate cell: scanned compile for memory/lowering proof
    + two unrolled shallow variants for linear cost extrapolation."""
    real_cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(real_cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "kind": shape.kind, "tag": extra_tag,
                "status": "skipped", "reason": why}

    base = run_cell(arch, shape_name, multi_pod, accum_steps, extra_tag)
    if base["status"] != "ok":
        return base

    (a, va), (b, vb), L_real = _layer_variants(real_cfg)
    ra = run_cell(arch, shape_name, multi_pod, accum_steps,
                  extra_tag, cfg=va, unroll=True)
    rb = run_cell(arch, shape_name, multi_pod, accum_steps,
                  extra_tag, cfg=vb, unroll=True)
    if ra["status"] != "ok" or rb["status"] != "ok":
        base["secant_error"] = (ra.get("error"), rb.get("error"))
        return base

    def extrap(fa, fb):
        slope = (fb - fa) / (b - a)
        return fa + slope * (L_real - a)

    flops = extrap(ra["cost"]["flops"], rb["cost"]["flops"])
    bytes_acc = extrap(ra["cost"]["bytes_accessed"],
                       rb["cost"]["bytes_accessed"])
    coll = {}
    for k in ra["collectives"]["bytes_weighted"]:
        coll[k] = extrap(ra["collectives"]["bytes_weighted"][k],
                         rb["collectives"]["bytes_weighted"][k])
    counts = {}
    for k in ra["collectives"]["counts"]:
        counts[k] = extrap(ra["collectives"]["counts"][k],
                           rb["collectives"]["counts"][k])
    coll_total = sum(coll.values())

    mesh = make_production_mesh(multi_pod=multi_pod)
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill") else shape.global_batch)
    terms = roofline_terms(flops, bytes_acc, coll_total)
    mf = model_flops(real_cfg.param_count(), real_cfg.active_param_count(),
                     tokens, shape.kind)
    terms["model_flops_per_device"] = mf / mesh.size
    terms["useful_flops_ratio"] = (mf / mesh.size / flops) if flops else 0.0

    base["cost"] = {"flops": flops, "bytes_accessed": bytes_acc,
                    "mode": "secant", "depths": [a, b],
                    "eq_layers": L_real}
    base["collectives"] = {"bytes_weighted": coll, "counts": counts,
                           "total_bytes": coll_total}
    base["roofline"] = terms
    base["cost_mode"] = "secant"
    return base


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--cost-mode", choices=("scan", "secant"),
                    default="scan")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                for mp in meshes:
                    cells.append((a, s, mp))
    else:
        assert args.arch and args.shape
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    for arch, shape, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        tag = f"__{args.tag}" if args.tag else ""
        path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}{tag}.json")
        if os.path.exists(path) and not args.force:
            print(f"[skip-existing] {path}")
            continue
        print(f"[cell] {arch} x {shape} x {mesh_name} ...", flush=True)
        t0 = time.time()
        runner = run_cell_secant if args.cost_mode == "secant" else run_cell
        try:
            res = runner(arch, shape, mp, accum_steps=args.accum_steps,
                         extra_tag=args.tag)
        except Exception as e:
            res = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()}
        res["wall_s"] = time.time() - t0
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        print(f"  -> {res['status']} ({res['wall_s']:.1f}s)", flush=True)


if __name__ == "__main__":
    main()
