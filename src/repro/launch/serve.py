"""Serving driver: M model replicas behind the QEdgeProxy router.

Each replica is a ServingEngine (on this CPU container they share the
device but carry distinct emulated network distances + load queues; on a
real cluster each would be one data-parallel replica group). K
front-ends issue request microbatches; the router learns per-replica
QoS success probabilities and SWRR-routes to meet (tau, rho, W).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --replicas 3 --frontends 4 --requests 200
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.core import BanditParams
from repro.models import build_model
from repro.serving import QEdgeRouter, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--frontends", type=int, default=4)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--tau", type=float, default=0.5)
    ap.add_argument("--slow-replica", type=int, default=-1,
                    help="index of a replica with +tau extra latency")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.smoke or True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.decode_steps

    engines = []
    for m in range(args.replicas):
        extra = args.tau if m == args.slow_replica else 0.0
        engines.append(ServingEngine(model, params, max_len, extra))

    router = QEdgeRouter(
        args.frontends, args.replicas,
        BanditParams(tau=args.tau, rho=0.9, window=30.0, cooldown=5.0))

    ok = 0
    total = 0
    t_last_maint = time.monotonic()
    for r in range(args.requests):
        choices = router.route()
        lats = np.zeros(args.frontends)
        for k, m in enumerate(choices):
            prompt = jax.random.randint(
                jax.random.PRNGKey(r * 131 + k), (args.batch, args.prompt_len),
                0, cfg.vocab_size)
            _, cache, lat_p = engines[m].prefill({"tokens": prompt})
            lat = lat_p
            tok = jnp.zeros((args.batch, 1), jnp.int32)
            for i in range(args.decode_steps):
                _, cache, lat_d = engines[m].decode(
                    cache, tok, args.prompt_len + i)
                lat += lat_d
            lats[k] = lat
            total += 1
            ok += int(lat <= args.tau)
        router.feedback(choices, lats)
        if time.monotonic() - t_last_maint > 1.0:
            router.maintenance()
            t_last_maint = time.monotonic()
        if r == args.requests // 2 and args.slow_replica >= 0:
            print(f"[{r}] weights:\n{router.weights.round(3)}")

    router.maintenance()
    print(f"QoS success: {ok}/{total} = {100*ok/max(total,1):.1f}% "
          f"(tau={args.tau}s)")
    print("final routing weights (frontends x replicas):")
    print(router.weights.round(3))
    print("replica QoS estimates:")
    print(router.qos_estimates.round(3))
    return router


if __name__ == "__main__":
    main()
