"""InternVL2-1B [arXiv:2404.16821] — transformer backbone only.

VLM: InternViT frontend is a STUB (input_specs provides 256 precomputed
patch embeddings); the LM backbone is Qwen2-0.5B-like: 24L, d_model=896,
14 heads (kv=2), head_dim=64, d_ff=4864, vocab=151655, QKV bias.
"""
from repro.configs.base import VLM, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family=VLM,
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    num_patches=256,
    tie_embeddings=True,
)
