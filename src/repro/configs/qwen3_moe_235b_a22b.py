"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3 MoE family].

MoE decoder: 94L, d_model=4096, 64 heads (kv=4), head_dim=128,
128 experts top-8, per-expert d_ff=1536, vocab=151936, qk-norm.
"""
from repro.configs.base import MOE, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family=MOE,
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=1536,
    fsdp=True,
)
