"""Qwen3-4B [hf:Qwen/Qwen3 family].

Dense GQA decoder with qk-norm: 36L, d_model=2560, 32 heads (kv=8),
head_dim=128, d_ff=9728, vocab=151936.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family=DENSE,
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
)
