"""Hymba-1.5B [arXiv:2411.13676].

Hybrid-head decoder: every layer runs attention heads and Mamba(-2
style SSD) heads *in parallel* on the same input and averages the
branch outputs. 32L, d_model=1600, 25 attn heads (kv=5), head_dim=64,
d_ff=5504, vocab=32001, ssm_state=16. Attention branch uses a sliding
window (Hymba keeps only 3 full-attention layers; we model the
sliding-window branch, which is what makes long_500k bounded).
"""
from repro.configs.base import HYBRID, ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family=HYBRID,
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,
    ssm_state=16,
    ssm_heads=25,
    ssm_head_dim=64,
    ssm_chunk=128,
    rope_theta=10_000.0,
)
