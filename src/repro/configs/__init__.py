"""Architecture config registry.

``get_config(name)`` returns the exact published config;
``get_config(name, reduced=True)`` returns the structurally-identical
smoke variant. ``--arch <id>`` in the launchers resolves through here.
"""
from __future__ import annotations

from repro.configs.base import (
    AUDIO,
    DENSE,
    HYBRID,
    LONG_CONTEXT_ARCHS,
    MOE,
    SHAPES,
    SSM,
    VLM,
    ModelConfig,
    ShapeConfig,
    shape_applicable,
)

from repro.configs import (  # noqa: E402
    gemma3_1b,
    hymba_1_5b,
    internvl2_1b,
    mamba2_1_3b,
    mistral_nemo_12b,
    qwen25_14b,
    qwen3_4b,
    qwen3_moe_30b_a3b,
    qwen3_moe_235b_a22b,
    whisper_tiny,
)

_REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        mistral_nemo_12b,
        gemma3_1b,
        qwen25_14b,
        qwen3_4b,
        hymba_1_5b,
        qwen3_moe_235b_a22b,
        qwen3_moe_30b_a3b,
        internvl2_1b,
        whisper_tiny,
        mamba2_1_3b,
    )
}

ARCH_NAMES = tuple(_REGISTRY)


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]
    return cfg.reduced() if reduced else cfg


def get_shape(name: str, reduced: bool = False) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    shp = SHAPES[name]
    return shp.reduced() if reduced else shp


def all_cells():
    """All (arch, shape) cells with applicability flags."""
    cells = []
    for a in ARCH_NAMES:
        for s in SHAPES:
            ok, why = shape_applicable(_REGISTRY[a], SHAPES[s])
            cells.append((a, s, ok, why))
    return cells


__all__ = [
    "ARCH_NAMES",
    "SHAPES",
    "LONG_CONTEXT_ARCHS",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "get_shape",
    "all_cells",
    "shape_applicable",
    "DENSE",
    "MOE",
    "SSM",
    "HYBRID",
    "VLM",
    "AUDIO",
]
