"""Whisper-tiny [arXiv:2212.04356] — transformer backbone only.

Enc-dec audio model; the conv frontend is a STUB (input_specs provides
precomputed frame embeddings at the post-conv rate: seq_len//2 frames).
4L encoder + 4L decoder, d_model=384, 6 heads (MHA, kv=6), head_dim=64,
d_ff=1536, vocab=51865, decoder max positions 448.
"""
from repro.configs.base import AUDIO, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family=AUDIO,
    num_layers=4,            # decoder layers
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    max_decode_len=448,
    cross_kv_len=1500,       # standard whisper 30 s => 1500 frames
    rope_theta=10_000.0,     # unused: whisper uses learned/sinusoidal pos
)
