"""Mamba2-1.3B [arXiv:2405.21060] — SSD (state-space duality).

Attention-free: 48L, d_model=2048, expand=2 (inner 4096), head_dim=64
=> 64 SSD heads, d_state=128, conv=4, vocab=50280.
"""
from repro.configs.base import SSM, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family=SSM,
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_heads=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
)
