"""Config dataclasses for architectures and input shapes.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (exact published numbers) built on :class:`ModelConfig`.
``ModelConfig.reduced()`` derives the CPU smoke-test variant of the same
family (small widths/layers/experts, tiny vocab).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# Families -----------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
VLM = "vlm"
AUDIO = "audio"

FAMILIES = (DENSE, MOE, SSM, HYBRID, VLM, AUDIO)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (decoder-only LM unless enc-dec)."""

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention details ---
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    sliding_window: Optional[int] = None       # window for *local* layers
    # (n_local, n_global) repeating pattern; None => all layers global.
    local_global_pattern: Optional[Tuple[int, int]] = None

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                          # per-expert hidden size
    # per-expert buffer = ceil(k*T/E * factor); tokens over it are
    # dropped (GShard semantics). Serving paths may want this higher.
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- encoder/decoder (whisper) ---
    encoder_layers: int = 0
    max_decode_len: int = 0                    # architectural cap (whisper: 448)
    cross_kv_len: int = 0                      # encoder output length seen by decoder

    # --- VLM ---
    num_patches: int = 0                       # vision-prefix length (stub frontend)

    # --- common ---
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    fsdp: bool = False                         # shard params over the data axis too

    # ------------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.family == SSM

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_inner(self) -> int:
        return self.ssm_heads * self.ssm_head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer attention kind: 'local' / 'global' (dense archs only)."""
        if self.local_global_pattern is None:
            return ("global",) * self.num_layers
        n_local, n_global = self.local_global_pattern
        period = n_local + n_global
        kinds = []
        for i in range(self.num_layers):
            kinds.append("local" if (i % period) < n_local else "global")
        return tuple(kinds)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                   # unembed
        per_layer = 0
        if self.family != SSM:
            per_layer += d * self.q_dim + self.q_dim * d          # Wq, Wo
            per_layer += 2 * d * self.kv_dim                      # Wk, Wv
            if self.qkv_bias:
                per_layer += self.q_dim + 2 * self.kv_dim
        if self.is_moe:
            per_layer += d * self.num_experts                     # router
            per_layer += self.num_experts * 3 * d * self.moe_d_ff
        elif self.d_ff > 0:
            per_layer += 3 * d * self.d_ff                        # SwiGLU
        if self.family in (SSM, HYBRID):
            inner = self.ssm_inner
            # in_proj -> [z, x, B, C, dt]; ngroups=1 so B,C are d_state wide
            per_layer += d * (2 * inner + 2 * self.ssm_state + self.ssm_heads)
            per_layer += inner * d                                 # out_proj
            per_layer += (inner + 2 * self.ssm_state) * self.ssm_conv  # conv1d
            per_layer += 2 * self.ssm_heads                        # A_log, dt_bias
        per_layer += 2 * d                                         # 2 RMSNorms
        n += per_layer * self.num_layers
        n += per_layer * self.encoder_layers                       # enc-dec approx
        n += d                                                     # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_experts = self.num_layers * self.num_experts * 3 * d * self.moe_d_ff
        active = self.num_layers * self.experts_per_token * 3 * d * self.moe_d_ff
        return full - all_experts + active

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Same-family smoke-test config: tiny but structurally identical."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            fsdp=False,
        )
        if self.local_global_pattern is not None:
            # keep one (local,global) group: 2 layers = 1 local + 1 global
            kw["local_global_pattern"] = (1, 1)
            kw["sliding_window"] = 8
        elif self.sliding_window is not None:
            kw["sliding_window"] = 8
        if self.is_moe:
            kw["num_experts"] = 8
            kw["experts_per_token"] = 2
            kw["moe_d_ff"] = 32
            kw["d_ff"] = 0
        if self.family in (SSM, HYBRID):
            kw["ssm_state"] = min(self.ssm_state, 8)
            kw["ssm_heads"] = 4
            kw["ssm_head_dim"] = 16
            kw["ssm_chunk"] = 16
        if self.encoder_layers:
            kw["encoder_layers"] = 1
            kw["num_layers"] = 1
            kw["max_decode_len"] = 32
            kw["cross_kv_len"] = 16
        if self.num_patches:
            kw["num_patches"] = 4
        return replace(self, **kw)


# Shapes --------------------------------------------------------------------
TRAIN = "train"
PREFILL = "prefill"
DECODE = "decode"


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell.

    ``kind``:
      - ``train``   lowers ``train_step`` (fwd+bwd+opt) on (batch, seq).
      - ``prefill`` lowers ``serve_prefill`` on (batch, seq).
      - ``decode``  lowers ``serve_step`` — one new token against a KV
        cache of length ``seq_len``.
    """

    name: str
    kind: str
    seq_len: int
    global_batch: int

    def reduced(self) -> "ShapeConfig":
        return replace(
            self,
            name=self.name + "-smoke",
            seq_len=min(self.seq_len, 64),
            global_batch=min(self.global_batch, 2),
        )


SHAPES = {
    "train_4k": ShapeConfig("train_4k", TRAIN, 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", PREFILL, 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", DECODE, 32_768, 128),
    "long_500k": ShapeConfig("long_500k", DECODE, 524_288, 1),
}

# long-context eligibility: sub-quadratic / bounded-state archs only
# (see DESIGN.md §Arch-applicability).
LONG_CONTEXT_ARCHS = ("mamba2-1.3b", "hymba-1.5b", "gemma3-1b")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether this (arch, shape) cell is runnable, with a reason if not."""
    if shape.name.startswith("long_") and cfg.name not in LONG_CONTEXT_ARCHS:
        return False, "pure full-attention arch: 500k decode is quadratic-cost/unbounded-KV (skip per assignment)"
    return True, ""
