"""Gemma-3 1B [hf:google/gemma-3-1b-pt].

Dense GQA decoder with 5:1 local:global attention pattern, 512-token
sliding window on local layers: 26L, d_model=1152, 4 heads (kv=1),
head_dim=256, d_ff=6912, vocab=262144, qk-norm, 128k context.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family=DENSE,
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    qk_norm=True,
    rope_theta=1_000_000.0,
    sliding_window=512,
    local_global_pattern=(5, 1),
    tie_embeddings=True,
)
