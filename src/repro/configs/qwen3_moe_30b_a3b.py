"""Qwen3-MoE-30B-A3B [hf:Qwen/Qwen3-30B-A3B].

MoE decoder: 48L, d_model=2048, 32 heads (kv=4), head_dim=128,
128 experts top-8, per-expert d_ff=768, vocab=151936, qk-norm.
"""
from repro.configs.base import MOE, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family=MOE,
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=768,
    fsdp=True,
)
