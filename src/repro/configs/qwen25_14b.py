"""Qwen2.5-14B [hf:Qwen/Qwen2.5 family].

Dense GQA decoder with QKV bias: 48L, d_model=5120, 40 heads (kv=8),
head_dim=128, d_ff=13824, vocab=152064.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family=DENSE,
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    fsdp=True,
)
