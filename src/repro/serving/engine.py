"""Serving engine: jitted prefill/decode with replica-routed batches.

``ServingEngine`` owns one model replica (params + cache); the
``QEdgeRouter`` (router.py) distributes microbatches across engines and
consumes their measured latencies as bandit feedback — see
examples/serve_routed.py for the full loop.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import Model


class ServingEngine:
    """Single-replica prefill/decode executor with timing."""

    def __init__(self, model: Model, params, max_len: int,
                 extra_latency: float = 0.0):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.extra_latency = extra_latency    # emulated network distance
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len))
        self._decode = jax.jit(model.decode)
        self.queue_depth = 0

    def prefill(self, batch):
        t0 = time.monotonic()
        logits, cache = self._prefill(self.params, batch)
        jax.block_until_ready(logits)
        return logits, cache, time.monotonic() - t0 + self.extra_latency

    def decode(self, cache, token, pos):
        t0 = time.monotonic()
        logits, cache = self._decode(
            self.params, cache, {"token": token, "pos": jnp.int32(pos)})
        jax.block_until_ready(logits)
        lat = time.monotonic() - t0 + self.extra_latency
        return logits, cache, lat


def generate(model: Model, params, prompt: jax.Array, steps: int,
             max_len: Optional[int] = None, greedy: bool = True,
             key: Optional[jax.Array] = None):
    """Simple generation loop (prefill + `steps` decode steps)."""
    B, S = prompt.shape
    max_len = max_len or (S + steps)
    logits, cache = model.prefill(params, {"tokens": prompt},
                                  max_len=max_len)
    decode = jax.jit(model.decode)
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(steps):
        out.append(tok)
        logits, cache = decode(params, cache,
                               {"token": tok, "pos": jnp.int32(S + i)})
        if greedy or key is None:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1].astype(jnp.float32))[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)
