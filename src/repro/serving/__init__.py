"""Serving runtime: engines, generation, QEdgeProxy replica routing."""
from repro.serving.engine import ServingEngine, generate
from repro.serving.router import QEdgeRouter

__all__ = ["ServingEngine", "generate", "QEdgeRouter"]
