"""QEdgeProxy replica router: the paper's technique as the serving
framework's request scheduler.

Mapping (DESIGN.md §3): *players* = front-end request shards (one per
ingress/pod), *arms* = data-parallel replica groups on the mesh.
Rewards stay heterogeneous (front-end <-> replica distance, per-replica
load) and collisions stay implicit (two front-ends picking the same
replica lengthen its batch queue) — exactly the paper's MP-MAB.

The router is host-side control plane with jitted state updates; the
error-count cooldown (Alg 2) doubles as straggler mitigation and the
instance add/remove handlers (Alg 3/4) as the elastic-scaling hooks.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bandit as qb


class QEdgeRouter:
    """Routes request microbatches from K front-ends to M replicas."""

    def __init__(
        self,
        num_frontends: int,
        num_replicas: int,
        params: Optional[qb.BanditParams] = None,
        rtt: Optional[np.ndarray] = None,   # (K, M) static distance [s]
        ring: int = 64,
        seed: int = 0,
    ):
        self.K, self.M = num_frontends, num_replicas
        self.params = params or qb.BanditParams()
        self.rtt = jnp.asarray(
            rtt if rtt is not None else np.zeros((self.K, self.M)),
            jnp.float32)
        self.state = qb.init_state(
            self.K, self.M, self.params, ring=ring,
            key=jax.random.PRNGKey(seed))
        self._select = jax.jit(qb.select)
        self._record = jax.jit(qb.record, static_argnums=1)
        self._maint = jax.jit(qb.maintenance, static_argnums=1)
        self._sync = jax.jit(qb.sync_active, static_argnums=1)
        self.t0 = time.monotonic()

    def _now(self) -> float:
        return time.monotonic() - self.t0

    # -- request path -------------------------------------------------
    def route(self) -> np.ndarray:
        """Pick a replica for each front-end's next microbatch. (K,)"""
        choice, self.state, _ = self._select(self.state)
        return np.asarray(choice)

    def feedback(self, choice: Sequence[int], latency: Sequence[float],
                 mask: Optional[Sequence[bool]] = None):
        """Report measured per-microbatch latencies (seconds)."""
        m = (jnp.ones((self.K,), bool) if mask is None
             else jnp.asarray(mask, bool))
        self.state = self._record(
            self.state, self.params, jnp.asarray(choice, jnp.int32),
            jnp.asarray(latency, jnp.float32), jnp.float32(self._now()), m)

    def maintenance(self):
        self.state = self._maint(self.state, self.params, self.rtt,
                                 jnp.float32(self._now()))

    # -- elastic / fault hooks (paper Alg 3/4) ------------------------
    def replicas_changed(self, active: Sequence[bool]):
        self.state = self._sync(self.state, self.params,
                                jnp.asarray(active, bool))

    def replica_failed(self, idx: int):
        act = np.asarray(self.state.active).copy()
        act[idx] = False
        self.replicas_changed(act)

    def replica_joined(self, idx: int):
        act = np.asarray(self.state.active).copy()
        act[idx] = True
        self.replicas_changed(act)

    def mesh_resized(self, surviving_rows: int):
        """Elastic re-mesh hook (fault/elastic.py step 3): after the
        runtime shrinks the data axis, mask every replica beyond the
        surviving rows so no microbatch routes to a dead replica group
        — Alg 4 immediately, not after the error-count cooldown trips.
        Growing back to ``M`` rows re-enters replicas through the Alg 3
        zero-weight ramp."""
        from repro.fault.elastic import surviving_replicas
        self.replicas_changed(surviving_replicas(self.M, surviving_rows))

    # -- introspection -------------------------------------------------
    @property
    def weights(self) -> np.ndarray:
        return np.asarray(self.state.weights)

    @property
    def qos_estimates(self) -> np.ndarray:
        return np.asarray(self.state.mu_hat)

    def in_cooldown(self) -> np.ndarray:
        return np.asarray(self.state.cooldown_until > self._now())
