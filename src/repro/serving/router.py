"""QEdgeProxy replica router: the paper's technique as the serving
framework's request scheduler.

Mapping (DESIGN.md §3): *players* = front-end request shards (one per
ingress/pod), *arms* = data-parallel replica groups on the mesh.
Rewards stay heterogeneous (front-end <-> replica distance, per-replica
load) and collisions stay implicit (two front-ends picking the same
replica lengthen its batch queue) — exactly the paper's MP-MAB.

The router is host-side control plane with jitted state updates; the
error-count cooldown (Alg 2) doubles as straggler mitigation and the
instance add/remove handlers (Alg 3/4) as the elastic-scaling hooks.

Every membership change (failure, join, resize, explicit active-set
sync) lands in ``self.events`` — the host-side mirror of the in-loop
flight recorder — and :meth:`QEdgeRouter.export_trace` writes them as
a Perfetto-loadable Chrome trace on the same lane conventions as
``repro.obs.trace``.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bandit as qb


class QEdgeRouter:
    """Routes request microbatches from K front-ends to M replicas."""

    def __init__(
        self,
        num_frontends: int,
        num_replicas: int,
        params: Optional[qb.BanditParams] = None,
        rtt: Optional[np.ndarray] = None,   # (K, M) static distance [s]
        ring: int = 64,
        seed: int = 0,
    ):
        self.K, self.M = num_frontends, num_replicas
        self.params = params or qb.BanditParams()
        self.rtt = jnp.asarray(
            rtt if rtt is not None else np.zeros((self.K, self.M)),
            jnp.float32)
        self.state = qb.init_state(
            self.K, self.M, self.params, ring=ring,
            key=jax.random.PRNGKey(seed))
        self._select = jax.jit(qb.select)
        self._record = jax.jit(qb.record, static_argnums=1)
        self._maint = jax.jit(qb.maintenance, static_argnums=1)
        self._sync = jax.jit(qb.sync_active, static_argnums=1)
        self.t0 = time.monotonic()
        # host-side flight log: (t_seconds, kind, entity, value) per
        # membership event, in occurrence order
        self.events: List[tuple] = []

    def _now(self) -> float:
        return time.monotonic() - self.t0

    def _log(self, kind: str, entity: int, value: float):
        self.events.append((self._now(), kind, int(entity), float(value)))

    # -- request path -------------------------------------------------
    def route(self) -> np.ndarray:
        """Pick a replica for each front-end's next microbatch. (K,)"""
        choice, self.state, _ = self._select(self.state)
        return np.asarray(choice)

    def feedback(self, choice: Sequence[int], latency: Sequence[float],
                 mask: Optional[Sequence[bool]] = None):
        """Report measured per-microbatch latencies (seconds)."""
        m = (jnp.ones((self.K,), bool) if mask is None
             else jnp.asarray(mask, bool))
        self.state = self._record(
            self.state, self.params, jnp.asarray(choice, jnp.int32),
            jnp.asarray(latency, jnp.float32), jnp.float32(self._now()), m)

    def maintenance(self):
        self.state = self._maint(self.state, self.params, self.rtt,
                                 jnp.float32(self._now()))

    # -- elastic / fault hooks (paper Alg 3/4) ------------------------
    def replicas_changed(self, active: Sequence[bool]):
        act = jnp.asarray(active, bool)
        self._log("replicas_changed", -1, float(np.asarray(act).sum()))
        self.state = self._sync(self.state, self.params, act)

    def replica_failed(self, idx: int):
        self._log("replica_failed", idx, 0.0)
        act = np.asarray(self.state.active).copy()
        act[idx] = False
        self.replicas_changed(act)

    def replica_joined(self, idx: int):
        self._log("replica_joined", idx, 1.0)
        act = np.asarray(self.state.active).copy()
        act[idx] = True
        self.replicas_changed(act)

    def mesh_resized(self, surviving_rows: int):
        """Elastic re-mesh hook (fault/elastic.py step 3): after the
        runtime shrinks the data axis, mask every replica beyond the
        surviving rows so no microbatch routes to a dead replica group
        — Alg 4 immediately, not after the error-count cooldown trips.
        Growing back to ``M`` rows re-enters replicas through the Alg 3
        zero-weight ramp."""
        from repro.fault.elastic import surviving_replicas
        self._log("mesh_resized", -1, float(surviving_rows))
        self.replicas_changed(surviving_replicas(self.M, surviving_rows))

    def export_trace(self, path: str) -> dict:
        """Write the membership flight log as a Chrome trace (one
        ``router`` process lane, one thread per event kind, instants at
        host-relative wall time). Loads in Perfetto next to a
        simulator trace from the same run."""
        from repro.obs import trace as obs_trace
        pid, named, evs = 2, set(), []
        kinds = []
        for _, kind, _, _ in self.events:
            if kind not in kinds:
                kinds.append(kind)
        for t, kind, entity, value in self.events:
            tid = kinds.index(kind) + 1
            if not named:
                evs.append(obs_trace._meta(pid, 0, "process_name",
                                           "router"))
                named.add(None)
            if kind not in named:
                evs.append(obs_trace._meta(pid, tid, "thread_name", kind))
                named.add(kind)
            evs.append({"ph": "i", "s": "t", "pid": pid, "tid": tid,
                        "name": kind, "cat": "router", "ts": t * 1e6,
                        "args": {"entity": entity, "value": value}})
        return obs_trace.write_chrome_trace(path, evs)

    # -- introspection -------------------------------------------------
    @property
    def weights(self) -> np.ndarray:
        return np.asarray(self.state.weights)

    @property
    def qos_estimates(self) -> np.ndarray:
        return np.asarray(self.state.mu_hat)

    def in_cooldown(self) -> np.ndarray:
        return np.asarray(self.state.cooldown_until > self._now())
